"""Fault tolerance, checkpoint/restart, straggler detection, data pipeline,
gradient compression."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointConfig, CheckpointStore
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models import ModelConfig
from repro.optim.compress import dequantize_int8, error_feedback_update, quantize_int8
from repro.runtime.trainer import (
    FailureInjector,
    StragglerMonitor,
    Trainer,
    TrainerConfig,
    run_supervised,
)

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                   dtype="float32")


def test_data_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=8)
    src = SyntheticLM(cfg)
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=8,
                                host_index=0, host_count=2))
    h1 = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=8,
                                host_index=1, host_count=2))
    assert h0.batch_at(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_prefetcher_orders_batches():
    src = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=2))
    pf = Prefetcher(src, start_step=3)
    try:
        for expect in (3, 4, 5):
            step, batch = pf.next()
            assert step == expect
            np.testing.assert_array_equal(batch["tokens"],
                                          src.batch_at(expect)["tokens"])
    finally:
        pf.close()


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(CheckpointConfig(directory=str(tmp_path),
                                             async_save=False))
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    store.save(7, state)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = store.restore(like)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_retention(tmp_path):
    store = CheckpointStore(CheckpointConfig(directory=str(tmp_path),
                                             keep=2, async_save=False))
    for s in (1, 2, 3, 4):
        store.save(s, {"x": jnp.zeros(3)})
    assert store.all_steps() == [3, 4]


def test_failure_restart_resumes_and_matches(tmp_path):
    """The supervisor restarts from the checkpoint after an injected
    failure and reaches the same final loss trajectory as an uninterrupted
    run (deterministic data + checkpointed state)."""
    data = DataConfig(vocab_size=64, seq_len=16, global_batch=4, seed=9)
    tc = TrainerConfig(total_steps=12, save_every=4, log_every=100)

    clean = Trainer(TINY, data, trainer_cfg=tc,
                    ckpt_cfg=CheckpointConfig(directory=str(tmp_path / "clean"),
                                              async_save=False))
    out_clean = run_supervised(clean)

    faulty = Trainer(TINY, data, trainer_cfg=tc,
                     ckpt_cfg=CheckpointConfig(directory=str(tmp_path / "faulty"),
                                               async_save=False))
    out_faulty = run_supervised(faulty, FailureInjector(fail_at=(6,)))
    assert out_faulty["restarts"] == 1
    # the post-restart losses re-cover steps 4..12 deterministically:
    # final loss equals the clean run's final loss
    assert abs(out_clean["losses"][-1] - out_faulty["losses"][-1]) < 1e-4


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(window=30)
    rng = np.random.default_rng(0)
    flagged = False
    for i in range(40):
        dt = 0.1 + rng.normal(0, 0.002)
        if i == 35:
            dt = 0.5  # straggling step
        flagged |= mon.observe(i, dt)
    assert flagged
    assert 35 in mon.flagged


def test_grad_compression_error_feedback():
    """int8 compression: bounded per-step error; error feedback keeps the
    *accumulated* signal unbiased (residual stays bounded)."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(0, 0.01, (64, 64)), jnp.float32)}
    q, scale = quantize_int8(g["w"])
    deq = dequantize_int8(q, scale, g["w"].shape)
    rel = float(jnp.max(jnp.abs(deq - g["w"]))) / float(jnp.max(jnp.abs(g["w"])))
    assert rel < 0.02

    residual = None
    total_true = jnp.zeros((8, 8))
    total_sent = jnp.zeros((8, 8))
    for step in range(30):
        g = {"w": jnp.asarray(rng.normal(0, 0.01, (8, 8)), jnp.float32)}
        comp, decomp, residual = error_feedback_update(g, residual)
        total_true = total_true + g["w"]
        total_sent = total_sent + decomp["w"]
    # accumulated transmitted signal tracks the accumulated true signal
    err = float(jnp.max(jnp.abs(total_sent - total_true)))
    res = float(jnp.max(jnp.abs(residual["w"])))
    assert err <= res + 1e-6   # the only gap is the current residual
    assert res < 0.01


def test_elastic_restore_to_different_mesh(tmp_path):
    """Checkpoints are mesh-agnostic: state saved from one device layout
    restores onto explicit shardings of another mesh (elastic restart)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    store = CheckpointStore(CheckpointConfig(directory=str(tmp_path),
                                             async_save=False))
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4),
             "step": jnp.int32(3)}
    store.save(11, state)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", None)),
                 "step": NamedSharding(mesh, P())}
    restored, step = store.restore(jax.tree.map(jnp.zeros_like, state),
                                   shardings=shardings)
    assert step == 11
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding.is_equivalent_to(shardings["w"], 2)
