"""Dry-run infrastructure: HLO analyzer calibration, sharding specs, and a
multi-device lowering test (8 forced host devices in a subprocess so the
main test process keeps its single-device view)."""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def test_analyzer_scales_while_loops():
    """cost_analysis counts loop bodies once; the analyzer multiplies by
    known_trip_count — calibrated on a scan of matmuls."""

    def scan_matmuls(w, x, n):
        def body(x, _):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, None, length=n)
        return x

    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    for n in (1, 4, 16):
        c = jax.jit(scan_matmuls, static_argnums=2).lower(w, x, n).compile()
        h = analyze_hlo(c.as_text())
        expected = n * 2 * 256**3
        assert abs(h.flops - expected) / expected < 0.01, (n, h.flops)
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        if n > 1:  # demonstrate the cost_analysis undercount
            assert float(ca.get("flops", 0)) < expected


def test_analyzer_bytes_monotone_in_depth():
    def stack(x, n):
        def body(x, _):
            return jnp.tanh(x @ x), None
        x, _ = jax.lax.scan(body, x, None, length=n)
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    b4 = analyze_hlo(jax.jit(stack, static_argnums=1).lower(x, 4).compile().as_text())
    b16 = analyze_hlo(jax.jit(stack, static_argnums=1).lower(x, 16).compile().as_text())
    assert b16.bytes_accessed > 2 * b4.bytes_accessed


def test_param_specs_cover_all_archs():
    """Every parameter leaf of every arch gets a valid spec of its rank."""
    from repro.configs import ARCHS, get_smoke
    from repro.launch.steps import abstract_params
    from repro.parallel import ShardingConfig, param_specs

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ARCHS:
        cfg = get_smoke(arch)
        shapes = abstract_params(cfg)
        specs = param_specs(shapes, cfg, mesh, ShardingConfig())
        leaves_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or
            type(x).__name__ == "PartitionSpec")
        leaves_p = jax.tree_util.tree_leaves(shapes)
        assert len(leaves_s) == len(leaves_p)


_SUBPROC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from repro.configs import get_smoke
    from repro.launch.dryrun import lower_cell  # noqa: re-exec safe
    from repro.launch.steps import (abstract_train_state, input_specs,
                                    make_train_step)
    from repro.parallel import ShardingConfig, batch_specs, param_specs
    from repro.configs.shapes import ShapeSpec
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_smoke("gemma2-2b")
    shape = ShapeSpec("t", 32, 8, "train")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    state = abstract_train_state(cfg)
    p_specs = param_specs(state["params"], cfg, mesh, ShardingConfig())
    specs = input_specs(cfg, shape)
    b_specs = batch_specs(mesh, specs)
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    opt_specs = {"m": p_specs, "v": p_specs, "count": P()}
    step = make_train_step(cfg)
    with mesh:
        lowered = jax.jit(step, in_shardings=(
            named({"params": p_specs, "opt": opt_specs}), named(b_specs))
        ).lower(state, specs)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    print(json.dumps({"ok": True, "flops": float(ca.get("flops", 0))}))
""")


@pytest.mark.slow  # ~8 min: spawns a fresh 8-device child interpreter
def test_multi_device_lowering_subprocess():
    """8-device mesh lowering succeeds end-to-end (train step, smoke config,
    real sharding rules) — run in a subprocess so this process keeps its
    1-device view (dryrun.py isolation contract)."""
    res = subprocess.run([sys.executable, "-c", _SUBPROC_SCRIPT],
                         capture_output=True, text=True, timeout=560,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["flops"] > 0


def test_roofline_report_math():
    from repro.launch.roofline import RooflineReport

    r = RooflineReport(
        arch="a", shape="s", mesh="16x16", chips=256,
        flops_per_device=1.97e13, bytes_per_device=8.19e11,
        collective_bytes_per_device=5e10, collective_ops={},
        collective_bytes_by_op={}, memory_per_device={},
        model_flops_global=1.97e13 * 256 * 0.75, model_params=int(1e9))
    assert abs(r.t_compute - 0.1) < 1e-6
    assert abs(r.t_memory - 1.0) < 1e-6
    assert abs(r.t_collective - 1.0) < 1e-6
    assert r.bottleneck in ("memory", "collective")
    assert abs(r.useful_flops_ratio - 0.75) < 1e-9
