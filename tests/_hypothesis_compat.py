"""Optional-dependency shim for ``hypothesis``.

``from _hypothesis_compat import given, settings, st`` yields the real
hypothesis API when it is installed. When it is not, the property-based
tests decorated with ``@given`` collect as skipped placeholders instead of
hard-failing the whole test module at import time; every non-property test
in the module still runs.
"""

HAVE_HYPOTHESIS = True

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any attribute/call chain used to build strategies."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        def decorate(fn):
            @pytest.mark.skip(reason="optional dep 'hypothesis' not installed")
            def placeholder():
                pass

            placeholder.__name__ = getattr(fn, "__name__", "test_property")
            placeholder.__doc__ = getattr(fn, "__doc__", None)
            return placeholder

        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate
