"""Every performance knob must be semantics-preserving (§Perf discipline):
the tuned lowering computes the same loss as the paper-faithful baseline."""

import jax
import pytest

from repro.configs import get_smoke
from repro.models import init_params, loss_fn
from repro.models.tuning import reset_tuning, set_tuning, tuning_tag


@pytest.fixture(autouse=True)
def _clean_tuning():
    reset_tuning()
    yield
    reset_tuning()


def _loss(arch, **knobs):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    reset_tuning()
    if knobs:
        set_tuning(**knobs)
    out = float(loss_fn(cfg, params, batch)[0])
    reset_tuning()
    return out


def test_moe_vmap_dispatch_equivalent():
    base = _loss("mixtral-8x22b")
    tuned = _loss("mixtral-8x22b", moe_vmap_dispatch=True)
    assert abs(base - tuned) < 1e-5


def test_ce_chunk_equivalent():
    base = _loss("gemma2-2b")
    tuned = _loss("gemma2-2b", ce_chunk=4)
    assert abs(base - tuned) < 1e-4


def test_attn_mask_and_norm_knobs_equivalent():
    base = _loss("gemma3-27b")
    tuned = _loss("gemma3-27b", attn_additive_mask=True, norm_bf16_io=True)
    assert abs(base - tuned) < 1e-4


def test_attn_probs_bf16_close():
    # bf16 softmax intermediates: small, bounded deviation allowed
    base = _loss("granite-20b")
    tuned = _loss("granite-20b", attn_probs_bf16=True)
    assert abs(base - tuned) < 5e-2


def test_tuning_tag_roundtrip():
    reset_tuning()
    assert tuning_tag() == "baseline"
    set_tuning(moe_vmap_dispatch=True, ce_chunk=8)
    tag = tuning_tag()
    assert "moe_vmap_dispatch=True" in tag and "ce_chunk=8" in tag
