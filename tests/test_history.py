"""Reproducibility-audit subsystem: store schema versioning, the run
archive (manifest-indexed lookups, content-hash idempotence), and the
TOST verdict engine — including the acceptance scenario (same-seed runs
certify EQUIVALENT, a mis-tuned collective drifts exactly its own cells)
and audit kill/resume at cell granularity."""

import json

import numpy as np
import pytest

import repro.history.audit as audit_mod
from repro.campaign import (SCHEMA_VERSION, Campaign, CampaignSpec,
                            ResultStore, SimBackend)
from repro.core import (EpochSummary, ExperimentDesign, ResultTable, TestCase)
from repro.history import (RunArchive, audit_runs, audit_tables,
                           format_audit_report, format_drift)

FAST_SYNC = dict(n_fitpts=60, n_exchanges=20)
AUDIT_CASES = [TestCase(op, m) for op in ("allreduce", "bcast")
               for m in (512, 4096)]


#: Quiet cost model: per-epoch medians spread ~±3-5% at p=8, so the ±10%
#: TOST margin is certifiable from 12 launch epochs (noisier regimes
#: correctly land in INCONCLUSIVE — tested on synthetic tables below).
QUIET = dict(noise_sigma=0.01, tail_prob=0.02, epoch_bias_sigma=0.005)


def _backend(seed0=0, per_op_kw=None):
    return SimBackend(p=8, seed0=seed0, per_op_kw=per_op_kw or {},
                      op_kw=dict(QUIET), sync_kw=dict(FAST_SYNC))


def _design(**kw):
    base = dict(n_launch_epochs=12, nrep=40, seed=5)
    base.update(kw)
    return ExperimentDesign(**base)


def _run_into(archive, backend, tag=None, cases=AUDIT_CASES, design=None):
    store = ResultStore(archive.new_store_path())
    Campaign(CampaignSpec(cases, design or _design(), name="audit-test"),
             backend, store).run()
    return archive.register(store.path, tag=tag)


def _table(cells: dict) -> ResultTable:
    """A ResultTable straight from per-epoch median values — the synthetic
    input that lets verdict code be tested without measuring anything."""
    summaries = [
        EpochSummary(case=TestCase(op, msize), epoch=e, mean=float(v),
                     median=float(v), n_kept=1, n_raw=1)
        for (op, msize), values in cells.items()
        for e, v in enumerate(values)
    ]
    return ResultTable(summaries=summaries)


# ---------------------------------------------------------------------------
# Store schema versioning (the silent-version-skew bugfix)
# ---------------------------------------------------------------------------

def test_new_store_stamps_schema_header(tmp_path):
    path = tmp_path / "a.jsonl"
    store = ResultStore(path)
    store.append_campaign(_backend().factors(_design()))
    first = json.loads(path.read_text().splitlines()[0])
    assert first == {"kind": "schema", "version": SCHEMA_VERSION}
    assert store.schema_version() == SCHEMA_VERSION
    # one header only, even across many appends
    store.append_meta(note="x")
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert sum(1 for o in lines if o["kind"] == "schema") == 1


def test_legacy_store_without_header_still_loads(tmp_path):
    store = ResultStore(tmp_path / "legacy.jsonl")
    res = Campaign(CampaignSpec([TestCase("allreduce", 256)],
                                _design(n_launch_epochs=2, nrep=5)),
                   _backend(), store).run()
    # strip the header: the pre-versioning format
    lines = [ln for ln in store.path.read_text().splitlines()
             if '"schema"' not in ln]
    legacy = tmp_path / "stripped.jsonl"
    legacy.write_text("\n".join(lines) + "\n")
    old = ResultStore(legacy)
    assert old.schema_version() == 0
    assert len(old.records(res.fingerprint)) == 2


def test_future_schema_version_raises_instead_of_warning(tmp_path):
    """The bugfix: version skew must fail loudly, not warn-and-drop lines
    (which silently re-measures or merges a resumed campaign)."""
    path = tmp_path / "future.jsonl"
    path.write_text(json.dumps({"kind": "schema",
                                "version": SCHEMA_VERSION + 1}) + "\n"
                    + json.dumps({"kind": "record", "fingerprint": "x",
                                  "op": "bcast", "msize": 1, "epoch": 0,
                                  "times": [1.0]}) + "\n")
    with pytest.raises(ValueError, match="schema version"):
        ResultStore(path).records("x")
    # resuming a campaign into it must refuse too (append consults _lines)
    with pytest.raises(ValueError, match="schema version"):
        Campaign(CampaignSpec([TestCase("allreduce", 256)],
                              _design(n_launch_epochs=1, nrep=5)),
                 _backend(), ResultStore(path)).run()


def test_meta_lines_round_trip_and_stay_out_of_records(tmp_path):
    store = ResultStore(tmp_path / "m.jsonl")
    res = Campaign(CampaignSpec([TestCase("allreduce", 256)],
                                _design(n_launch_epochs=2, nrep=5)),
                   _backend(), store).run()
    store.append_meta(archived=dict(run_id="abc", tag="ref"))
    store.append_meta(note="second stamp")
    meta = store.meta()
    assert meta["archived"]["run_id"] == "abc" and meta["note"] == "second stamp"
    assert len(store.records(res.fingerprint)) == 2


# ---------------------------------------------------------------------------
# Run archive: registration, manifest lookups, baseline resolution
# ---------------------------------------------------------------------------

def test_register_is_idempotent_and_stamping_preserves_identity(tmp_path):
    archive = RunArchive(tmp_path / "arch")
    entry = _run_into(archive, _backend())
    # registration stamped the store; re-registering the stamped file must
    # return the same run (meta lines are outside the content identity)
    store = archive.open_store(entry)
    assert store.meta()["archived"]["run_id"] == entry.run_id
    again = archive.register(store.path)
    assert again.run_id == entry.run_id
    assert len(archive.entries()) == 1


def test_grown_store_supersedes_its_entry(tmp_path):
    archive = RunArchive(tmp_path / "arch")
    entry = _run_into(archive, _backend(),
                      cases=[TestCase("allreduce", 512)])
    # resume the campaign with one more case: same file, more records
    store = archive.open_store(entry)
    Campaign(CampaignSpec([TestCase("allreduce", 512),
                           TestCase("bcast", 512)], _design(),
                          name="audit-test"), _backend(), store).run()
    grown = archive.register(store.path)
    assert grown.run_id != entry.run_id
    assert grown.n_records > entry.n_records
    assert len(archive.entries()) == 2          # history keeps both
    assert [e.run_id for e in archive.runs()] == [grown.run_id]  # latest wins


def test_manifest_carries_index_without_reparsing_stores(tmp_path):
    archive = RunArchive(tmp_path / "arch")
    entry = _run_into(archive, _backend(), tag="reference")
    # lookups must work from the manifest alone — even if the store files
    # vanish, runs()/entry()/baseline_for() still answer
    archive.open_store(entry).path.unlink()
    assert archive.runs(tag="reference")[0].run_id == entry.run_id
    assert archive.entry(entry.run_id).fingerprints == entry.fingerprints
    assert entry.host and entry.n_records == len(AUDIT_CASES) * 12
    assert entry.names == ("audit-test",)
    assert entry.schema_version == SCHEMA_VERSION
    assert entry.factors["measurement_backend"] == "sim"


def test_baseline_resolution_fingerprint_tag_and_name_fallback(tmp_path):
    archive = RunArchive(tmp_path / "arch")
    ref = _run_into(archive, _backend(), tag="reference")
    cand = _run_into(archive, _backend())
    assert archive.baseline_for(cand).run_id == ref.run_id   # same fingerprint
    # a mis-tuned backend changes the fingerprint: the name fallback (and
    # the tag pin) still find the reference
    bad = _run_into(archive, _backend(per_op_kw={"bcast": dict(alpha=9e-6)}))
    assert not (set(bad.fingerprints) & set(ref.fingerprints))
    assert archive.baseline_for(bad).run_id == cand.run_id
    assert archive.baseline_for(bad, tag="reference").run_id == ref.run_id
    with pytest.raises(KeyError, match="no archived run tagged"):
        archive.baseline_for(bad, tag="nonesuch")
    # the first run has no baseline
    assert archive.baseline_for(ref) is None


def test_retagging_a_registered_run_supersedes_not_drops(tmp_path):
    """Registering an unchanged store again *with a tag* must re-tag it
    (e.g. pinning an auto-registered run as the reference), not silently
    return the old untagged entry."""
    archive = RunArchive(tmp_path / "arch")
    entry = _run_into(archive, _backend())          # untagged
    assert entry.tag is None
    retagged = archive.register(archive.open_store(entry).path,
                                tag="reference")
    assert retagged.run_id == entry.run_id
    assert retagged.tag == "reference"
    assert retagged.timestamp == entry.timestamp    # age is unchanged
    assert archive.runs(tag="reference")[0].run_id == entry.run_id
    # id-based lookup sees the superseding entry, not the stale original
    assert archive.entry(entry.run_id).tag == "reference"
    # and it is idempotent at the new tag
    assert len(archive.entries()) == 2
    archive.register(archive.open_store(entry).path, tag="reference")
    assert len(archive.entries()) == 2


def test_control_runs_never_become_default_baselines(tmp_path):
    """A seeded-drift (control) run stays archived but is skipped by
    default baseline resolution — otherwise a second bad run would
    'pass' its audit against the first one."""
    from repro.history.archive import CONTROL_TAG

    archive = RunArchive(tmp_path / "arch")
    ref = _run_into(archive, _backend(), tag="reference")
    mistuned = {"bcast": dict(alpha=12e-6, gamma=6e-6)}
    bad1 = _run_into(archive, _backend(per_op_kw=mistuned), tag=CONTROL_TAG)
    bad2 = _run_into(archive, _backend(per_op_kw=mistuned), tag=CONTROL_TAG)
    # bad2 shares a fingerprint with bad1, but bad1 is a control: the
    # default baseline is the honest reference, and the audit still fails
    assert set(bad2.fingerprints) == set(bad1.fingerprints)
    assert archive.baseline_for(bad2).run_id == ref.run_id
    report = audit_runs(archive, bad2)
    assert {c.op for c in report.drifted()} == {"bcast"}
    # an explicit tag pin can still select a control deliberately
    assert archive.baseline_for(bad2, tag=CONTROL_TAG).run_id == bad1.run_id


def test_new_store_path_never_collides(tmp_path):
    archive = RunArchive(tmp_path / "arch")
    a = archive.new_store_path()
    a.write_text("")
    b = archive.new_store_path()
    assert a.name == "run-000.jsonl" and b.name == "run-001.jsonl"


def test_campaign_auto_registers_into_archive(tmp_path):
    archive = RunArchive(tmp_path / "arch")
    store = ResultStore(archive.new_store_path())
    res = Campaign(CampaignSpec([TestCase("allreduce", 512)],
                                _design(n_launch_epochs=2, nrep=5)),
                   _backend(), store, archive=archive).run()
    run_id = res.meta["archived_run"]
    assert archive.entry(run_id).fingerprints == (res.fingerprint,)
    with pytest.raises(ValueError, match="needs a store"):
        Campaign(CampaignSpec([], _design()), _backend(), archive=archive)


# ---------------------------------------------------------------------------
# Verdict engine on synthetic tables
# ---------------------------------------------------------------------------

def test_audit_tables_identical_distributions_certify_equivalent():
    rng = np.random.default_rng(0)
    cells = {("allreduce", 512): rng.lognormal(-11, 0.02, 15)}
    ref = _table(cells)
    cand = _table({k: v * rng.lognormal(0, 0.005, v.size)
                   for k, v in cells.items()})
    report = audit_tables(ref, cand, margin=0.10)
    assert report.all_equivalent and report.ok


def test_audit_tables_shift_beyond_margin_drifts():
    rng = np.random.default_rng(1)
    base = rng.lognormal(-11, 0.02, 15)
    ref = _table({("allreduce", 512): base, ("bcast", 512): base})
    cand = _table({("allreduce", 512): base * 1.4,
                   ("bcast", 512): base * rng.lognormal(0, 0.005, base.size)})
    report = audit_tables(ref, cand, margin=0.10)
    verdicts = {c.op: c.verdict for c in report.cells}
    assert verdicts == {"allreduce": "DRIFTED", "bcast": "EQUIVALENT"}
    assert not report.ok
    drifted = report.drifted()[0]
    assert drifted.ci_lo > 1.1 and drifted.ratio == pytest.approx(1.4, rel=0.1)
    assert "allreduce @ msize=512" in format_drift(report)
    assert format_drift(audit_tables(ref, ref)) == ""


def test_audit_tables_small_sample_is_inconclusive_not_equivalent():
    """The whole point of TOST: too little data must NOT pass the gate as
    'no significant difference' — two identical epochs prove nothing, and
    the exact-p floor keeps the normal approximation from pretending
    otherwise."""
    rng = np.random.default_rng(2)
    cells = {("allreduce", 512): rng.lognormal(-11, 0.02, 2)}
    ref, cand = _table(cells), _table({k: v.copy() for k, v in cells.items()})
    report = audit_tables(ref, cand, margin=0.10)
    assert report.cells[0].verdict == "INCONCLUSIVE"
    assert report.cells[0].p_tost >= 1.0 / 6.0   # 1 / C(4, 2): the exact floor
    assert report.ok                 # inconclusive does not fail the gate
    assert not report.all_equivalent


def test_constant_identical_runs_are_not_drifted():
    """Degenerate determinism: a backend with quantized timings can yield
    bit-identical *constant* per-epoch medians. All-tied samples carry no
    ordering information — the exact rank-sum p is 1 — so the audit must
    certify, not let a zero-variance normal approximation scream DRIFTED."""
    from repro.core import wilcoxon_rank_sum

    const = np.full(10, 12.5e-6)
    for alt in ("two-sided", "less", "greater"):
        assert wilcoxon_rank_sum(const, const, alt).p_value == 1.0
    cells = {("allreduce", m): const.copy() for m in (512, 4096)}
    report = audit_tables(_table(cells), _table(cells), margin=0.10)
    assert report.all_equivalent
    assert all(c.p_diff == 1.0 for c in report.cells)


def test_audit_tables_requires_common_cells():
    with pytest.raises(ValueError, match="no common"):
        audit_tables(_table({("a", 1): np.ones(5)}),
                     _table({("b", 1): np.ones(5)}))


def test_audit_margin_validation():
    from repro.core import tost_wilcoxon

    with pytest.raises(ValueError, match="margin"):
        tost_wilcoxon(np.ones(5), np.ones(5), margin=1.5)
    with pytest.raises(ValueError, match="positive"):
        tost_wilcoxon(np.zeros(5), np.ones(5), margin=0.1)


# ---------------------------------------------------------------------------
# End-to-end: the acceptance scenario
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def seeded_archive(tmp_path_factory):
    """One reference + one same-seed re-run + one mis-tuned run."""
    archive = RunArchive(tmp_path_factory.mktemp("hist") / "arch")
    ref = _run_into(archive, _backend(), tag="reference")
    cand = _run_into(archive, _backend())
    bad = _run_into(archive, _backend(
        per_op_kw={"bcast": dict(alpha=12e-6, gamma=6e-6)}))
    return archive, ref, cand, bad


def test_same_seed_reruns_certify_all_equivalent(seeded_archive):
    archive, ref, cand, _ = seeded_archive
    report = audit_runs(archive, cand)
    assert report.baseline.run_id == ref.run_id
    assert report.all_equivalent
    assert len(report.cells) == len(AUDIT_CASES)
    assert not report.factor_diffs
    out = format_audit_report(report, title="audit")
    assert out.count("EQUIVALENT") >= len(AUDIT_CASES)


def test_mistuned_collective_drifts_exactly_its_own_cells(seeded_archive):
    archive, ref, _, bad = seeded_archive
    report = audit_runs(archive, bad, baseline_tag="reference")
    assert {c.op for c in report.drifted()} == {"bcast"}
    assert all(c.verdict == "EQUIVALENT" for c in report.cells
               if c.op != "bcast")
    assert not report.ok
    # the factor diff names the seeded defect, not the whole extra tuple
    assert any(k.startswith("extra.per_op_kw") for k in report.factor_diffs)


def test_audit_log_resumes_without_recomputation(seeded_archive, monkeypatch):
    archive, ref, cand, _ = seeded_archive
    first = audit_runs(archive, cand)
    calls = []
    orig = audit_mod._audit_cell
    monkeypatch.setattr(audit_mod, "_audit_cell",
                        lambda *a, **k: calls.append(a) or orig(*a, **k))
    again = audit_runs(archive, cand)
    assert not calls
    assert again.n_computed == 0 and again.n_resumed == len(AUDIT_CASES)
    assert [c.verdict for c in again.cells] == [c.verdict
                                                for c in first.cells]
    for a, b in zip(first.cells, again.cells):
        assert a == b           # bootstrap CIs identical: per-cell seeds


def test_killed_audit_recomputes_only_missing_cells(tmp_path, monkeypatch):
    """The kill/resume scenario, mirrored from the sweep tests: an audit
    killed mid-comparison keeps its finished cells in the audit log and
    re-reads them; only the missing cells are recomputed — and the resumed
    report is identical to an uninterrupted one."""
    archive = RunArchive(tmp_path / "arch")
    _run_into(archive, _backend(), tag="reference")
    cand = _run_into(archive, _backend())
    full = audit_runs(archive, cand)

    # simulate the kill: keep the audit log only up to the second cell line
    log = archive.root / "audits.jsonl"
    lines = log.read_text().splitlines()
    cell_lines = [i for i, ln in enumerate(lines) if '"audit-cell"' in ln]
    assert len(cell_lines) == len(AUDIT_CASES)
    log.write_text("\n".join(lines[:cell_lines[1] + 1]) + "\n")

    calls = []
    orig = audit_mod._audit_cell
    monkeypatch.setattr(audit_mod, "_audit_cell",
                        lambda *a, **k: calls.append(a) or orig(*a, **k))
    resumed = audit_runs(archive, cand)
    assert len(calls) == len(AUDIT_CASES) - 2
    assert resumed.n_resumed == 2
    assert resumed.n_computed == len(AUDIT_CASES) - 2
    assert resumed.cells == full.cells       # verdicts, p-values, CIs
    # and the log is complete again: a further run computes nothing
    final = audit_runs(archive, cand)
    assert final.n_computed == 0


def test_audit_without_baseline_raises(tmp_path):
    archive = RunArchive(tmp_path / "arch")
    only = _run_into(archive, _backend())
    with pytest.raises(LookupError, match="no baseline"):
        audit_runs(archive, only)
