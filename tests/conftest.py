"""Shared test configuration.

Requests 4 host CPU devices (``--xla_force_host_platform_device_count``)
*before* the first JAX import in the test process, so
:class:`repro.campaign.JaxBackend` multi-device tests can run on a single
host. Tests that need a real device mesh carry the ``jaxdevices`` marker
and are auto-skipped when JAX still cannot provide enough devices (e.g.
the flag was already consumed by an earlier backend initialization, or an
explicit ``XLA_FLAGS`` overrode it).
"""

import os

import pytest

_REQUIRED_DEVICES = 4

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count={_REQUIRED_DEVICES}"
    ).strip()


def pytest_collection_modifyitems(config, items):
    marked = [it for it in items if it.get_closest_marker("jaxdevices")]
    if not marked:
        return
    import jax

    have = jax.device_count()
    for item in marked:
        marker = item.get_closest_marker("jaxdevices")
        need = marker.kwargs.get("n", marker.args[0] if marker.args
                                 else _REQUIRED_DEVICES)
        if have < need:
            item.add_marker(pytest.mark.skip(
                reason=f"needs >= {need} JAX devices, have {have} "
                       "(--xla_force_host_platform_device_count)"))
