"""Pallas kernel validation: shape/dtype sweeps vs. the pure-jnp oracles
(interpret mode on CPU) + hypothesis property tests (skipped when the
optional ``hypothesis`` dependency is absent)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ops import flash_attention, ssd_scan
from repro.kernels.ssd_scan.ref import ssd_chunked_ref

RNG = np.random.default_rng(0)


def _t(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,s,h,hkv,d", [
    (2, 256, 4, 2, 64),    # GQA
    (1, 512, 8, 1, 64),    # MQA (granite / gemma-2b pattern)
    (2, 128, 4, 4, 128),   # MHA
    (1, 256, 8, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, s, h, hkv, d, dtype):
    q, k, v = _t(b, s, h, d, dtype=dtype), _t(b, s, hkv, d, dtype=dtype), \
        _t(b, s, hkv, d, dtype=dtype)
    out = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    """Mixtral SWA / gemma local layers."""
    q, k, v = _t(2, 256, 4, 64), _t(2, 256, 2, 64), _t(2, 256, 2, 64)
    out = flash_attention(q, k, v, window=window, block_q=128, block_k=128,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    """Gemma-2 logit soft-capping."""
    q, k, v = _t(1, 256, 4, 64, scale=3), _t(1, 256, 4, 64, scale=3), \
        _t(1, 256, 4, 64)
    out = flash_attention(q, k, v, logit_cap=30.0, block_q=128, block_k=128,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, logit_cap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_decode_offset_kvlen():
    """Static decode: 1 query at position 100 against a 256-slot cache with
    kv_len=101."""
    q = _t(2, 128, 4, 64)
    k, v = _t(2, 256, 2, 64), _t(2, 256, 2, 64)
    out = flash_attention_fwd(
        jnp.transpose(q, (0, 2, 1, 3)), jnp.transpose(k, (0, 2, 1, 3)),
        jnp.transpose(v, (0, 2, 1, 3)), None, causal=True, q_offset=100,
        kv_len=172, block_q=128, block_k=128, interpret=True)
    out = jnp.transpose(out, (0, 2, 1, 3))
    ref = flash_attention_ref(q, k, v, q_offset=100, kv_len=172)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_block_size_invariance():
    q, k, v = _t(1, 512, 4, 64), _t(1, 512, 2, 64), _t(1, 512, 2, 64)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in [(128, 128), (256, 512), (512, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


@given(st.integers(1, 3), st.sampled_from([64, 128, 192, 256]),
       st.sampled_from([(4, 2), (8, 1), (4, 4)]),
       st.sampled_from([32, 64]), st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(b, s, heads, d, seed):
    h, hkv = heads
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    # attention output is a convex combination of values
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-3


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk,hg", [
    (2, 128, 8, 16, 32, 32, 4),
    (1, 256, 16, 32, 64, 64, 8),
    (2, 256, 8, 64, 128, 128, 8),   # mamba2-1.3b-like ratios
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_shapes_dtypes(b, s, h, p, n, chunk, hg, dtype):
    x = _t(b, s, h, p, dtype=dtype)
    dta = -jnp.abs(_t(b, s, h, dtype=jnp.float32)) * 0.1
    B, C = _t(b, s, n, dtype=dtype), _t(b, s, n, dtype=dtype)
    y = ssd_scan(x, dta, B, C, chunk=chunk, head_group=hg, interpret=True)
    yr, _ = ssd_chunked_ref(x, dta, B, C, chunk)
    scale = float(jnp.max(jnp.abs(yr))) + 1e-6
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32) - yr.astype(jnp.float32)))) / scale
    assert err < (3e-2 if dtype == jnp.bfloat16 else 1e-5), err


def test_ssd_matches_sequential_recurrence():
    """The chunked algorithm equals the naive per-step recurrence."""
    b, s, h, p, n = 1, 64, 2, 8, 16
    x = _t(b, s, h, p)
    dta = -jnp.abs(_t(b, s, h)) * 0.2
    B, C = _t(b, s, n), _t(b, s, n)
    y, final = ssd_chunked_ref(x, dta, B, C, 16)
    state = np.zeros((b, h, p, n), np.float32)
    xs = np.asarray(x)
    dts = np.asarray(dta)
    Bs, Cs = np.asarray(B), np.asarray(C)
    y_naive = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        decay = np.exp(dts[:, t])[:, :, None, None]
        upd = np.einsum("bhp,bn->bhpn", xs[:, t], Bs[:, t])
        state = state * decay + upd
        y_naive[:, t] = np.einsum("bhpn,bn->bhp", state, Cs[:, t])
    np.testing.assert_allclose(np.asarray(y), y_naive, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)


def test_ssm_block_decode_matches_train():
    """ssm_block over a sequence == repeated ssm_decode_step."""
    from repro.models import ModelConfig
    from repro.models.ssm import init_ssm_cache, init_ssm_params, ssm_block, ssm_decode_step

    cfg = ModelConfig(family="ssm", d_model=32, ssm_state=8, ssm_head_dim=8,
                      ssm_chunk=4, dtype="float32")
    params = init_ssm_params(cfg, jax.random.PRNGKey(0))
    x = _t(2, 16, 32) * 0.3
    y_train = ssm_block(cfg, params, x)
    cache = init_ssm_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(16):
        o, cache = ssm_decode_step(cfg, params, x[:, t:t + 1], cache)
        outs.append(o[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# sim_scan: fused duration-sampling kernel (repro.simjax hot path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("coeff", [0.35, 0.0, -0.5, 0.9])
@pytest.mark.parametrize("n", [32, 1000])
def test_sim_scan_kernel_matches_ref(coeff, n):
    """Pallas fused AR(1)+mixture == the associative_scan oracle, across
    chunk-aligned and padded lengths and the coeff operating range."""
    from jax.experimental import enable_x64

    from repro.kernels.sim_scan.kernel import sim_durations_scan
    from repro.kernels.sim_scan.ref import sim_durations_ref

    with enable_x64():
        key = jax.random.PRNGKey(coeff is None or int(abs(coeff) * 100))
        ks = jax.random.split(key, 4)
        eps = 0.04 * jax.random.normal(ks[0], (n,), jnp.float64)
        u = [jax.random.uniform(k, (n,), jnp.float64) for k in ks[1:]]
        kw = dict(coeff=coeff, state=0.1, t0=22e-6, tail_prob=0.08,
                  tail_shift=0.35, spike_prob=0.003, spike_scale=8.0)
        t_ref, s_ref = sim_durations_ref(eps, *u, **kw)
        t_ker, s_ker = sim_durations_scan(eps, *u, **kw)
        np.testing.assert_allclose(np.asarray(t_ker), np.asarray(t_ref),
                                   rtol=1e-12, atol=1e-18)
        np.testing.assert_allclose(np.asarray(s_ker), np.asarray(s_ref),
                                   rtol=1e-12, atol=1e-14)


def test_sim_scan_ref_matches_numpy_ar1_filter():
    """The jnp oracle reproduces the numpy engine's _ar1_filter math."""
    from jax.experimental import enable_x64

    from repro.core.mpi_ops import _ar1_filter
    from repro.kernels.sim_scan.ref import sim_durations_ref

    rng = np.random.default_rng(7)
    eps = rng.normal(0.0, 0.04, size=500)
    with enable_x64():
        zeros = jnp.zeros(500, jnp.float64)
        _, s = sim_durations_ref(jnp.asarray(eps), zeros, zeros, zeros,
                                 coeff=0.35, state=0.7, t0=1.0,
                                 tail_prob=0.0, tail_shift=0.0,
                                 spike_prob=0.0, spike_scale=1.0)
    np.testing.assert_allclose(np.asarray(s),
                               _ar1_filter(eps, 0.35, 0.7),
                               rtol=1e-10, atol=1e-14)
