"""Fused (campaign-resident) jax engine: equivalence with the per-epoch
engine, compile-shape bucket contracts, rank-axis sharding, the
`measure_epochs` campaign capability, jit telemetry, and the
once-per-sweep fallback warning.

The fused engine's contract mirrors the batch-engine contract one level
up: duration sampling is *bit-identical* per epoch to the per-epoch jax
engine (same `_cores` sample program under the same fold_in keys), while
the window recurrence — float32 relative-frame arithmetic and a
LUT-quantile imbalance draw — is a different draw of the same process and
must be statistically indistinguishable."""

import os
import warnings

import numpy as np
import pytest

from repro.campaign import (Campaign, CampaignSpec, ResultStore, SimBackend,
                            SweepScheduler, SweepSpec)
from repro.core import (ExperimentDesign, FactorAxis, FactorGrid, TestCase,
                        compare_tables, make_op, make_sync,
                        wilcoxon_rank_sum)

pytest.importorskip("jax")

from repro.simjax import engine_stats, run_windowed_epochs_jax  # noqa: E402
from repro.simjax.engine import _bucket, _chunk_for, run_windowed_jax  # noqa: E402

SYNC_KW = dict(n_fitpts=60, n_exchanges=20)
NOISE_FREE = dict(noise_sigma=0.0, tail_prob=0.0, spike_prob=0.0,
                  rank_imbalance=0.0, epoch_bias_sigma=0.0, autocorr=0.0)


def _epochs(E, p=8, seed0=7, op="allreduce", **op_kw):
    nets, syncs, ops = [], [], []
    for e in range(E):
        from repro.core import SimNet

        net = SimNet(p, seed=seed0 + 1000 * e)
        syncs.append(make_sync("hca", **SYNC_KW).synchronize(net))
        nets.append(net)
        ops.append(make_op(op, **op_kw))
    return nets, syncs, ops


def _sim(**kw):
    kw.setdefault("p", 8)
    kw.setdefault("seed0", 5)
    kw.setdefault("sync_kw", dict(SYNC_KW))
    return SimBackend(**kw)


# ---------------------------------------------------------------------------
# Engine-level equivalence
# ---------------------------------------------------------------------------

def test_fused_matches_per_epoch_engine_statistically():
    """Per epoch: same sampled durations (pinned via the AR(1) carry-out),
    same simulator end state, Wilcoxon-indistinguishable times."""
    E, nrep = 3, 2000
    nets_u, syncs_u, ops_u = _epochs(E, seed0=7)
    nets_f, syncs_f, ops_f = _epochs(E, seed0=7)
    unfused = [run_windowed_jax(nets_u[e], syncs_u[e], ops_u[e], 4096, nrep,
                                400e-6) for e in range(E)]
    fused = run_windowed_epochs_jax(nets_f, syncs_f, ops_f, 4096, nrep,
                                    400e-6)
    for e in range(E):
        # durations bit-identical => identical AR(1) carry-out
        assert ops_u[e]._ar_state == ops_f[e]._ar_state
        res = wilcoxon_rank_sum(unfused[e].valid_times,
                                fused[e].valid_times)
        assert res.p_value > 0.01, (e, res.p_value)
        np.testing.assert_allclose(nets_u[e].t, nets_f[e].t, rtol=1e-5)


def test_fused_exact_when_noise_free():
    """No noise, no imbalance: the fused float32 relative-frame window must
    reproduce the per-epoch engine's f64 times to f32 resolution — this
    isolates the affine-decomposition algebra from the draw change."""
    E, nrep = 2, 128
    nets_u, syncs_u, ops_u = _epochs(E, seed0=11, **NOISE_FREE)
    nets_f, syncs_f, ops_f = _epochs(E, seed0=11, **NOISE_FREE)
    unfused = [run_windowed_jax(nets_u[e], syncs_u[e], ops_u[e], 4096, nrep,
                                400e-6) for e in range(E)]
    fused = run_windowed_epochs_jax(nets_f, syncs_f, ops_f, 4096, nrep,
                                    400e-6)
    for e in range(E):
        np.testing.assert_allclose(fused[e].times, unfused[e].times,
                                   rtol=1e-5)
        assert np.array_equal(fused[e].errors, unfused[e].errors)


def test_fused_strict_on_random_walk_clocks():
    from repro.core import SimNet
    from repro.simjax import SimJaxUnavailable

    net = SimNet(4, seed=3, clocks=None)
    net.clocks[0].rw_sigma = 1e-7
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    with pytest.raises(SimJaxUnavailable):
        run_windowed_epochs_jax([net], [sync], [make_op("bcast")], 256, 10,
                                400e-6)


# ---------------------------------------------------------------------------
# Compile-shape buckets
# ---------------------------------------------------------------------------

def test_bucket_edges():
    assert _bucket(1) == 32 and _bucket(32) == 32        # at the edge
    assert _bucket(33) == 64                             # one past it
    assert _bucket(1023) == 1024 and _bucket(1024) == 1024
    assert _bucket(1025) == 1025                         # exact above 1024
    assert 256 <= _chunk_for(64, 10**5) <= 8192
    assert _chunk_for(64, 100) == 100                    # never above n


def test_bucketing_never_changes_values_within_a_bucket():
    """nrep at vs. past a pow2 edge, same bucket: identical draws, so the
    shorter run is a bitwise prefix of the longer — trace reuse is
    observationally free. (Crossing the edge changes the compiled shape
    and with it JAX's counter layout: a fresh draw of the same process,
    which is exactly what the statistical equivalence tests cover.)"""
    def run(nrep):
        nets, syncs, ops = _epochs(1, seed0=5)
        return run_windowed_jax(nets[0], syncs[0], ops[0], 4096, nrep,
                                400e-6)

    a, b = run(33), run(64)                  # both bucket 64
    assert np.array_equal(a.times, b.times[:33])
    assert np.array_equal(a.errors, b.errors[:33])
    c, d = run(20), run(32)                  # both bucket 32
    assert np.array_equal(c.times, d.times[:20])

    def fused(nrep):
        nets, syncs, ops = _epochs(2, seed0=5)
        return run_windowed_epochs_jax(nets, syncs, ops, 4096, nrep, 400e-6)

    fa, fb = fused(33), fused(64)
    for e in range(2):
        assert np.array_equal(fa[e].times, fb[e].times[:33])


def test_bucket_trace_reuse_and_edge_recompile():
    """Same bucket -> zero new traces; crossing the edge -> new traces.
    Measured through the engine's own telemetry, not inferred."""
    from repro.simjax import reset_engine_stats

    def fused(nrep, seed0):
        nets, syncs, ops = _epochs(2, seed0=seed0)
        return run_windowed_epochs_jax(nets, syncs, ops, 4096, nrep, 400e-6)

    reset_engine_stats()      # count relative to this test only
    fused(40, 21)                            # warm bucket 64
    s0 = engine_stats()
    fused(50, 31)                            # same bucket: reuse only
    s1 = engine_stats()
    assert s1["n_traces"] == s0["n_traces"]
    assert s1["n_dispatches"] > s0["n_dispatches"]
    fused(70, 41)                            # bucket 128: recompile
    s2 = engine_stats()
    assert s2["n_traces"] > s1["n_traces"]


def test_adaptive_topup_across_bucket_is_deterministic():
    """An adaptive campaign whose top-up chunks cross a bucket edge (24 ->
    bucket 32, later chunks -> bucket 64) must stay fully deterministic:
    two identical runs produce byte-identical stores, and the sample-size
    accounting survives the bucket crossings."""
    design = ExperimentDesign(n_launch_epochs=2, nrep_min=24, nrep_max=120,
                              rel_ci_target=1e-6, seed=3)
    cases = [TestCase("allreduce", 512)]

    def run(path):
        store = ResultStore(path)
        res = Campaign(CampaignSpec(cases, design),
                       _sim(engine="jax"), store=store).run()
        return res

    import tempfile
    with tempfile.TemporaryDirectory() as d:
        p1, p2 = os.path.join(d, "a.jsonl"), os.path.join(d, "b.jsonl")
        r1, r2 = run(p1), run(p2)
        assert open(p1, "rb").read() == open(p2, "rb").read()
    for r in r1.records:
        assert r.meta["nrep_used"] == r.times.size == 120
        assert r.meta["converged"] is False and "rel_ci" in r.meta


# ---------------------------------------------------------------------------
# Rank-axis sharding
# ---------------------------------------------------------------------------

@pytest.mark.jaxdevices(4)
def test_sharded_fused_bitwise_matches_unsharded(monkeypatch):
    """Under 4 forced host devices the (p,) inputs are placed with a
    rank-axis NamedSharding; all cross-rank reductions are
    order-independent, so the sharded program must be *bitwise* identical
    to the explicitly-unsharded one."""
    import repro.simjax.engine as eng

    assert eng._rank_sharding(8) is not None     # sharding actually active
    nets_s, syncs_s, ops_s = _epochs(2, seed0=13)
    sharded = run_windowed_epochs_jax(nets_s, syncs_s, ops_s, 4096, 300,
                                      400e-6)
    monkeypatch.setattr(eng, "_rank_sharding", lambda p: None)
    nets_u, syncs_u, ops_u = _epochs(2, seed0=13)
    unsharded = run_windowed_epochs_jax(nets_u, syncs_u, ops_u, 4096, 300,
                                        400e-6)
    for e in range(2):
        assert np.array_equal(sharded[e].times, unsharded[e].times)
        assert np.array_equal(sharded[e].errors, unsharded[e].errors)
        assert np.array_equal(nets_s[e].t, nets_u[e].t)


# ---------------------------------------------------------------------------
# Campaign capability: measure_epochs
# ---------------------------------------------------------------------------

def test_fused_campaign_equivalent_resumable_and_metered():
    """The tentpole, end to end: a fused campaign is compare_tables-
    equivalent to the per-cell-epoch one, resumes byte-compatibly at an
    epoch boundary, shares the unfused campaign's factor fingerprint
    (fuse_epochs is an execution knob, not a factor), and reports its jit
    telemetry in the campaign meta."""
    design = ExperimentDesign(n_launch_epochs=4, nrep=50, seed=5)
    cases = [TestCase("allreduce", 256), TestCase("allreduce", 4096),
             TestCase("bcast", 1024)]
    spec = CampaignSpec(cases, design)
    rf = Campaign(spec, _sim(engine="jax", fuse_epochs=True)).run()
    ru = Campaign(spec, _sim(engine="jax", fuse_epochs=False)).run()

    assert rf.factors.fingerprint() == ru.factors.fingerprint()
    for row in compare_tables(rf.table, ru.table):
        assert row.p_two_sided > 0.01, row
    assert all(r.meta["engine"] == "jax" and r.meta.get("fused")
               for r in rf.records)
    assert not any(r.meta.get("fused") for r in ru.records)
    assert all(r.meta["nrep_used"] == r.times.size == 50 for r in rf.records)

    jit = rf.meta["jit"]
    assert jit["n_dispatches"] > 0 and 0.0 <= jit["cache_hit_rate"] <= 1.0
    assert rf.meta["jit"]["n_dispatches"] < ru.meta["jit"]["n_dispatches"]

    import tempfile
    with tempfile.TemporaryDirectory() as d:
        p1, p2 = os.path.join(d, "a.jsonl"), os.path.join(d, "b.jsonl")
        Campaign(spec, _sim(engine="jax"), store=ResultStore(p1)).run()
        Campaign(spec, _sim(engine="jax"),
                 store=ResultStore(p2)).run(epochs=[0, 1])
        Campaign(spec, _sim(engine="jax"), store=ResultStore(p2)).run()
        assert open(p1, "rb").read() == open(p2, "rb").read()


def test_fused_gating_falls_back_cleanly():
    """measure_epochs declines — and the campaign still runs identically
    through the per-epoch path — for every gate: non-jax engine, fusing
    disabled, shared-cluster epoch isolation."""
    design = ExperimentDesign(n_launch_epochs=2, nrep=10, seed=5)
    spec = CampaignSpec([TestCase("bcast", 256)], design)
    for backend in (_sim(engine="auto"),
                    _sim(engine="jax", fuse_epochs=False),
                    _sim(engine="jax", epoch_isolation="none")):
        assert backend.measure_epochs({0: spec.cases}, design) is None
        res = Campaign(spec, backend).run()
        assert len(res.records) == 2
        assert not any(r.meta.get("fused") for r in res.records)
    # auto resolves to the numpy engine: no jit telemetry in its meta
    assert "jit" not in Campaign(spec, _sim(engine="auto")).run().meta


def test_fused_no_factor_leak():
    """fuse_epochs must not appear anywhere in the factor set: flipping it
    cannot re-key stores, sweeps or audits."""
    design = ExperimentDesign(n_launch_epochs=2, nrep=5)
    a = _sim(engine="jax", fuse_epochs=True).factors(design)
    b = _sim(engine="jax", fuse_epochs=False).factors(design)
    assert a.fingerprint() == b.fingerprint()
    assert "fuse" not in repr(sorted(a.extra))


# ---------------------------------------------------------------------------
# Fallback warning: once per sweep
# ---------------------------------------------------------------------------

def test_engine_fallback_warns_once_per_sweep():
    """engine='jax' on random-walk clocks inside a sweep: the substitution
    RuntimeWarning fires once for the whole sweep (not once per cell), and
    the per-record `engine_fallback` provenance is untouched."""
    grid = FactorGrid((FactorAxis("dtype", ("float32", "float64")),))
    spec = SweepSpec(grid, [TestCase("bcast", 256)],
                     ExperimentDesign(n_launch_epochs=2, nrep=5, seed=1))
    backend = _sim(engine="jax", clock_kw=dict(rw_sigma=1e-7))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = SweepScheduler(spec, backend).run()
    fallback = [w for w in caught if issubclass(w.category, RuntimeWarning)
                and "engine" in str(w.message)]
    assert len(fallback) == 1, [str(w.message) for w in fallback]
    assert len(res.cells) == 2
    # per-record provenance: run one cell campaign directly
    r = Campaign(CampaignSpec(spec.cases, spec.design), backend).run()
    assert all(rec.meta["engine"] == "batch_rw" and
               "engine_fallback" in rec.meta for rec in r.records)


def test_engine_fallback_still_once_per_campaign_outside_sweep():
    backend = _sim(engine="jax", clock_kw=dict(rw_sigma=1e-7))
    spec = CampaignSpec([TestCase("bcast", 256)],
                        ExperimentDesign(n_launch_epochs=3, nrep=5, seed=1))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        Campaign(spec, backend).run()
    fallback = [w for w in caught if issubclass(w.category, RuntimeWarning)
                and "engine" in str(w.message)]
    assert len(fallback) == 1
