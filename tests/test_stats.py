"""Statistics layer: Tukey, Wilcoxon (vs. known values), CIs, ACF, JB."""

import math

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import (
    autocorr_significant_lags,
    bootstrap_ci,
    chi2_sf,
    cliffs_delta,
    holm_bonferroni,
    jarque_bera,
    kruskal_wallis,
    mean_confidence_interval,
    normal_ppf,
    significance_stars,
    t_ppf,
    tost_wilcoxon,
    tukey_filter,
    wilcoxon_rank_sum,
)


def test_tukey_removes_spikes_keeps_bulk():
    rng = np.random.default_rng(0)
    x = rng.normal(100.0, 1.0, 1000)
    x[::100] = 1000.0  # OS-noise spikes
    kept = tukey_filter(x)
    assert kept.max() < 110
    assert kept.size > 900


def test_tukey_small_samples_passthrough():
    x = np.array([1.0, 2.0, 3.0])
    assert np.array_equal(tukey_filter(x), x)


def test_normal_ppf_known_values():
    assert abs(normal_ppf(0.975) - 1.959964) < 1e-5
    assert abs(normal_ppf(0.5)) < 1e-12
    assert abs(normal_ppf(0.025) + 1.959964) < 1e-5


def test_t_ppf_known_values():
    # R: qt(0.975, 10) = 2.228139; qt(0.975, 29) = 2.045230
    assert abs(t_ppf(0.975, 10) - 2.228139) < 5e-3
    assert abs(t_ppf(0.975, 29) - 2.045230) < 2e-3


def test_wilcoxon_known_value():
    # scipy.stats.mannwhitneyu(x, y, alternative='two-sided',
    # method='asymptotic', use_continuity=True) reference
    x = np.array([1.0, 2.0, 3.0, 4.0, 10.0, 12.0, 14.0, 16.0, 18.0, 20.0])
    y = np.array([5.0, 6.0, 7.0, 8.0, 9.0, 11.0, 13.0, 15.0, 17.0, 19.0])
    res = wilcoxon_rank_sum(x, y)
    assert res.statistic == 45.0           # U1
    assert 0.70 < res.p_value < 0.76       # scipy: 0.7337


def test_wilcoxon_direction():
    rng = np.random.default_rng(1)
    a = rng.normal(10, 1, 30)
    b = a + 2.0
    assert wilcoxon_rank_sum(a, b, "less").p_value < 0.001
    assert wilcoxon_rank_sum(a, b, "greater").p_value > 0.99
    assert wilcoxon_rank_sum(a, b, "two-sided").significant


def test_wilcoxon_null_uniform_p():
    """Under H0 the test should reject at ~the nominal rate."""
    rng = np.random.default_rng(2)
    rejections = 0
    trials = 200
    for _ in range(trials):
        a = rng.normal(0, 1, 25)
        b = rng.normal(0, 1, 25)
        if wilcoxon_rank_sum(a, b).p_value <= 0.05:
            rejections += 1
    assert rejections / trials < 0.12


def test_stars():
    assert significance_stars(0.0001) == "***"
    assert significance_stars(0.005) == "**"
    assert significance_stars(0.03) == "*"
    assert significance_stars(0.2) == ""


def test_mean_ci_coverage():
    rng = np.random.default_rng(3)
    hits = 0
    for _ in range(300):
        x = rng.normal(5.0, 2.0, 30)
        m, lo, hi = mean_confidence_interval(x, 0.95)
        hits += lo <= 5.0 <= hi
    assert 0.90 <= hits / 300 <= 0.99


def test_jarque_bera_discriminates():
    rng = np.random.default_rng(4)
    _, p_norm = jarque_bera(rng.normal(0, 1, 500))
    _, p_exp = jarque_bera(rng.exponential(1.0, 500))
    assert p_norm > 0.01
    assert p_exp < 1e-6


def test_autocorrelation_detects_ar1():
    rng = np.random.default_rng(5)
    n = 2000
    x = np.zeros(n)
    for i in range(1, n):
        x[i] = 0.6 * x[i - 1] + rng.normal()
    sig = autocorr_significant_lags(x, max_lag=10)
    assert 1 in sig
    white = rng.normal(0, 1, n)
    assert autocorr_significant_lags(white, max_lag=10).size <= 1


# ---------------------------------------------------------------------------
# property-based tests
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False), min_size=8, max_size=200))
@settings(max_examples=50, deadline=None)
def test_tukey_subset_and_idempotent(xs):
    x = np.array(xs)
    kept = tukey_filter(x)
    assert kept.size <= x.size
    # every kept element is in the original multiset
    assert np.all(np.isin(kept, x))
    # idempotence is NOT generally true for Tukey; but re-filtering never
    # grows the sample
    again = tukey_filter(kept)
    assert again.size <= kept.size


@given(st.integers(5, 40), st.integers(5, 40), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_wilcoxon_symmetry(n1, n2, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, n1)
    b = rng.normal(0.5, 1, n2)
    p_ab = wilcoxon_rank_sum(a, b, "less").p_value
    p_ba = wilcoxon_rank_sum(b, a, "greater").p_value
    assert abs(p_ab - p_ba) < 1e-9
    p2 = wilcoxon_rank_sum(a, b).p_value
    assert 0.0 <= p2 <= 1.0


@given(st.floats(0.01, 0.99))
@settings(max_examples=50, deadline=None)
def test_normal_ppf_inverse(q):
    z = normal_ppf(q)
    # Phi(z) == q
    phi = 0.5 * math.erfc(-z / math.sqrt(2))
    assert abs(phi - q) < 1e-6


def test_chi2_sf_known_critical_values():
    # 5% critical values of chi-square, df = 1..4 (standard tables)
    for df, crit in ((1, 3.841), (2, 5.991), (3, 7.815), (4, 9.488)):
        assert abs(chi2_sf(crit, df) - 0.05) < 5e-4, df
    assert chi2_sf(0.0, 3) == 1.0
    assert chi2_sf(-1.0, 3) == 1.0
    assert chi2_sf(1e4, 2) < 1e-300 or chi2_sf(1e4, 2) == 0.0


def test_kruskal_wallis_known_value():
    # scipy.stats.kruskal reference on a fixed example (with ties)
    a = np.array([1.0, 2.0, 3.0, 4.0])
    b = np.array([2.0, 4.0, 6.0, 8.0])
    c = np.array([5.0, 6.0, 7.0, 8.0])
    h, p = kruskal_wallis([a, b, c])
    assert abs(h - 5.734042553191489) < 1e-9   # scipy 1.x
    assert abs(p - 0.0568680687883) < 1e-9


def test_kruskal_wallis_detects_shift_and_null():
    rng = np.random.default_rng(1)
    base = [rng.lognormal(0, 0.3, 60) for _ in range(3)]
    _, p_null = kruskal_wallis(base)
    assert p_null > 0.01
    shifted = base[:2] + [base[2] * 2.0]
    _, p_shift = kruskal_wallis(shifted)
    assert p_shift < 1e-6
    h, p = kruskal_wallis([np.ones(6), np.ones(7)])   # all tied
    assert h == 0.0 and p == 1.0


def test_cliffs_delta_bounds_and_signs():
    a = np.array([10.0, 11.0, 12.0])
    b = np.array([1.0, 2.0, 3.0])
    assert cliffs_delta(a, b) == 1.0
    assert cliffs_delta(b, a) == -1.0
    assert cliffs_delta(a, a) == 0.0
    # ties count as neither greater nor less: 3 "less" pairs + 1 tie of 4
    assert cliffs_delta(np.array([1.0, 2.0]), np.array([2.0, 3.0])) == -0.75


@given(st.integers(5, 30), st.integers(5, 30), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_cliffs_delta_antisymmetric(n1, n2, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, n1)
    b = rng.normal(0.3, 1, n2)
    d = cliffs_delta(a, b)
    assert -1.0 <= d <= 1.0
    assert abs(d + cliffs_delta(b, a)) < 1e-12


@given(st.integers(2, 25), st.integers(2, 25), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_cliffs_delta_antisymmetric_under_heavy_ties(n1, n2, seed):
    """Antisymmetry where it is actually at risk: integer-valued samples
    with many cross-sample ties (ties count as neither > nor <)."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 5, n1).astype(np.float64)
    b = rng.integers(0, 5, n2).astype(np.float64)
    d = cliffs_delta(a, b)
    assert -1.0 <= d <= 1.0
    assert abs(d + cliffs_delta(b, a)) < 1e-12
    assert cliffs_delta(a, a) == 0.0


@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_holm_dominates_raw_and_is_monotone(ps):
    """Holm adjustment never *reduces* a p-value, stays in [0, 1], and is
    monotone: a smaller raw p never ends up with a larger adjusted p."""
    p = np.array(ps, dtype=np.float64)
    adj = holm_bonferroni(p)
    assert np.all(adj >= p) and np.all(adj <= 1.0)
    order = np.argsort(p, kind="mergesort")
    assert np.all(np.diff(adj[order]) >= 0.0)
    # permutation-equivariant: adjusting a shuffled family shuffles the
    # adjustments the same way
    rng = np.random.default_rng(int(p.size))
    perm = rng.permutation(p.size)
    assert np.array_equal(holm_bonferroni(p[perm]), adj[perm])


@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=30),
       st.floats(0.01, 0.2))
@settings(max_examples=60, deadline=None)
def test_holm_stepdown_idempotent_on_rejected_family(ps, alpha):
    """The step-down procedure's self-consistency (its 'idempotence'):
    re-running Holm on just the rejected subfamily rejects everything
    again — a decision, once made, survives removal of the accepted
    hypotheses. (The adjusted *values* shrink, since the subfamily is
    smaller; the decisions cannot flip.)"""
    p = np.array(ps, dtype=np.float64)
    adj = holm_bonferroni(p)
    rejected = p[adj <= alpha]
    if rejected.size:
        assert np.all(holm_bonferroni(rejected) <= alpha)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_kruskal_wallis_permutation_invariant(seed):
    """(H, p) depend only on the group *memberships*: shuffling the
    observations within groups and re-ordering the groups changes
    nothing — including with heavy ties."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 5))
    groups = [rng.integers(0, 6, int(rng.integers(5, 15))).astype(np.float64)
              for _ in range(k)]
    h0, p0 = kruskal_wallis(groups)
    shuffled = [rng.permutation(g) for g in groups]
    reordered = [shuffled[i] for i in rng.permutation(k)]
    h1, p1 = kruskal_wallis(reordered)
    assert abs(h0 - h1) < 1e-9
    assert abs(p0 - p1) < 1e-9


def _tost_reference(a, b, margin):
    """Scalar-loop reference for tost_wilcoxon: explicit O(n^2) pair
    counting for U, the tie-corrected normal approximation written out
    directly, and the exact complete-separation floor."""
    from collections import Counter

    def one_sided_p(x, y, alternative):
        n1, n2 = len(x), len(y)
        u1 = sum(1.0 for xi in x for yj in y if xi > yj) \
            + 0.5 * sum(1.0 for xi in x for yj in y if xi == yj)
        counts = Counter(list(x) + list(y))
        tie_term = sum(t**3 - t for t in counts.values())
        n = n1 + n2
        mu = n1 * n2 / 2.0
        sigma = math.sqrt(max(
            n1 * n2 / 12.0 * ((n + 1) - tie_term / (n * (n - 1))), 1e-300))
        if alternative == "greater":
            z = (u1 - mu - 0.5) / sigma
            p = 0.5 * math.erfc(z / math.sqrt(2.0))
        else:
            z = (u1 - mu + 0.5) / sigma
            p = 0.5 * math.erfc(-z / math.sqrt(2.0))
        return max(p, 1.0 / math.comb(n, n1))

    lo = one_sided_p(list(a), list((1.0 - margin) * np.asarray(b)), "greater")
    hi = one_sided_p(list(a), list((1.0 + margin) * np.asarray(b)), "less")
    return max(lo, hi)


@given(st.integers(2, 25), st.integers(2, 25), st.integers(0, 2**31 - 1),
       st.floats(0.02, 0.5))
@settings(max_examples=40, deadline=None)
def test_tost_agrees_with_scalar_reference(n1, n2, seed, margin):
    rng = np.random.default_rng(seed)
    a = rng.lognormal(0.0, 0.2, n1)
    b = rng.lognormal(rng.normal(0.0, 0.1), 0.2, n2)
    res = tost_wilcoxon(a, b, margin)
    assert abs(res.p_value - _tost_reference(a, b, margin)) < 1e-9
    assert res.p_value == max(res.p_lower, res.p_upper)
    assert 0.0 < res.p_value <= 1.0


@given(st.integers(5, 20), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_bootstrap_ci_contains_point_estimate_and_orders(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.lognormal(0, 0.3, n)
    lo, hi = bootstrap_ci(lambda s: float(np.median(s)), (x,),
                          n_boot=300, seed=seed)
    assert lo <= hi
    assert x.min() <= lo and hi <= x.max()
