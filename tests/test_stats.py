"""Statistics layer: Tukey, Wilcoxon (vs. known values), CIs, ACF, JB."""

import math

import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import (
    autocorr_significant_lags,
    chi2_sf,
    cliffs_delta,
    jarque_bera,
    kruskal_wallis,
    mean_confidence_interval,
    normal_ppf,
    significance_stars,
    t_ppf,
    tukey_filter,
    wilcoxon_rank_sum,
)


def test_tukey_removes_spikes_keeps_bulk():
    rng = np.random.default_rng(0)
    x = rng.normal(100.0, 1.0, 1000)
    x[::100] = 1000.0  # OS-noise spikes
    kept = tukey_filter(x)
    assert kept.max() < 110
    assert kept.size > 900


def test_tukey_small_samples_passthrough():
    x = np.array([1.0, 2.0, 3.0])
    assert np.array_equal(tukey_filter(x), x)


def test_normal_ppf_known_values():
    assert abs(normal_ppf(0.975) - 1.959964) < 1e-5
    assert abs(normal_ppf(0.5)) < 1e-12
    assert abs(normal_ppf(0.025) + 1.959964) < 1e-5


def test_t_ppf_known_values():
    # R: qt(0.975, 10) = 2.228139; qt(0.975, 29) = 2.045230
    assert abs(t_ppf(0.975, 10) - 2.228139) < 5e-3
    assert abs(t_ppf(0.975, 29) - 2.045230) < 2e-3


def test_wilcoxon_known_value():
    # scipy.stats.mannwhitneyu(x, y, alternative='two-sided',
    # method='asymptotic', use_continuity=True) reference
    x = np.array([1.0, 2.0, 3.0, 4.0, 10.0, 12.0, 14.0, 16.0, 18.0, 20.0])
    y = np.array([5.0, 6.0, 7.0, 8.0, 9.0, 11.0, 13.0, 15.0, 17.0, 19.0])
    res = wilcoxon_rank_sum(x, y)
    assert res.statistic == 45.0           # U1
    assert 0.70 < res.p_value < 0.76       # scipy: 0.7337


def test_wilcoxon_direction():
    rng = np.random.default_rng(1)
    a = rng.normal(10, 1, 30)
    b = a + 2.0
    assert wilcoxon_rank_sum(a, b, "less").p_value < 0.001
    assert wilcoxon_rank_sum(a, b, "greater").p_value > 0.99
    assert wilcoxon_rank_sum(a, b, "two-sided").significant


def test_wilcoxon_null_uniform_p():
    """Under H0 the test should reject at ~the nominal rate."""
    rng = np.random.default_rng(2)
    rejections = 0
    trials = 200
    for _ in range(trials):
        a = rng.normal(0, 1, 25)
        b = rng.normal(0, 1, 25)
        if wilcoxon_rank_sum(a, b).p_value <= 0.05:
            rejections += 1
    assert rejections / trials < 0.12


def test_stars():
    assert significance_stars(0.0001) == "***"
    assert significance_stars(0.005) == "**"
    assert significance_stars(0.03) == "*"
    assert significance_stars(0.2) == ""


def test_mean_ci_coverage():
    rng = np.random.default_rng(3)
    hits = 0
    for _ in range(300):
        x = rng.normal(5.0, 2.0, 30)
        m, lo, hi = mean_confidence_interval(x, 0.95)
        hits += lo <= 5.0 <= hi
    assert 0.90 <= hits / 300 <= 0.99


def test_jarque_bera_discriminates():
    rng = np.random.default_rng(4)
    _, p_norm = jarque_bera(rng.normal(0, 1, 500))
    _, p_exp = jarque_bera(rng.exponential(1.0, 500))
    assert p_norm > 0.01
    assert p_exp < 1e-6


def test_autocorrelation_detects_ar1():
    rng = np.random.default_rng(5)
    n = 2000
    x = np.zeros(n)
    for i in range(1, n):
        x[i] = 0.6 * x[i - 1] + rng.normal()
    sig = autocorr_significant_lags(x, max_lag=10)
    assert 1 in sig
    white = rng.normal(0, 1, n)
    assert autocorr_significant_lags(white, max_lag=10).size <= 1


# ---------------------------------------------------------------------------
# property-based tests
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(min_value=-1e6, max_value=1e6,
                          allow_nan=False), min_size=8, max_size=200))
@settings(max_examples=50, deadline=None)
def test_tukey_subset_and_idempotent(xs):
    x = np.array(xs)
    kept = tukey_filter(x)
    assert kept.size <= x.size
    # every kept element is in the original multiset
    assert np.all(np.isin(kept, x))
    # idempotence is NOT generally true for Tukey; but re-filtering never
    # grows the sample
    again = tukey_filter(kept)
    assert again.size <= kept.size


@given(st.integers(5, 40), st.integers(5, 40), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_wilcoxon_symmetry(n1, n2, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, n1)
    b = rng.normal(0.5, 1, n2)
    p_ab = wilcoxon_rank_sum(a, b, "less").p_value
    p_ba = wilcoxon_rank_sum(b, a, "greater").p_value
    assert abs(p_ab - p_ba) < 1e-9
    p2 = wilcoxon_rank_sum(a, b).p_value
    assert 0.0 <= p2 <= 1.0


@given(st.floats(0.01, 0.99))
@settings(max_examples=50, deadline=None)
def test_normal_ppf_inverse(q):
    z = normal_ppf(q)
    # Phi(z) == q
    phi = 0.5 * math.erfc(-z / math.sqrt(2))
    assert abs(phi - q) < 1e-6


def test_chi2_sf_known_critical_values():
    # 5% critical values of chi-square, df = 1..4 (standard tables)
    for df, crit in ((1, 3.841), (2, 5.991), (3, 7.815), (4, 9.488)):
        assert abs(chi2_sf(crit, df) - 0.05) < 5e-4, df
    assert chi2_sf(0.0, 3) == 1.0
    assert chi2_sf(-1.0, 3) == 1.0
    assert chi2_sf(1e4, 2) < 1e-300 or chi2_sf(1e4, 2) == 0.0


def test_kruskal_wallis_known_value():
    # scipy.stats.kruskal reference on a fixed example (with ties)
    a = np.array([1.0, 2.0, 3.0, 4.0])
    b = np.array([2.0, 4.0, 6.0, 8.0])
    c = np.array([5.0, 6.0, 7.0, 8.0])
    h, p = kruskal_wallis([a, b, c])
    assert abs(h - 5.734042553191489) < 1e-9   # scipy 1.x
    assert abs(p - 0.0568680687883) < 1e-9


def test_kruskal_wallis_detects_shift_and_null():
    rng = np.random.default_rng(1)
    base = [rng.lognormal(0, 0.3, 60) for _ in range(3)]
    _, p_null = kruskal_wallis(base)
    assert p_null > 0.01
    shifted = base[:2] + [base[2] * 2.0]
    _, p_shift = kruskal_wallis(shifted)
    assert p_shift < 1e-6
    h, p = kruskal_wallis([np.ones(6), np.ones(7)])   # all tied
    assert h == 0.0 and p == 1.0


def test_cliffs_delta_bounds_and_signs():
    a = np.array([10.0, 11.0, 12.0])
    b = np.array([1.0, 2.0, 3.0])
    assert cliffs_delta(a, b) == 1.0
    assert cliffs_delta(b, a) == -1.0
    assert cliffs_delta(a, a) == 0.0
    # ties count as neither greater nor less: 3 "less" pairs + 1 tie of 4
    assert cliffs_delta(np.array([1.0, 2.0]), np.array([2.0, 3.0])) == -0.75


@given(st.integers(5, 30), st.integers(5, 30), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_cliffs_delta_antisymmetric(n1, n2, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, n1)
    b = rng.normal(0.3, 1, n2)
    d = cliffs_delta(a, b)
    assert -1.0 <= d <= 1.0
    assert abs(d + cliffs_delta(b, a)) < 1e-12
