"""Statistical-soundness meta-tests: the verdict procedures audited.

This repo's outputs are not numbers but *verdicts* — VIOLATED from the
guideline verifier, DRIFTED/EQUIVALENT from the reproducibility audit —
and each verdict procedure advertises an error-rate contract (family-wise
false-positive rate ≤ α, drift power at practical effect sizes). This
tier validates those contracts *empirically*: hundreds of simulated
null-hypothesis campaigns are pushed through the exact production verdict
code paths (:func:`~repro.guidelines.verdicts_from_table`,
:func:`~repro.history.audit_tables` — no re-derivation), and the observed
error rates are pinned against the advertised bounds.

Everything is seeded, so the observed counts are deterministic — the
tests are regression pins on the procedures' operating characteristics,
not flaky statistical coin-flips. Marked ``slow``: hundreds of trials
belong in the nightly tier, not the PR fast tier.
"""

import numpy as np
import pytest

from repro.core import (EpochSummary, ResultTable, TestCase, bootstrap_ci,
                        tost_wilcoxon)
from repro.guidelines import Guideline, verdicts_from_table
from repro.history import audit_tables

pytestmark = pytest.mark.slow

ALPHA = 0.05
N_EPOCHS = 10                     # launch epochs per side, paper-plausible
MARGIN = 0.10

#: A 5-guideline x 2-msize family of synthetic op names — same family
#: size as the stock SIM_GUIDELINES verification.
GUIDELINES = tuple(Guideline(f"g{i}", lhs=f"lhs{i}", rhs=f"rhs{i}")
                   for i in range(5))
MSIZES = (1024, 8192)

#: The audit campaign's cell family (3 ops x 2 msizes, as the CLI runs it).
AUDIT_CELLS = tuple((op, m) for op in ("allreduce", "bcast", "alltoall")
                    for m in (512, 4096))


def _table(cells: dict) -> ResultTable:
    """A ResultTable of per-epoch medians — a simulated campaign outcome
    without the campaign."""
    return ResultTable([
        EpochSummary(case=TestCase(op, m), epoch=e, mean=float(v),
                     median=float(v), n_kept=1, n_raw=1)
        for (op, m), values in cells.items() for e, v in enumerate(values)
    ])


def _null_medians(rng, sigma=0.04):
    return rng.lognormal(-10, sigma, N_EPOCHS)


# ---------------------------------------------------------------------------
# Guideline verifier: family-wise false-violation rate
# ---------------------------------------------------------------------------

def test_guideline_false_violation_rate_bounded_by_alpha():
    """400 null campaigns (lhs and rhs drawn from the same distribution):
    the fraction of *reports* containing any VIOLATED cell must stay
    within the advertised family-wise α — the Holm correction doing its
    job across the 10-cell family."""
    rng = np.random.default_rng(101)
    n_trials, false_reports = 400, 0
    for _ in range(n_trials):
        cells = {}
        for g in GUIDELINES:
            for m in MSIZES:
                cells[(g.lhs, m)] = _null_medians(rng, sigma=0.1)
                cells[(g.rhs, m)] = _null_medians(rng, sigma=0.1)
        verdicts = verdicts_from_table(GUIDELINES, _table(cells),
                                       msizes=MSIZES, alpha=ALPHA)
        false_reports += any(v.violated for v in verdicts)
    assert false_reports / n_trials <= ALPHA     # observed (seeded): 0.025


def test_guideline_verifier_flags_real_violation_with_power():
    """The companion power check: one guideline whose lhs is genuinely
    30% slower must be VIOLATED in >= 80% of campaigns."""
    rng = np.random.default_rng(111)
    n_trials, hits = 250, 0
    for _ in range(n_trials):
        cells = {}
        for g in GUIDELINES:
            for m in MSIZES:
                cells[(g.lhs, m)] = _null_medians(rng, sigma=0.1)
                cells[(g.rhs, m)] = _null_medians(rng, sigma=0.1)
        cells[(GUIDELINES[0].lhs, MSIZES[0])] = \
            cells[(GUIDELINES[0].rhs, MSIZES[0])] * 1.3 \
            * rng.lognormal(0, 0.02, N_EPOCHS)
        verdicts = verdicts_from_table(GUIDELINES, _table(cells),
                                       msizes=MSIZES, alpha=ALPHA)
        hits += any(v.violated and v.guideline.name == "g0"
                    and v.msize == MSIZES[0] for v in verdicts)
    assert hits / n_trials >= 0.8                # observed (seeded): 1.0


# ---------------------------------------------------------------------------
# Reproducibility audit: false-DRIFTED, false-EQUIVALENT, drift power
# ---------------------------------------------------------------------------

def test_audit_false_drift_rate_bounded_by_alpha():
    """250 null audit pairs (reference and candidate from the same
    distribution): reports containing any DRIFTED cell must be <= α —
    and a faithful reproduction should actually *certify*, so the
    all-EQUIVALENT rate is pinned high as well."""
    rng = np.random.default_rng(202)
    n_trials, false_drift, certified = 250, 0, 0
    for i in range(n_trials):
        ref = _table({k: _null_medians(rng) for k in AUDIT_CELLS})
        cand = _table({k: _null_medians(rng) for k in AUDIT_CELLS})
        report = audit_tables(ref, cand, margin=MARGIN, alpha=ALPHA,
                              n_boot=50, seed=i)
        false_drift += not report.ok
        certified += report.all_equivalent
    assert false_drift / n_trials <= ALPHA       # observed (seeded): 0.004
    assert certified / n_trials >= 0.9           # observed (seeded): 1.0


def test_audit_false_equivalent_rate_bounded_at_margin_boundary():
    """TOST's own type-I error: when the true ratio sits exactly on the
    equivalence margin (the hardest non-equivalent truth), certifying
    EQUIVALENT anywhere in the family must stay <= α."""
    rng = np.random.default_rng(404)
    n_trials, false_eq = 250, 0
    for i in range(n_trials):
        ref = _table({k: _null_medians(rng) for k in AUDIT_CELLS})
        cand = _table({k: _null_medians(rng) * (1.0 + MARGIN)
                       for k in AUDIT_CELLS})
        report = audit_tables(ref, cand, margin=MARGIN, alpha=ALPHA,
                              n_boot=50, seed=i)
        false_eq += any(c.verdict == "EQUIVALENT" for c in report.cells)
    assert false_eq / n_trials <= ALPHA          # observed (seeded): 0.028


def test_audit_flags_seeded_drift_with_power():
    """The acceptance criterion: a single cell drifted by 3x the margin
    must be flagged DRIFTED with power >= 0.8 (observed: ~1.0), without
    dragging its innocent sibling cells along."""
    rng = np.random.default_rng(303)
    n_trials, hits, innocents_flagged = 250, 0, 0
    for i in range(n_trials):
        ref = _table({k: _null_medians(rng) for k in AUDIT_CELLS})
        cand_cells = {k: _null_medians(rng) for k in AUDIT_CELLS}
        cand_cells[("bcast", 512)] = cand_cells[("bcast", 512)] \
            * (1.0 + 3 * MARGIN)
        report = audit_tables(ref, _table(cand_cells), margin=MARGIN,
                              alpha=ALPHA, n_boot=50, seed=i)
        hits += any(c.op == "bcast" and c.msize == 512
                    and c.verdict == "DRIFTED" for c in report.cells)
        innocents_flagged += any(
            c.verdict == "DRIFTED" for c in report.cells
            if not (c.op == "bcast" and c.msize == 512))
    assert hits / n_trials >= 0.8
    assert innocents_flagged / n_trials <= ALPHA


# ---------------------------------------------------------------------------
# Primitive operating characteristics
# ---------------------------------------------------------------------------

def test_bootstrap_ci_covers_true_median_ratio():
    """Percentile-bootstrap coverage of the median ratio at nominal 95%:
    accepted within [0.85, 0.995] — the percentile method undercovers
    slightly at n=20, which is why the CI is reported as an effect-size
    aid and the verdicts rest on the rank tests."""
    rng = np.random.default_rng(505)
    true_ratio = 1.2
    n_trials, covered = 200, 0
    for i in range(n_trials):
        ref = rng.lognormal(-10, 0.1, 20)
        cand = rng.lognormal(-10 + np.log(true_ratio), 0.1, 20)
        lo, hi = bootstrap_ci(
            lambda c, r: float(np.median(c) / np.median(r)), (cand, ref),
            n_boot=200, level=0.95, seed=i)
        covered += lo <= true_ratio <= hi
    assert 0.85 <= covered / n_trials <= 0.995


def test_tost_type_one_error_at_exact_boundary():
    """The scalar TOST primitive itself, off the audit scaffolding: at a
    true ratio of exactly 1 + margin, P(p <= α) must not exceed α."""
    rng = np.random.default_rng(606)
    n_trials, rejections = 400, 0
    for _ in range(n_trials):
        b = rng.lognormal(0, 0.05, N_EPOCHS)
        a = rng.lognormal(np.log(1.0 + MARGIN), 0.05, N_EPOCHS)
        rejections += tost_wilcoxon(a, b, margin=MARGIN).p_value <= ALPHA
    assert rejections / n_trials <= ALPHA


# ---------------------------------------------------------------------------
# Budgeted allocation: false-retire / false-survive operating characteristics
# ---------------------------------------------------------------------------

def _race(seed, effect=0.0, sigma=0.05, n_axes=2, n_epochs_max=8,
          policy=None):
    """One full racing allocation over a synthetic 2^n grid, through the
    production decision path (RacingPolicy.plan_round/decide ->
    axis_decisions). ``effect`` is an additive shift on axis ``a0``'s
    second level; the other axes are truly null. Returns the decided map."""
    from repro.sweeps import AllocState, CellData, RacingPolicy

    pol = policy or RacingPolicy(n_min_null=6)
    levels = ("x", "y")
    n_cells = 2 ** n_axes
    axes = [dict(name=f"a{i}", labels=list(levels)) for i in range(n_axes)]
    cell_levels = {c: {f"a{i}": levels[(c >> i) & 1] for i in range(n_axes)}
                   for c in range(n_cells)}
    measured = {c: {} for c in range(n_cells)}

    def state(decided, rnd, spent):
        cells = []
        for c in range(n_cells):
            if not measured[c]:
                continue
            vals = np.array([measured[c][e] for e in sorted(measured[c])])
            cells.append(CellData(index=c, levels=dict(cell_levels[c]),
                                  medians={("op", 1): vals}))
        return AllocState(axes=axes, cell_levels=cell_levels, cells=cells,
                          decided=dict(decided), round=rnd, spent_nrep=spent,
                          n_epochs_max=n_epochs_max)

    decided, rnd, spent = {}, 0, 0
    while True:
        plan = pol.plan_round(state(decided, rnd, spent))
        if plan is None:
            break
        for c in plan.cells:
            shift = effect if cell_levels[c]["a0"] == levels[1] else 0.0
            for e in range(*plan.epochs):
                rng = np.random.default_rng([seed, c, e])
                measured[c][e] = 1.0 + shift + float(rng.normal(0, sigma))
        spent += plan.n_cell_epochs() * 10
        rnd += 1
        for axis, d in pol.decide(state(decided, rnd, spent)).items():
            if d.resolved and axis not in decided:
                decided[axis] = d.verdict
    return decided


def test_racing_false_matters_rate_bounded_by_alpha():
    """All axes truly null: the alpha-spending + Holm schedule must keep
    the family-wise rate of a spurious MATTERS (a *false survive* that
    burns budget AND misreports the ranking) at or below α across the
    whole multi-look allocation."""
    n_trials = 200
    false_matters = sum(
        "MATTERS" in _race(seed=1000 + t, effect=0.0).values()
        for t in range(n_trials))
    assert false_matters / n_trials <= ALPHA


def test_racing_retires_true_nulls_instead_of_spending():
    """The flip side of the futility rule: truly-null axes should
    overwhelmingly end retired as null, not limp along undecided to the
    epoch cap — that is where the budget saving comes from."""
    n_trials = 100
    retired = sum(
        list(_race(seed=3000 + t, effect=0.0).values()).count("null")
        for t in range(n_trials))
    assert retired / (2 * n_trials) >= 0.8


def test_racing_power_and_false_retire_rate_on_strong_effect():
    """A strong real effect on a0 (far above delta_null's futility bar):
    the race must call it MATTERS with power >= 0.8, and the rate of
    *false retire* (a0 ending 'null' — the error that would silently drop
    a real factor from the paper's ranking) must stay <= α."""
    n_trials = 100
    decisions = [_race(seed=2000 + t, effect=0.5) for t in range(n_trials)]
    matters = sum(d.get("a0") == "MATTERS" for d in decisions)
    false_retire = sum(d.get("a0") == "null" for d in decisions)
    assert matters / n_trials >= 0.8
    assert false_retire / n_trials <= ALPHA
