"""Per-architecture smoke tests: one forward/train step on a REDUCED config
of the same family, asserting output shapes and finiteness; plus decode
consistency (prefill == repeated decode) on a small dense model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config, get_smoke
from repro.models import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    num_params,
)
from repro.launch.steps import make_train_step
from repro.optim import OptimizerConfig, init_opt_state

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["embeds"] = 0.01 * jnp.ones((B, 8, cfg.d_model), cfg.jdtype)
    if cfg.frontend == "audio":
        params = init_params(cfg, key)
        batch["memory"] = encode(cfg, params,
                                 0.01 * jnp.ones((B, 16, cfg.d_model)))
    return batch


# The heaviest smoke configs (many layers / wide MoE => slow CPU jit) are
# marked slow and skipped in the default tier-1 run (see pytest.ini).
_SLOW_ARCHS = {"zamba2-7b", "deepseek-v2-236b", "mixtral-8x22b"}
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
    for a in ARCHS
]


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    assert num_params(params) > 0
    batch = _batch(cfg, key)

    logits, aux = forward(cfg, params, batch["tokens"],
                          embeds=batch.get("embeds"),
                          memory=batch.get("memory"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3)))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    cache = init_cache(cfg, B, S + 4)
    tok = batch["tokens"][:, :1]
    for _ in range(3):
        logits, cache = decode_step(cfg, params, cache, tok,
                                    memory=batch.get("memory"))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, axis=-1)
    assert int(cache["pos"]) == 3


def test_full_configs_match_assigned_table():
    """The exact assigned hyperparameters."""
    c = get_config("gemma3-27b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (62, 5376, 32, 16, 21504, 262144)
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_experts, c.moe_top_k,
            c.kv_lora_rank, c.n_shared_experts) == (60, 5120, 128, 160, 6, 512, 2)
    c = get_config("mixtral-8x22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.n_experts,
            c.moe_top_k, c.vocab_size) == (56, 6144, 48, 8, 8, 2, 32768)
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab_size) == (81, 3584, 64, 32000)
    c = get_config("mamba2-1.3b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 2048, 128)
    c = get_config("granite-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (52, 6144, 48, 1)
    c = get_config("gemma-2b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.head_dim) == (18, 2048, 1, 256)
    c = get_config("gemma2-2b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.d_ff) == (26, 2304, 4, 9216)
    c = get_config("pixtral-12b")
    assert (c.n_layers, c.d_model, c.n_kv_heads, c.vocab_size) == (40, 5120, 8, 131072)
    c = get_config("seamless-m4t-medium")
    assert (c.n_layers, c.n_encoder_layers, c.d_model, c.vocab_size) == (12, 12, 1024, 256206)


def test_param_counts_in_expected_range():
    """Analytic parameter counts land near the advertised sizes."""
    expect = {
        "gemma-2b": (2.0e9, 3.3e9),
        "gemma2-2b": (2.0e9, 3.6e9),
        "gemma3-27b": (24e9, 31e9),
        "granite-20b": (18e9, 23e9),
        "mixtral-8x22b": (120e9, 150e9),
        "deepseek-v2-236b": (210e9, 260e9),
        "pixtral-12b": (11e9, 14e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "zamba2-7b": (6e9, 9e9),
        "seamless-m4t-medium": (0.5e9, 1.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_long_500k_only_for_subquadratic():
    for arch in ARCHS:
        shapes = applicable_shapes(get_config(arch))
        if arch in ("mamba2-1.3b", "zamba2-7b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


def test_prefill_decode_consistency():
    """prefill(tokens) produces the same logits trajectory as repeated
    single-token decode (same cache math)."""
    from repro.models import prefill

    cfg = get_smoke("gemma2-2b")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    logits_pf, cache_pf = prefill(cfg, params, toks, max_len=12)

    cache = init_cache(cfg, 1, 12)
    outs = []
    for i in range(8):
        lg, cache = decode_step(cfg, params, cache, toks[:, i:i + 1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_train_step_reduces_loss():
    """A few steps of AdamW reduce loss on a fixed batch (end-to-end
    gradient correctness)."""
    cfg = get_smoke("granite-20b")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    state = {"params": params, "opt": init_opt_state(params)}
    step = jax.jit(make_train_step(
        cfg, OptimizerConfig(lr=3e-3, warmup_steps=1, weight_decay=0.0)))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
