"""Guideline-verification subsystem: the op-expression grammar, composite
mock-up execution on the backends, Holm correction, and the PGMPI verdict
engine — proven in both directions (an honest library passes, a seeded
mis-tuned collective is flagged) plus store resume of a killed
verification campaign."""

import numpy as np
import pytest

from repro.campaign import KernelBackend, ResultStore, SimBackend
from repro.core import (ExperimentDesign, SimNet, TestCase, compare_cases,
                        compare_tables, holm_bonferroni, is_composite,
                        make_composite_op, parse_opexpr)
from repro.core.design import analyze_records
from repro.guidelines import (SIM_GUIDELINES, Guideline, compile_cases,
                              format_report, format_violations,
                              verify_guidelines)

FAST_SYNC = dict(n_fitpts=100, n_exchanges=20)


def _sim(seed0=0, p=8, **kw):
    kw.setdefault("sync_kw", dict(FAST_SYNC))
    return SimBackend(p=p, seed0=seed0, **kw)


def _design(**kw):
    base = dict(n_launch_epochs=8, nrep=25, seed=5)
    base.update(kw)
    return ExperimentDesign(**base)


# ---------------------------------------------------------------------------
# Op-expression grammar
# ---------------------------------------------------------------------------

def test_parse_opexpr_terms_and_modifiers():
    t, = parse_opexpr("allreduce")
    assert (t.op, t.msize_scale, t.procs, t.impl) == ("allreduce", 1.0,
                                                      "all", None)
    terms = parse_opexpr("scatter + allgather*0.5")
    assert [x.op for x in terms] == ["scatter", "allgather"]
    assert terms[1].msize(1000) == 500
    t, = parse_opexpr("allreduce@half#ref")
    assert t.procs == "half" and t.impl == "ref"
    assert not is_composite("allreduce")
    for expr in ("allreduce*2", "a+b", "allreduce@half", "x#ref"):
        assert is_composite(expr), expr


def test_parse_opexpr_rejects_garbage():
    for bad in ("", "a-b", "a*", "a*0", "a@quarter", "1op", "a+"):
        with pytest.raises(ValueError):
            parse_opexpr(bad)


# ---------------------------------------------------------------------------
# Composite mock-up execution (SimBackend)
# ---------------------------------------------------------------------------

def test_composite_sim_op_sums_constituent_durations():
    net = SimNet(4, seed=7)
    comp = make_composite_op("reduce+bcast")
    lone = make_composite_op("reduce")
    d_comp = comp.sample_durations(net, 4, 4096, 200)
    net2 = SimNet(4, seed=7)
    d_lone = lone.sample_durations(net2, 4, 4096, 200)
    assert d_comp.mean() > d_lone.mean()
    # base_time is exactly additive (the stochastic parts are not)
    assert comp.base_time(4, 4096) == pytest.approx(
        make_composite_op("reduce").base_time(4, 4096)
        + make_composite_op("bcast").base_time(4, 4096))


def test_composite_half_term_uses_fewer_processes():
    # at zero message size the cost is pure latency: alpha * ceil(log2 p)
    comp = make_composite_op("allreduce@half+allreduce@half")
    full2 = make_composite_op("allreduce+allreduce")
    assert comp.base_time(8, 0) < full2.base_time(8, 0)


def test_composite_runs_through_windowed_campaign():
    backend = _sim(seed0=3, p=4)
    ctx = backend.make_epoch(0)
    times = backend.measure(ctx, TestCase("scatter+allgather", 2048), 40)
    ref = backend.measure(ctx, TestCase("bcast", 2048), 40)
    assert times.size >= 20 and np.all(times > 0)
    assert times.mean() > ref.mean()     # the mock-up costs more than bcast


def test_sim_rejects_impl_tags_and_per_op_kw_changes_fingerprint():
    backend = _sim(seed0=1)
    with pytest.raises(ValueError, match="implementation tags"):
        backend.make_epoch(0).op("allreduce#ref")
    d = ExperimentDesign(n_launch_epochs=2, nrep=5)
    honest = _sim(seed0=1).factors(d).fingerprint()
    seeded = _sim(seed0=1, per_op_kw={"alltoall": dict(alpha=9e-6)})
    assert seeded.factors(d).fingerprint() != honest


# ---------------------------------------------------------------------------
# Statistics: Holm correction, within-table comparison
# ---------------------------------------------------------------------------

def test_holm_bonferroni_adjustment():
    adj = holm_bonferroni([0.01, 0.04, 0.03, 0.9])
    np.testing.assert_allclose(adj, [0.04, 0.09, 0.09, 0.9])
    assert holm_bonferroni([]).size == 0
    np.testing.assert_allclose(holm_bonferroni([0.5]), [0.5])
    assert np.all(holm_bonferroni([0.4, 0.5, 0.6]) <= 1.0)
    with pytest.raises(ValueError):
        holm_bonferroni([0.1, 1.5])


def test_compare_cases_within_one_table():
    backend = _sim(seed0=13, p=4)
    cases = [TestCase("bcast", 1024), TestCase("alltoall", 1024)]
    from repro.core import run_design

    records = run_design(_design(), backend, cases=cases)
    table = analyze_records(records)
    row = compare_cases(table, cases[0], cases[1])
    assert row.case == cases[0]
    assert row.avg_a < row.avg_b          # bcast is cheaper than alltoall
    assert row.p_a_less <= 0.05
    with pytest.raises(ValueError, match="no data"):
        compare_cases(table, TestCase("nope", 1), cases[1])


def test_compare_tables_raises_without_common_cells():
    backend = _sim(seed0=17, p=4)
    from repro.core import run_design

    ta = analyze_records(run_design(_design(n_launch_epochs=2), backend,
                                    cases=[TestCase("bcast", 256)]))
    tb = analyze_records(run_design(_design(n_launch_epochs=2), backend,
                                    cases=[TestCase("bcast", 512)]))
    with pytest.raises(ValueError, match="no common"):
        compare_tables(ta, tb)


# ---------------------------------------------------------------------------
# Guideline engine
# ---------------------------------------------------------------------------

def test_compile_cases_dedups_shared_sides():
    gls = [
        Guideline("a", lhs="allgather", rhs="alltoall"),
        Guideline("b", lhs="allreduce", rhs="allreduce", rhs_msize_scale=2.0),
        Guideline("c", lhs="allreduce", rhs="reduce+bcast"),
    ]
    cases = compile_cases(gls, msizes=(1024, 2048))
    keys = [c.key() for c in cases]
    assert len(keys) == len(set(keys))
    # monotonicity rhs at 2x1024 coincides with the 2048 lhs cell
    assert ("allreduce", 2048) in keys
    assert sum(1 for k in keys if k == ("allreduce", 2048)) == 1


def test_honest_sim_library_passes_all_guidelines():
    report = verify_guidelines(SIM_GUIDELINES, _sim(seed0=2),
                               design=_design(), msizes=(1024, 8192))
    assert len(report.verdicts) == 10
    assert report.ok and not report.violations()
    # every family holds with positive evidence, not mere non-refutation
    assert all(v.verdict == "holds(<)" for v in report.verdicts)
    text = format_report(report)
    assert "all 10 cells hold" in text
    assert format_violations(report) == ""


def test_seeded_violation_inflated_alltoall_is_flagged():
    """The true-violation direction: a deliberately inflated alltoall
    base_time breaks the mock-up guideline that bounds alltoall from
    above, and only that guideline."""
    gls = list(SIM_GUIDELINES) + [
        # synthetic mock-up upper bound on alltoall (honest models satisfy
        # it comfortably: see the cost presets in repro.core.mpi_ops)
        Guideline("alltoall_mock_bound", lhs="alltoall",
                  rhs="allreduce*2+bcast*2",
                  description="mock-up bound: alltoall ⪯ allreduce(2m)+bcast(2m)"),
    ]
    honest = verify_guidelines(gls, _sim(seed0=4), design=_design(),
                               msizes=(1024,))
    assert honest.ok

    seeded = verify_guidelines(
        gls,
        _sim(seed0=4, per_op_kw={"alltoall": dict(alpha=12e-6, gamma=10e-6)}),
        design=_design(), msizes=(1024,))
    bad = seeded.violations()
    assert [v.guideline.name for v in bad] == ["alltoall_mock_bound"]
    v = bad[0]
    assert v.verdict == "VIOLATED" and v.ratio > 1.0
    assert v.p_violated <= v.p_holm <= 0.05
    assert "alltoall_mock_bound" in format_violations(seeded)


def test_seeded_violation_inflated_allgather_breaks_pattern_containment():
    report = verify_guidelines(
        SIM_GUIDELINES,
        _sim(seed0=6, per_op_kw={"allgather": dict(alpha=9e-6, gamma=8e-6)}),
        design=_design(), msizes=(1024,))
    names = {v.guideline.name for v in report.violations()}
    assert names == {"allgather_pat_alltoall"}


# ---------------------------------------------------------------------------
# Store: resumable verification campaigns
# ---------------------------------------------------------------------------

def test_guideline_campaign_resumes_from_store(tmp_path):
    store = ResultStore(tmp_path / "g.jsonl")
    first = verify_guidelines(SIM_GUIDELINES, _sim(seed0=8),
                              design=_design(), msizes=(1024,), store=store)
    assert first.n_measured > 0 and first.n_resumed == 0
    again = verify_guidelines(SIM_GUIDELINES, _sim(seed0=8),
                              design=_design(), msizes=(1024,), store=store)
    assert again.n_measured == 0
    assert again.n_resumed == first.n_measured
    assert [v.verdict for v in again.verdicts] == \
        [v.verdict for v in first.verdicts]
    for a, b in zip(first.verdicts, again.verdicts):
        assert a.lhs_us == pytest.approx(b.lhs_us)
        assert a.p_violated == pytest.approx(b.p_violated)


def test_killed_guideline_campaign_resumes_missing_cells_only(tmp_path):
    """Simulate a campaign killed mid-write: keep half the record lines
    plus a truncated tail. Resume warns about the torn line, re-measures
    only the missing cells, and still produces the full verdict table."""
    path = tmp_path / "g.jsonl"
    full = verify_guidelines(SIM_GUIDELINES, _sim(seed0=9),
                             design=_design(), msizes=(1024,),
                             store=ResultStore(path))
    lines = path.read_text().splitlines()
    # schema header + declaration + half the records
    n_keep = 2 + (len(lines) - 2) // 2
    killed = tmp_path / "killed.jsonl"
    killed.write_text("\n".join(lines[:n_keep]) + "\n"
                      + '{"kind": "record", "fingerprint": "'[:40])
    with pytest.warns(RuntimeWarning, match="undecodable"):
        resumed = verify_guidelines(SIM_GUIDELINES, _sim(seed0=9),
                                    design=_design(), msizes=(1024,),
                                    store=ResultStore(killed))
    assert resumed.n_resumed == n_keep - 2
    assert resumed.n_resumed + resumed.n_measured == full.n_measured
    assert len(resumed.verdicts) == len(full.verdicts)
    assert resumed.ok


# ---------------------------------------------------------------------------
# Kernel backend: impl tags (pallas vs ref inside one campaign)
# ---------------------------------------------------------------------------

def test_kernel_backend_impl_tags_and_composites():
    backend = KernelBackend(batch=1, heads=2, head_dim=16, interpret=True)
    ctx = backend.make_epoch(0)
    t_ref = backend.measure(ctx, TestCase("flash_attention#ref", 64), 2)
    assert t_ref.size == 2 and np.all(t_ref > 0)
    t_seq = backend.measure(
        ctx, TestCase("flash_attention#ref+flash_attention#ref", 64), 2)
    assert t_seq.size == 2 and np.all(t_seq > 0)
    with pytest.raises(ValueError, match="@half"):
        backend.measure(ctx, TestCase("flash_attention#ref@half", 64), 1)


@pytest.mark.jaxdevices(4)
def test_jax_backend_composite_collective(tmp_path):
    from repro.campaign import JaxBackend

    backend = JaxBackend(n_devices=4)
    ctx = backend.make_epoch(0)
    times = backend.measure(ctx, TestCase("psum+all_gather", 1024), 3)
    assert times.size == 3 and np.all(times > 0)
    half = backend.measure(ctx, TestCase("psum@half", 1024), 3)
    assert half.size == 3 and np.all(half > 0)
    with pytest.raises(ValueError, match="implementation tags"):
        backend.measure(ctx, TestCase("psum#x", 1024), 1)
