"""Budgeted sweep allocation (repro.sweeps.alloc): policy unit behavior,
the determinism/prefix properties the resume machinery leans on, and the
end-to-end contract — a racing sweep must land the same factor verdicts
as the uniform reference at a real nrep saving, serially, on a fleet,
and across a mid-allocation kill/resume.
"""

import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.campaign import ResultStore, SweepScheduler
from repro.fleet import FleetConfig, FleetScheduler
from repro.sweeps import (AllocState, CellData, RacingPolicy, RoundPlan,
                          SuccessiveHalvingPolicy, UniformPolicy,
                          cells_from_result, default_sim_sweep, main_effects,
                          make_policy)

SMOKE_AXES = ("tuning", "dtype")


# ---------------------------------------------------------------------------
# synthetic driver: the scheduler loop without any scheduler
# ---------------------------------------------------------------------------

_LEVELS = ("x", "y")


def _synth_value(seed, cell, epoch, loud):
    """Deterministic per-(cell, epoch) observation; ``loud`` cells carry
    a large injected effect."""
    rng = np.random.default_rng([int(seed), int(cell), int(epoch)])
    return 1.0 + (1.0 if loud else 0.0) + float(rng.normal(0.0, 0.05))


def _drive(policy, seed, n_axes=2, n_epochs_max=8, nrep=10,
           loud_axis=None):
    """Run the allocation loop against synthetic data: returns the list
    of executed RoundPlans and the final decided map. Pure in
    ``(policy, seed, ...)`` — no store, no scheduler, no wall clock."""
    axes = [dict(name=f"a{i}", labels=list(_LEVELS)) for i in range(n_axes)]
    n_cells = 2 ** n_axes
    cell_levels = {
        c: {f"a{i}": _LEVELS[(c >> i) & 1] for i in range(n_axes)}
        for c in range(n_cells)}
    measured = {c: {} for c in range(n_cells)}   # cell -> {epoch: median}

    def _state(decided, rnd, spent):
        cells = []
        for c in range(n_cells):
            if not measured[c]:
                continue
            vals = np.array([measured[c][e] for e in sorted(measured[c])])
            cells.append(CellData(index=c, levels=dict(cell_levels[c]),
                                  medians={("op", 1): vals}))
        return AllocState(axes=[dict(a, labels=list(a["labels"]))
                                for a in axes],
                          cell_levels={k: dict(v)
                                       for k, v in cell_levels.items()},
                          cells=cells, decided=dict(decided), round=rnd,
                          spent_nrep=spent, n_epochs_max=n_epochs_max)

    decided, rnd, spent, plans = {}, 0, 0, []
    while True:
        plan = policy.plan_round(_state(decided, rnd, spent))
        if plan is None:
            break
        plans.append(plan)
        for c in plan.cells:
            for e in range(*plan.epochs):
                loud = (loud_axis is not None
                        and cell_levels[c][loud_axis] == _LEVELS[1])
                measured[c][e] = _synth_value(seed, c, e, loud)
        spent += plan.n_cell_epochs() * nrep
        rnd += 1
        for axis, d in policy.decide(_state(decided, rnd, spent)).items():
            if d.resolved and axis not in decided:
                decided[axis] = d.verdict
    return plans, decided


# ---------------------------------------------------------------------------
# policy unit behavior
# ---------------------------------------------------------------------------

def test_uniform_policy_is_one_full_round():
    plans, decided = _drive(UniformPolicy(), seed=0, loud_axis="a0")
    assert len(plans) == 1
    assert plans[0] == RoundPlan(round=0, epochs=(0, 8),
                                 cells=tuple(range(4)))
    assert decided.get("a0") == "MATTERS"


def test_racing_windows_grow_geometrically_and_pin_decided_axes():
    pol = RacingPolicy(n_min_null=6)
    plans, decided = _drive(pol, seed=0, loud_axis="a0", nrep=10)
    # contiguous geometric windows: cumulative epoch edges 1, 2, 4, 8
    assert [p.epochs for p in plans] == \
        [(0, 1), (1, 2), (2, 4), (4, 8)][:len(plans)]
    assert decided == {"a0": "MATTERS", "a1": "null"}
    # a decided axis is pinned at its reference level in every later round
    shrunk = [p for p in plans if len(p.cells) < 4]
    assert shrunk, "no round ever dropped a cell"
    for p in shrunk:
        for c in p.cells:
            assert c in (0, 1, 2, 3)
        # cells surviving a shrink agree on the pinned axis level
        assert len(p.cells) == 2
    # racing spends strictly less than uniform on the same grid
    spent = sum(p.n_cell_epochs() for p in plans)
    assert spent < 4 * 8


def test_racing_respects_budget_as_stop_criterion():
    nrep = 10
    plans, _ = _drive(RacingPolicy(nrep_budget=4 * nrep), seed=0,
                      loud_axis="a0", nrep=nrep)
    # round 0 costs exactly the budget -> no further rounds are planned
    assert len(plans) == 1


def test_successive_halving_force_retires_weakest_half():
    # no real effect anywhere and a futility bar set out of reach: only
    # the halving rule can retire axes, and it must mark them forced
    pol = SuccessiveHalvingPolicy(n_min_null=10 ** 6)
    axes = [dict(name=f"a{i}", labels=list(_LEVELS)) for i in range(2)]
    cell_levels = {c: {f"a{i}": _LEVELS[(c >> i) & 1] for i in range(2)}
                   for c in range(4)}
    rng = np.random.default_rng(5)
    cells = [CellData(index=c, levels=dict(cell_levels[c]),
                      medians={("op", 1): 1 + rng.normal(0, .05, 6)})
             for c in range(4)]
    state = AllocState(axes=axes, cell_levels=cell_levels, cells=cells,
                       decided={}, round=1, spent_nrep=0, n_epochs_max=8)
    out = pol.decide(state)
    forced = [a for a, d in out.items() if d.forced]
    assert len(forced) == 1                  # weakest half of 2 axes
    assert out[forced[0]].verdict == "null"
    # plain racing never forces
    assert not any(d.forced
                   for d in RacingPolicy(n_min_null=10 ** 6)
                   .decide(state).values())


def test_make_policy_registry():
    assert make_policy("racing", nrep_budget=None) == RacingPolicy()
    assert make_policy("uniform").name == "uniform"
    with pytest.raises(ValueError, match="unknown allocation policy"):
        make_policy("greedy")
    m = make_policy("successive_halving", nrep_budget=120).manifest()
    assert m["name"] == "successive_halving" and m["nrep_budget"] == 120


# ---------------------------------------------------------------------------
# properties: determinism + budget-prefix (satellite #4)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       policy_name=st.sampled_from(["uniform", "racing",
                                    "successive_halving"]))
def test_property_allocation_is_deterministic_in_seed_and_records(
        seed, policy_name):
    """Same policy + same observed records => byte-identical allocation
    sequence and decisions (no RNG, no clock in any policy)."""
    runs = [_drive(make_policy(policy_name, n_min_null=6)
                   if policy_name != "uniform" else make_policy(policy_name),
                   seed, loud_axis="a0") for _ in range(2)]
    assert runs[0] == runs[1]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       budget_rounds=st.integers(1, 3),
       policy_name=st.sampled_from(["racing", "successive_halving"]))
def test_property_raising_budget_only_extends_the_sequence(
        seed, budget_rounds, policy_name):
    """The budget is a stop criterion, never a decision input: the
    allocation under a smaller budget is a strict prefix of the
    allocation under a larger (or absent) one."""
    nrep = 10

    def run(budget):
        return _drive(make_policy(policy_name, n_min_null=6,
                                  nrep_budget=budget),
                      seed, loud_axis="a0", nrep=nrep)

    small_plans, _ = run(budget_rounds * 4 * nrep)
    big_plans, _ = run(None)
    assert len(small_plans) <= len(big_plans)
    assert small_plans == big_plans[:len(small_plans)]


# ---------------------------------------------------------------------------
# end-to-end: racing == uniform verdicts, cheaper
# ---------------------------------------------------------------------------

def _verdicts(effects):
    return {e.axis: ("MATTERS" if e.significant else "null")
            for e in effects}


def test_racing_sweep_matches_uniform_verdicts_at_a_saving(tmp_path):
    spec_u, backend_u = default_sim_sweep(seed=0, axes=SMOKE_AXES,
                                          n_launch_epochs=6, nrep=30)
    res_u = SweepScheduler(spec_u, backend_u).run()
    uniform_verdicts = _verdicts(main_effects(cells_from_result(res_u)))

    spec_r, backend_r = default_sim_sweep(seed=0, axes=SMOKE_AXES,
                                          n_launch_epochs=6, nrep=30)
    store = ResultStore(tmp_path / "racing.jsonl")
    res_r = SweepScheduler(spec_r, backend_r, store,
                           policy=make_policy("racing")).run()
    alloc = res_r.meta["alloc"]
    assert alloc["decisions"] == uniform_verdicts
    assert alloc["undecided"] == []
    assert alloc["savings"] >= 1.4
    assert alloc["spent_nrep"] < alloc["uniform_nrep"]
    # the sweep-alloc trail is persisted, one line per round, in order
    lines = store.sweep_allocs(res_r.sweep_id)
    assert [ln["round"] for ln in lines] == list(range(alloc["n_rounds"]))
    assert lines[-1]["spent_nrep"] == alloc["spent_nrep"]


def test_adaptive_sweep_requires_a_store():
    spec, backend = default_sim_sweep(seed=0, axes=SMOKE_AXES)
    with pytest.raises(ValueError, match="store"):
        SweepScheduler(spec, backend, policy=make_policy("racing")).run()


# ---------------------------------------------------------------------------
# fleet: serial identity + kill/resume byte-prefix
# ---------------------------------------------------------------------------

def _records_by_fp(store):
    snap = store.snapshot()
    return {fp: [(r.epoch, r.case, r.times.tobytes())
                 for r in sorted(recs, key=lambda r: (r.epoch,
                                                      str(r.case)))]
            for fp, recs in snap.records.items()}


def _alloc_trail(store, sweep_id):
    return json.loads(json.dumps(store.sweep_allocs(sweep_id)))


def test_fleet_racing_equals_serial(tmp_path):
    results = {}
    for label, n_workers in (("serial", None), ("fleet", 1)):
        spec, backend = default_sim_sweep(seed=0, axes=SMOKE_AXES,
                                          n_launch_epochs=6, nrep=30)
        store = ResultStore(tmp_path / f"{label}.jsonl")
        if n_workers is None:
            res = SweepScheduler(spec, backend, store,
                                 policy=make_policy("racing")).run()
        else:
            res = FleetScheduler(spec, backend, store,
                                 FleetConfig(n_workers=n_workers),
                                 policy=make_policy("racing")).run()
        results[label] = (res, _records_by_fp(store),
                          _alloc_trail(store, res.sweep_id))
    (res_s, recs_s, trail_s), (res_f, recs_f, trail_f) = \
        results["serial"], results["fleet"]
    assert recs_s == recs_f
    assert trail_s == trail_f
    assert res_s.meta["alloc"]["decisions"] == \
        res_f.meta["alloc"]["decisions"]
    assert res_s.meta["alloc"]["spent_nrep"] == \
        res_f.meta["alloc"]["spent_nrep"]


def test_fleet_kill_resume_is_a_byte_prefix(tmp_path):
    """Kill a fleet-run racing sweep at arbitrary store prefixes and
    resume: the resumed run must reproduce the uninterrupted store's
    records and allocation decisions exactly."""
    def run(path):
        spec, backend = default_sim_sweep(seed=0, axes=SMOKE_AXES,
                                          n_launch_epochs=6, nrep=30)
        store = ResultStore(path)
        res = FleetScheduler(spec, backend, store, FleetConfig(n_workers=1),
                             policy=make_policy("racing")).run()
        return store, res

    full_store, full_res = run(tmp_path / "full.jsonl")
    full_recs = _records_by_fp(full_store)
    full_trail = _alloc_trail(full_store, full_res.sweep_id)
    lines = (tmp_path / "full.jsonl").read_bytes().splitlines(keepends=True)
    assert len(lines) > 4
    # cut after the first sweep-alloc line (mid-allocation) and at a
    # mid-round record boundary
    alloc_pos = next(i for i, ln in enumerate(lines)
                     if b'"sweep-alloc"' in ln)
    for cut in {alloc_pos + 1, max(1, len(lines) // 2)}:
        trunc = tmp_path / f"trunc{cut}.jsonl"
        trunc.write_bytes(b"".join(lines[:cut]))
        store, res = run(trunc)
        assert _records_by_fp(store) == full_recs, f"cut={cut}"
        assert _alloc_trail(store, res.sweep_id) == full_trail, f"cut={cut}"
        assert res.meta["alloc"]["decisions"] == \
            full_res.meta["alloc"]["decisions"]
