"""Campaign subsystem: backend protocol, adaptive-nrep stopping, the
persistent JSONL store (append -> resume -> load), and the end-to-end
multi-backend comparison the architecture exists for."""

import numpy as np
import pytest

from repro.campaign import (Campaign, CampaignSpec, JaxBackend, KernelBackend,
                            MeasurementBackend, ResultStore, SimBackend)
from repro.core import (ExperimentDesign, TestCase, analyze_records,
                        compare_tables, measure_adaptive, run_design)

QUIET = dict(noise_sigma=0.004, tail_prob=0.0, spike_prob=0.0,
             autocorr=0.0, rank_imbalance=0.01, epoch_bias_sigma=0.0)
HEAVY = dict(noise_sigma=0.35, tail_prob=0.45, tail_shift=3.0,
             spike_prob=0.05, spike_scale=40.0)
FAST_SYNC = dict(n_fitpts=100, n_exchanges=20)


def _spec(cases, **design_kw):
    kw = dict(n_launch_epochs=3, nrep=20, seed=11)
    kw.update(design_kw)
    return CampaignSpec(cases=cases, design=ExperimentDesign(**kw))


def _sim(seed0=0, op_kw=None, **kw):
    kw.setdefault("sync_kw", dict(FAST_SYNC))
    return SimBackend(p=4, seed0=seed0, op_kw=op_kw or {}, **kw)


# ---------------------------------------------------------------------------
# Backend protocol & run_design integration
# ---------------------------------------------------------------------------

def test_backends_satisfy_protocol():
    for b in (SimBackend(), JaxBackend(), KernelBackend()):
        assert isinstance(b, MeasurementBackend)
        assert b.default_cases()
        fs = b.factors(ExperimentDesign(n_launch_epochs=2, nrep=5))
        assert fs.measurement_backend == b.name
        assert fs.fingerprint()


def test_sim_backend_records_resolved_engine_meta():
    """Each record carries the engine that actually ran — ``auto`` on
    affine clocks resolves to ``batch``, and on random-walk clocks to
    ``batch_rw`` (never the scalar path)."""
    res = Campaign(_spec([TestCase("bcast", 256)], n_launch_epochs=2,
                         nrep=10), _sim(seed0=5)).run()
    assert all(r.meta["engine"] == "batch" for r in res.records)
    res_rw = Campaign(_spec([TestCase("bcast", 256)], n_launch_epochs=2,
                            nrep=10),
                      _sim(seed0=5, clock_kw=dict(rw_sigma=1e-7))).run()
    assert all(r.meta["engine"] == "batch_rw" for r in res_rw.records)


def test_sim_backend_jax_engine_fallback_warns_once_and_is_recorded():
    """engine='jax' on random-walk clocks: substituted (batch_rw), warned
    exactly once per campaign, and stamped on every record's meta."""
    import warnings

    backend = _sim(seed0=5, engine="jax", clock_kw=dict(rw_sigma=1e-7))
    spec = _spec([TestCase("bcast", 256)], n_launch_epochs=3, nrep=10)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = Campaign(spec, backend).run()
    fallback = [w for w in caught if issubclass(w.category, RuntimeWarning)
                and "resolved to" in str(w.message)]
    assert len(fallback) == 1, [str(w.message) for w in caught]
    assert all(r.meta["engine"] == "batch_rw" for r in res.records)
    assert all("engine_fallback" in r.meta for r in res.records)


def test_sim_backend_fallback_warning_points_at_caller():
    """The engine-fallback RuntimeWarning must be attributed to the code
    that asked for the engine (this file), not to a frame inside repro —
    ``warnings.filterwarnings(module=...)`` and editor jump-to-source
    both key off that location."""
    import warnings

    backend = _sim(seed0=5, engine="jax", clock_kw=dict(rw_sigma=1e-7))
    spec = _spec([TestCase("bcast", 256)], n_launch_epochs=2, nrep=10)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        Campaign(spec, backend).run()
    fallback = [w for w in caught if issubclass(w.category, RuntimeWarning)
                and "resolved to" in str(w.message)]
    assert len(fallback) == 1
    assert fallback[0].filename == __file__, (
        f"fallback warning attributed to {fallback[0].filename}, "
        f"expected {__file__}")


def test_sim_backend_jax_engine_end_to_end():
    """A campaign through the jit-compiled engine: right shapes, engine
    recorded, and means in the same ballpark as the numpy engine."""
    pytest.importorskip("jax")
    spec = _spec([TestCase("allreduce", 512)], n_launch_epochs=2, nrep=30)
    res_np = Campaign(spec, _sim(seed0=5)).run()
    res_jx = Campaign(spec, _sim(seed0=5, engine="jax")).run()
    assert all(r.meta["engine"] == "jax" for r in res_jx.records)
    case = res_jx.table.cases()[0]
    m_np = float(np.mean(res_np.table.means(case)))
    m_jx = float(np.mean(res_jx.table.means(case)))
    assert abs(m_np - m_jx) < 0.05 * m_np


def test_run_design_accepts_backend():
    """run_design consumes a backend directly (no ad-hoc pair) and falls
    back to the backend's default cases."""
    backend = _sim(seed0=3)
    design = ExperimentDesign(n_launch_epochs=3, nrep=15, seed=3)
    records = run_design(design, backend)
    cases = {c.key() for c in backend.default_cases()}
    assert {r.case.key() for r in records} == cases
    assert len(records) == 3 * len(cases)
    with pytest.raises(TypeError):
        run_design(design, lambda e: None)   # factory without measure


def test_sim_backend_tops_up_window_discards():
    """A tiny window discards many observations; the backend tops the
    valid sample back up toward the requested nrep."""
    backend = _sim(seed0=5, win_size=25e-6)
    ctx = backend.make_epoch(0)
    times = backend.measure(ctx, TestCase("alltoall", 8192), 50)
    assert times.size >= 25


# ---------------------------------------------------------------------------
# Adaptive nrep (sequential stopping)
# ---------------------------------------------------------------------------

def test_adaptive_nrep_converges_below_cap_on_quiet_case():
    backend = _sim(seed0=21, op_kw=QUIET)
    design = ExperimentDesign(n_launch_epochs=1, nrep_min=10, nrep_max=400,
                              rel_ci_target=0.02, seed=21)
    times, meta = measure_adaptive(backend.measure, backend.make_epoch(0),
                                   TestCase("allreduce", 1024), design)
    assert meta["converged"]
    assert meta["nrep_used"] < 400
    assert meta["rel_ci"] <= 0.02
    assert times.size == meta["nrep_used"]


def test_adaptive_nrep_hits_cap_on_heavy_tail_case():
    backend = _sim(seed0=22, op_kw=HEAVY)
    design = ExperimentDesign(n_launch_epochs=1, nrep_min=10, nrep_max=120,
                              rel_ci_target=0.02, seed=22)
    times, meta = measure_adaptive(backend.measure, backend.make_epoch(0),
                                   TestCase("allreduce", 1024), design)
    assert not meta["converged"]
    assert times.size >= 120
    assert meta["rel_ci"] > 0.02


def test_adaptive_records_carry_provenance():
    spec = _spec([TestCase("allreduce", 256)], n_launch_epochs=2, nrep_min=8,
                 nrep_max=30, rel_ci_target=0.05)
    res = Campaign(spec, _sim(seed0=9)).run()
    for rec in res.records:
        assert 8 <= rec.meta["nrep_used"] <= 30 + 8  # chunking may overshoot
        assert "rel_ci" in rec.meta
    assert res.factors.nrep_max == 30 and res.factors.nrep == 0


# ---------------------------------------------------------------------------
# Persistent store
# ---------------------------------------------------------------------------

def test_store_round_trip_matches_in_memory(tmp_path):
    """append -> load: analyze_records over store records reduces
    identically to the in-memory run."""
    spec = _spec([TestCase("allreduce", 256), TestCase("bcast", 1024)])
    store = ResultStore(tmp_path / "a.jsonl")
    res = Campaign(spec, _sim(seed0=31), store).run()
    assert res.n_measured == 6 and res.n_resumed == 0

    loaded = store.records(res.fingerprint)
    t_mem = res.table
    t_disk = analyze_records(loaded)
    for case in t_mem.cases():
        np.testing.assert_array_equal(t_mem.medians(case),
                                      t_disk.medians(case))
        np.testing.assert_array_equal(t_mem.means(case), t_disk.means(case))


def test_store_resume_skips_measurement(tmp_path):
    """Re-running the identical campaign against the same store loads every
    cell instead of re-measuring, and yields the same table."""
    spec = _spec([TestCase("allreduce", 256)])
    path = tmp_path / "a.jsonl"
    first = Campaign(spec, _sim(seed0=33), ResultStore(path)).run()

    calls = []
    backend = _sim(seed0=33)
    orig = backend.measure
    backend.measure = lambda *a, **k: calls.append(1) or orig(*a, **k)
    resumed = Campaign(spec, backend, ResultStore(path)).run()
    assert not calls
    assert resumed.n_resumed == 3 and resumed.n_measured == 0
    case = first.table.cases()[0]
    np.testing.assert_array_equal(first.table.medians(case),
                                  resumed.table.medians(case))


def test_store_partial_resume_measures_only_missing(tmp_path):
    """Truncating the store to the first epoch leaves later epochs to be
    measured; earlier cells come back verbatim."""
    spec = _spec([TestCase("allreduce", 256)], n_launch_epochs=4)
    path = tmp_path / "a.jsonl"
    full = Campaign(spec, _sim(seed0=35), ResultStore(path)).run()

    # first four lines: schema header, campaign declaration, two records
    lines = path.read_text().splitlines()
    cut = ResultStore(tmp_path / "cut.jsonl")
    (tmp_path / "cut.jsonl").write_text("\n".join(lines[:4]) + "\n")
    assert cut.completed(full.fingerprint) == {("allreduce", 256, 0),
                                               ("allreduce", 256, 1)}
    resumed = Campaign(spec, _sim(seed0=35), cut).run()
    assert resumed.n_resumed == 2 and resumed.n_measured == 2
    assert len(cut.completed(full.fingerprint)) == 4
    for rec, ref in zip(resumed.records[:2], full.records[:2]):
        np.testing.assert_array_equal(rec.times, ref.times)


def test_store_distinguishes_factor_sets(tmp_path):
    """One file, two campaigns with different factors: records stay keyed
    to their own fingerprint."""
    store = ResultStore(tmp_path / "multi.jsonl")
    spec = _spec([TestCase("allreduce", 256)], n_launch_epochs=2)
    ra = Campaign(spec, _sim(seed0=41), store).run()
    rb = Campaign(spec, _sim(seed0=41, op_kw=dict(alpha=9e-6)), store).run()
    assert ra.fingerprint != rb.fingerprint
    assert store.fingerprints() == [ra.fingerprint, rb.fingerprint]
    assert len(store.records(ra.fingerprint)) == 2
    a = store.to_table(ra.fingerprint).medians(TestCase("allreduce", 256))
    b = store.to_table(rb.fingerprint).medians(TestCase("allreduce", 256))
    assert np.mean(b) > np.mean(a)           # the slower library stayed slower


def test_design_identity_changes_fingerprint():
    """A different seed, randomization choice, or adaptive precision target
    is a different experiment: it must not resume another campaign's
    records from the store."""
    backend = _sim(seed0=47)
    base = dict(n_launch_epochs=2, nrep_min=5, nrep_max=20,
                rel_ci_target=0.05, seed=1)
    fp = backend.factors(ExperimentDesign(**base)).fingerprint()
    for change in (dict(seed=2), dict(shuffle=False),
                   dict(rel_ci_target=0.01), dict(nrep_max=40)):
        other = backend.factors(
            ExperimentDesign(**{**base, **change})).fingerprint()
        assert other != fp, change


def test_backend_identity_changes_fingerprint():
    """Backend configuration knobs that change what is measured must show
    up in the store fingerprint (no silent resume of a different
    experiment)."""
    d = ExperimentDesign(n_launch_epochs=2, nrep=5)
    assert (_sim(seed0=1).factors(d).fingerprint()
            != _sim(seed0=1, sync_kw=dict(n_fitpts=10, n_exchanges=2),
                    ).factors(d).fingerprint())
    assert (KernelBackend(kv_heads=2, seed0=0).factors(d).fingerprint()
            != KernelBackend(kv_heads=4, seed0=99).factors(d).fingerprint())


def test_store_redeclares_changed_spec(tmp_path):
    """Growing a campaign's case list resumes the same fingerprint but
    refreshes the declaration, so the last spec describes the data."""
    store = ResultStore(tmp_path / "a.jsonl")
    r1 = Campaign(_spec([TestCase("allreduce", 256)], n_launch_epochs=2),
                  _sim(seed0=61), store).run()
    r2 = Campaign(_spec([TestCase("allreduce", 256),
                         TestCase("allreduce", 4096)], n_launch_epochs=2),
                  _sim(seed0=61), store).run()
    assert r1.fingerprint == r2.fingerprint
    assert r2.n_resumed == 2 and r2.n_measured == 2
    assert store.fingerprints() == [r1.fingerprint]
    specs = [o for o in store._lines() if o["kind"] == "campaign"]
    assert len(specs) == 2 and len(specs[-1]["spec"]["cases"]) == 2


def test_store_warns_and_skips_truncated_tail_line(tmp_path):
    spec = _spec([TestCase("allreduce", 256)], n_launch_epochs=2)
    path = tmp_path / "a.jsonl"
    res = Campaign(spec, _sim(seed0=43), ResultStore(path)).run()
    with open(path, "a") as f:
        f.write('{"kind": "record", "fingerprint": "xyz", "op": "allre')
    with pytest.warns(RuntimeWarning,
                      match=r'undecodable "record" tail line'):
        assert len(ResultStore(path).records(res.fingerprint)) == 2


# ---------------------------------------------------------------------------
# End-to-end: one spec, two backends, two stores, one comparison
# ---------------------------------------------------------------------------

def test_end_to_end_sim_and_kernel_backends_compose(tmp_path):
    """The acceptance scenario: the *same* Campaign spec runs against
    SimBackend and KernelBackend (CPU interpret mode), both persist to
    stores, both reload, and compare_tables produces the report — proving
    the backend protocol, the store, and adaptive nrep compose."""
    spec = CampaignSpec(
        cases=[TestCase("flash_attention", 64)],
        design=ExperimentDesign(n_launch_epochs=2, nrep_min=3, nrep_max=6,
                                rel_ci_target=0.3, seed=17),
        name="e2e",
    )
    backends = {
        "sim": _sim(seed0=50),     # unknown op name -> generic cost model
        "kernel": KernelBackend(impl="pallas", batch=1, heads=2, head_dim=16,
                                interpret=True),
    }
    stores = {}
    for label, backend in backends.items():
        store = ResultStore(tmp_path / f"{label}.jsonl")
        res = Campaign(spec, backend, store).run()
        assert res.n_measured == 2
        assert all(3 <= r.meta["nrep_used"] for r in res.records)
        assert store.factors()["measurement_backend"] == backend.name
        stores[label] = store

    rows = compare_tables(stores["sim"], stores["kernel"])
    assert len(rows) == 1
    row = rows[0]
    assert row.case.key() == ("flash_attention", 64)
    assert row.n_a == 2 and row.n_b == 2
    assert 0.0 <= row.p_two_sided <= 1.0
    assert np.isfinite(row.ratio)


@pytest.mark.jaxdevices(4)
def test_jax_backend_collectives_multi_device(tmp_path):
    """JaxBackend measures real jitted collectives over a >= 4-device host
    mesh and persists/reloads through the store."""
    spec = CampaignSpec(
        cases=[TestCase("psum", 1024), TestCase("all_to_all", 1024)],
        design=ExperimentDesign(n_launch_epochs=2, nrep_min=3, nrep_max=6,
                                rel_ci_target=0.5, seed=19),
        name="jax-collectives",
    )
    store = ResultStore(tmp_path / "jax.jsonl")
    res = Campaign(spec, JaxBackend(n_devices=4), store).run()
    assert res.factors.mesh_shape == (4,)
    table = store.to_table(res.fingerprint)
    for case in table.cases():
        med = table.medians(case)
        assert med.size == 2
        assert np.all(med > 0)
