"""Clock-synchronization correctness: merge exactness, per-algorithm
accuracy, and the paper's qualitative claims (Figs. 8-10)."""

import numpy as np
import pytest

from repro.core import (
    ClockParams,
    LinearModel,
    SimNet,
    linear_fit,
    make_sync,
    probe_offsets,
    true_offsets,
)

PAPER_PARAMS = dict(n_fitpts=300, n_exchanges=60)


def test_merge_lms_exact_composition():
    """MERGE_LMS (Alg. 4) composes child-time-parameterized drift models
    exactly — verified on noise-free linear clocks."""
    t = np.linspace(0.0, 50.0, 200)

    def clock(off, sk):
        return off + (1 + sk) * t

    t1 = clock(0.0, 0.0)
    t2 = clock(1.7e-3, 2e-5)
    t3 = clock(-0.4e-3, -3e-5)
    lm21 = linear_fit(t2, t2 - t1)
    lm32 = linear_fit(t3, t3 - t2)
    merged = LinearModel.merge(lm21, lm32)
    direct = linear_fit(t3, t3 - t1)
    assert abs(merged.slope - direct.slope) < 1e-15
    assert abs(merged.intercept - direct.intercept) < 1e-12


def test_normalize_denormalize_roundtrip():
    lm = LinearModel(slope=3e-5, intercept=-2e-3)
    for t in [0.0, 1.0, 17.3, 1e4]:
        assert abs(lm.denormalize(lm.normalize(t)) - t) < 1e-9


@pytest.mark.parametrize("name", ["skampi", "netgauge", "jk", "hca", "hca2"])
def test_initial_offset_small(name):
    """Fig. 8: every algorithm synchronizes to ~microsecond offsets
    immediately after the sync phase."""
    net = SimNet(8, seed=3)
    kw = PAPER_PARAMS if name in ("jk", "hca", "hca2") else {}
    res = make_sync(name, **kw).synchronize(net)
    off = np.abs(true_offsets(net, res))[1:]
    assert off.max() < 20e-6, f"{name}: {off.max()*1e6:.1f}us"


def test_drift_correction_beats_offset_only():
    """Fig. 9: after 20 s, drift-aware algorithms (JK/HCA) hold ~us offsets
    while offset-only ones (SKaMPI/Netgauge) drift to hundreds of us."""
    results = {}
    for name in ["skampi", "hca"]:
        net = SimNet(8, seed=5)
        kw = PAPER_PARAMS if name == "hca" else {}
        res = make_sync(name, **kw).synchronize(net)
        net.sleep_all(20.0)
        results[name] = np.abs(true_offsets(net, res))[1:].max()
    assert results["skampi"] > 50e-6          # drifted
    assert results["hca"] < 20e-6             # drift-corrected
    assert results["hca"] < results["skampi"] / 5


def test_hca_faster_than_jk_at_scale():
    """Fig. 10's trade-off: at larger p, HCA's O(log p) slope phase
    finishes well before JK's O(p) interleaved phase."""
    kw = dict(n_fitpts=40, n_exchanges=10)
    net1 = SimNet(32, seed=7)
    hca = make_sync("hca", **kw).synchronize(net1)
    net2 = SimNet(32, seed=7)
    jk = make_sync("jk", **kw).synchronize(net2)
    assert hca.duration < jk.duration


def test_probe_matches_ground_truth():
    """The paper-faithful network probe (Alg. 20) agrees with simulator
    ground truth up to ~RTT/2 error."""
    net = SimNet(6, seed=11)
    res = make_sync("hca", n_fitpts=200, n_exchanges=40).synchronize(net)
    probed = probe_offsets(net, res, n_rounds=10)
    truth = true_offsets(net, res)
    assert np.max(np.abs(probed[1:] - truth[1:])) < 30e-6


def test_hca2_hierarchical_intercepts_worse_than_hca():
    """§4.4/Fig. 9: hierarchically merged intercepts accumulate error along
    the tree. The effect is read *directly after* synchronization — a few
    seconds later the slope-error drift (common to both variants) dominates
    and the intercept signal drowns in it."""
    errs = {}
    for name in ["hca", "hca2"]:
        accs = []
        for seed in range(5):
            net = SimNet(16, seed=100 + seed)
            res = make_sync(name, n_fitpts=200, n_exchanges=40).synchronize(net)
            accs.append(np.abs(true_offsets(net, res))[1:].max())
        errs[name] = np.median(accs)
    assert errs["hca2"] >= errs["hca"]


def test_netgauge_error_grows_with_rounds():
    """Fig. 8(b): Netgauge's tree-summed offsets accumulate error with p."""
    small, big = [], []
    for seed in range(4):
        net = SimNet(4, seed=200 + seed)
        res = make_sync("netgauge").synchronize(net)
        small.append(np.abs(true_offsets(net, res))[1:].max())
        net = SimNet(64, seed=300 + seed)
        res = make_sync("netgauge").synchronize(net)
        big.append(np.abs(true_offsets(net, res))[1:].max())
    assert np.median(big) > np.median(small)


def test_frequency_estimation_error_inflates_drift():
    """§4.2.1 / Fig. 5: a ~4.3e-6 frequency-estimation error adds ~us/s of
    drift to an offset-only global clock."""
    base, freqerr = [], []
    for seed in range(3):
        net = SimNet(8, seed=400 + seed,
                     clocks=ClockParams(skew_sigma=1e-7))
        res = make_sync("skampi").synchronize(net)
        net.sleep_all(10.0)
        base.append(np.abs(true_offsets(net, res))[1:].max())
        net = SimNet(8, seed=400 + seed,
                     clocks=ClockParams(skew_sigma=1e-7, freq_est_sigma=4.3e-6))
        res = make_sync("skampi").synchronize(net)
        net.sleep_all(10.0)
        freqerr.append(np.abs(true_offsets(net, res))[1:].max())
    assert np.median(freqerr) > 3 * np.median(base)
