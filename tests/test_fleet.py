"""Fault-tolerant fleet execution: the retry policy, the hardened
parallel map, store corruption handling, the lease queue's exact
schedules, deterministic fault injection, shard federation, and the
chaos-fleet invariant — a fleet store under injected faults is
record-identical to a serial no-fault run, with quarantined cells
excluded *and reported*."""

import os
import tempfile
import time
import warnings
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.campaign import (Campaign, CampaignSpec, ResultStore, SimBackend,
                            SweepScheduler)
from repro.core import RetryBudgetExceeded, RetryPolicy, retry_call
from repro.core.design import (ExperimentDesign, MeasurementRecord, TestCase,
                               map_parallel)
from repro.fleet import (CrashFault, FaultPlan, FaultyBackend, FleetConfig,
                         FleetScheduler, LeaseQueue, TransientFault,
                         merge_stores)
from repro.fleet.faults import TORN_LINE
from repro.fleet.queue import LEASED, PENDING, QUARANTINED
from repro.history import RunArchive
from repro.sweeps import default_sim_sweep

FAST_SYNC = dict(n_fitpts=60, n_exchanges=20)


def _tiny_sweep(seed=0, axes=("tuning",), n_launch_epochs=2, nrep=8):
    return default_sim_sweep(seed=seed, axes=axes, msizes=(512,),
                             n_launch_epochs=n_launch_epochs, nrep=nrep)


def _dump(store):
    """Every record of every campaign, exact times included — the
    bit-identity yardstick."""
    out = {}
    for fp in store.fingerprints():
        out[fp] = sorted(
            (r.case.op, r.case.msize, r.epoch,
             tuple(np.asarray(r.times, np.float64).tolist()))
            for r in store.records(fp))
    return out


class _FakeClock:
    """Deterministic clock for driving schedulers without real sleeps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += max(float(s), 1e-4)


def _fast_fleet(**kw):
    clk = _FakeClock()
    kw.setdefault("n_workers", 1)
    kw.setdefault("clock", clk)
    kw.setdefault("sleep", clk.sleep)
    return FleetConfig(**kw)


# ---------------------------------------------------------------------------
# RetryPolicy / retry_call
# ---------------------------------------------------------------------------

def test_retry_policy_ceiling_grows_and_caps():
    p = RetryPolicy(base=0.1, factor=2.0, max_delay=0.5)
    assert [p.ceiling(k) for k in range(5)] == [0.1, 0.2, 0.4, 0.5, 0.5]


def test_retry_policy_seeded_delay_is_deterministic_and_jittered():
    p = RetryPolicy(base=0.1, seed=7)
    assert p.delay(2, key=3) == p.delay(2, key=3)
    assert p.delay(2, key=3) != p.delay(2, key=4)   # per-key streams
    assert p.delay(2, key=3) != RetryPolicy(base=0.1, seed=8).delay(2, key=3)
    for k in range(6):
        assert 0.0 <= p.delay(k) <= p.ceiling(k)


def test_retry_policy_deadline_caps_schedule():
    p = RetryPolicy(base=1.0, factor=2.0, max_delay=100.0, attempts=10,
                    deadline=2.0, seed=0)
    sched = list(p.delays())
    assert sum(sched) <= 2.0 and len(sched) < 9


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="factor"):
        RetryPolicy(factor=0.5)
    with pytest.raises(ValueError, match="attempts"):
        RetryPolicy(attempts=0)
    with pytest.raises(ValueError, match=">= 0"):
        RetryPolicy(base=-1.0)


def test_retry_call_succeeds_after_transient_failures():
    calls = {"n": 0}
    slept = []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    p = RetryPolicy(base=0.01, attempts=5, seed=0)
    assert retry_call(flaky, p, sleep=slept.append) == "ok"
    assert calls["n"] == 3 and len(slept) == 2
    assert slept == [p.delay(0), p.delay(1)]   # the exact seeded schedule


def test_retry_call_exhaustion_chains_last_error():
    def boom():
        raise ValueError("always")

    with pytest.raises(RetryBudgetExceeded) as ei:
        retry_call(boom, RetryPolicy(base=0.0, attempts=3, seed=0),
                   sleep=lambda s: None)
    assert ei.value.attempts == 3
    assert isinstance(ei.value.__cause__, ValueError)


def test_retry_call_does_not_retry_unmatched_exceptions():
    calls = {"n": 0}

    def boom():
        calls["n"] += 1
        raise TypeError("programming error")

    with pytest.raises(TypeError):
        retry_call(boom, RetryPolicy(attempts=5, seed=0),
                   retry_on=(OSError,), sleep=lambda s: None)
    assert calls["n"] == 1


# ---------------------------------------------------------------------------
# map_parallel hardening: setup fallback vs crash restart vs stall
# ---------------------------------------------------------------------------

def _mp_ret(x):
    return x


def _mp_crash_once(flag, x):
    if x == 0 and not os.path.exists(flag):
        open(flag, "w").close()
        os._exit(1)           # worker killed mid-task: BrokenProcessPool
    return x * 10


def _mp_always_crash(x):
    os._exit(1)


def _mp_hang(x):
    time.sleep(60)


def test_map_parallel_empty_and_serial_fallback_on_unpicklable():
    assert map_parallel(_mp_ret, [], 2) == []
    with pytest.warns(RuntimeWarning, match="not picklable"):
        assert map_parallel(lambda x: x, [(1,)], 2) is None


def test_map_parallel_restarts_pool_after_worker_crash(tmp_path):
    """One worker dies mid-run: the pool is restarted, only unfinished
    tasks are resubmitted, and the warning names the crash — no silent
    serial fallback."""
    flag = str(tmp_path / "crashed-once")
    with pytest.warns(RuntimeWarning, match="worker process died"):
        out = map_parallel(_mp_crash_once, [(flag, i) for i in range(3)],
                           n_workers=2, what="crash-once tasks",
                           retry=RetryPolicy(base=0.0, seed=0))
    assert out == [0, 10, 20]


def test_map_parallel_reraises_when_pool_keeps_dying():
    import concurrent.futures as cf

    with pytest.warns(RuntimeWarning, match="worker process died"):
        with pytest.raises(cf.process.BrokenProcessPool,
                           match="unfinished"):
            map_parallel(_mp_always_crash, [(i,) for i in range(2)],
                         n_workers=2, max_restarts=1,
                         retry=RetryPolicy(base=0.0, seed=0))


def test_map_parallel_stall_raises_timeout_naming_in_flight():
    t0 = time.time()
    with pytest.raises(TimeoutError, match="in flight"):
        map_parallel(_mp_hang, [(1,), (2,)], n_workers=2, timeout=0.5)
    assert time.time() - t0 < 30   # the hung workers were actually killed


# ---------------------------------------------------------------------------
# Store hardening: mid-file corruption, torn-tail healing
# ---------------------------------------------------------------------------

def _store_with_records(path, n=3, fp="fp-test"):
    store = ResultStore(path)
    store._append(dict(kind="campaign", fingerprint=fp, factors={}, spec={}))
    for e in range(n):
        store.append_record(fp, MeasurementRecord(
            case=TestCase("allreduce", 512), epoch=e,
            times=np.array([1.0 + e, 2.0 + e])))
    return store, fp


def test_store_counts_and_names_midfile_corruption(tmp_path):
    store, fp = _store_with_records(tmp_path / "s.jsonl")
    lines = (tmp_path / "s.jsonl").read_text().splitlines()
    lines.insert(3, '{"kind": "record", "fingerprint": "torn-in-the-mi')
    (tmp_path / "s.jsonl").write_text("\n".join(lines) + "\n")
    with pytest.warns(RuntimeWarning,
                      match=r's\.jsonl:4: dropping undecodable "record" '
                            r'line mid-file'):
        recs = store.records(fp)
    assert len(recs) == 3                 # every intact record survives
    assert store.n_corrupt == 1
    assert store.snapshot().n_corrupt == 1


def test_store_tail_truncation_warns_differently(tmp_path):
    store, fp = _store_with_records(tmp_path / "t.jsonl")
    raw = (tmp_path / "t.jsonl").read_bytes()
    (tmp_path / "t.jsonl").write_bytes(raw[:-20])   # tear the last line
    with pytest.warns(RuntimeWarning, match="truncated write from a killed"):
        recs = store.records(fp)
    assert len(recs) == 2 and store.n_corrupt == 1


def test_append_heals_torn_tail_instead_of_gluing(tmp_path):
    """Appending to a file whose last line was torn mid-write must not
    merge the new line into the garbage — the torn residue is newline-
    terminated first, so only *it* is lost."""
    store, fp = _store_with_records(tmp_path / "h.jsonl")
    raw = (tmp_path / "h.jsonl").read_bytes()
    (tmp_path / "h.jsonl").write_bytes(raw[:-20])
    store.append_record(fp, MeasurementRecord(
        case=TestCase("allreduce", 512), epoch=9,
        times=np.array([9.0, 9.5])))
    with pytest.warns(RuntimeWarning):
        recs = store.records(fp)
    assert {r.epoch for r in recs} == {0, 1, 9}   # the new append survived
    assert store.n_corrupt == 1                   # only the torn line lost


# ---------------------------------------------------------------------------
# LeaseQueue: exact claim/heartbeat/expiry/backoff/quarantine schedules
# ---------------------------------------------------------------------------

def _queue(n=3, ttl=10.0, budget=3, seed=0):
    policy = RetryPolicy(base=1.0, factor=2.0, max_delay=8.0, seed=seed)
    return LeaseQueue([(i, f"fp{i}") for i in range(n)], lease_ttl=ttl,
                      policy=policy, retry_budget=budget), policy


def test_queue_validation():
    with pytest.raises(ValueError, match="lease_ttl"):
        LeaseQueue([(0, "a")], lease_ttl=0)
    with pytest.raises(ValueError, match="retry_budget"):
        LeaseQueue([(0, "a")], lease_ttl=1, retry_budget=0)


def test_queue_claims_lowest_index_first_and_exhausts():
    q, _ = _queue(n=2)
    a = q.claim("w0", now=0.0)
    b = q.claim("w1", now=0.0)
    assert (a.index, b.index) == (0, 1)
    assert a.state == LEASED and a.worker == "w0"
    assert q.claim("w2", now=0.0) is None
    assert not q.finished()


def test_queue_heartbeat_extends_lease_and_expiry_fires_without_it():
    q, _ = _queue(ttl=10.0)
    t = q.claim("w0", now=0.0)
    assert q.expired(now=9.9) == []
    q.heartbeat(t.index, now=8.0)          # lease now runs to 18.0
    assert q.expired(now=15.0) == []
    assert [x.index for x in q.expired(now=18.0)] == [t.index]


def test_queue_release_requeues_behind_exact_backoff_gate():
    q, policy = _queue(n=1)
    t = q.claim("w0", now=0.0)
    assert q.release(t.index, now=100.0, error="crash") == PENDING
    gate = 100.0 + policy.delay(0, key=t.index)   # seeded, reproducible
    assert t.not_before == gate and t.attempts == 1
    assert q.claim("w1", now=gate - 1e-6) is None or gate == 100.0
    assert q.next_wake(now=100.0) == gate
    got = q.claim("w1", now=gate)
    assert got is t and t.worker == "w1"


def test_queue_stale_heartbeat_after_revocation_is_ignored():
    q, _ = _queue()
    t = q.claim("w0", now=0.0)
    q.release(t.index, now=5.0, error="lease expired")
    q.heartbeat(t.index, now=6.0)          # zombie worker phones home
    assert t.state == PENDING and t.lease_expires <= 10.0


def test_queue_quarantines_after_retry_budget():
    q, _ = _queue(n=1, budget=2)
    for k in range(2):
        t = q.claim("w0", now=float(k * 100))
        state = q.release(t.index, now=float(k * 100 + 1), error=f"e{k}")
    assert state == QUARANTINED and t.errors == ["e0", "e1"]
    assert q.finished() and q.claim("w1", now=1e9) is None
    assert [x.index for x in q.quarantined()] == [0]
    s = q.stats()
    assert s["n_quarantined"] == 1 and s["n_failed_attempts"] == 2


def test_queue_finished_and_next_wake():
    q, _ = _queue(n=2, ttl=5.0)
    a = q.claim("w0", now=0.0)
    q.complete(a.index)
    b = q.claim("w0", now=1.0)
    assert q.next_wake(now=1.0) == 6.0     # only the live lease's expiry
    q.complete(b.index)
    assert q.finished() and q.next_wake(now=1.0) is None


# ---------------------------------------------------------------------------
# Fault injection: seeded, deterministic, fingerprint-transparent
# ---------------------------------------------------------------------------

def test_fault_plan_decides_deterministically_per_cell_attempt():
    plan = FaultPlan(seed=3, p_crash=0.5, p_raise=0.5)
    for cell in range(6):
        assert plan.decide(cell, 0) == plan.decide(cell, 0)
    assert any(plan.decide(c, 0) != FaultPlan(seed=4, p_crash=0.5,
                                              p_raise=0.5).decide(c, 0)
               for c in range(6))


def test_fault_plan_spares_attempts_past_the_faulty_budget():
    plan = FaultPlan(seed=0, p_crash=1.0, max_faulty_attempts=2)
    assert plan.decide(0, 0) and plan.decide(0, 1)
    assert plan.decide(0, 2) == [] and plan.decide(0, 99) == []


def test_fault_plan_validation_and_parse():
    with pytest.raises(ValueError, match="p_crash"):
        FaultPlan(p_crash=1.5)
    plan = FaultPlan.parse("crash=0.4,straggle=0.2,seed=7,within_calls=3,"
                           "torn_on_crash=false")
    assert plan == FaultPlan(seed=7, p_crash=0.4, p_straggle=0.2,
                             within_calls=3, torn_on_crash=False)
    with pytest.raises(ValueError, match="unknown key"):
        FaultPlan.parse("explode=1.0")
    with pytest.raises(ValueError, match="key=value"):
        FaultPlan.parse("crash")
    assert not FaultPlan().any_faults() and plan.any_faults()


def test_faulty_backend_is_fingerprint_transparent():
    design = ExperimentDesign(n_launch_epochs=2, nrep=5, seed=0)
    inner = SimBackend(p=4, seed0=1, sync_kw=dict(FAST_SYNC))
    fb = FaultyBackend(inner, FaultPlan(seed=0, p_crash=1.0), cell_index=0)
    assert fb.factors(design).fingerprint() == \
        inner.factors(design).fingerprint()
    assert fb.name == inner.name


def test_faulty_backend_injects_at_the_decided_call(tmp_path):
    design = ExperimentDesign(n_launch_epochs=1, nrep=4, seed=0)
    case = TestCase("allreduce", 512)

    def fresh(plan, attempt=0, shard=None):
        inner = SimBackend(p=4, seed0=1, sync_kw=dict(FAST_SYNC))
        fb = FaultyBackend(inner, plan, cell_index=0, attempt=attempt,
                           hard=False, shard_path=shard)
        return fb, fb.make_epoch(0)

    fb, ctx = fresh(FaultPlan(seed=0, p_crash=1.0, within_calls=1))
    with pytest.raises(CrashFault, match="cell 0, attempt 0, call 1"):
        fb.measure(ctx, case, 4)
    fb, ctx = fresh(FaultPlan(seed=0, p_raise=1.0, within_calls=1))
    with pytest.raises(TransientFault):
        fb.measure(ctx, case, 4)
    # past the faulty-attempt budget the same plan is a no-op, and the
    # measured values are the inner backend's exactly
    fb, ctx = fresh(FaultPlan(seed=0, p_crash=1.0, within_calls=1),
                    attempt=1)
    ref, rctx = fresh(FaultPlan(seed=0))
    np.testing.assert_array_equal(fb.measure(ctx, case, 4),
                                  ref.measure(rctx, case, 4))
    # torn writes land newline-terminated garbage in the shard
    shard = tmp_path / "shard.jsonl"
    fb, ctx = fresh(FaultPlan(seed=0, p_torn=1.0, within_calls=1),
                    shard=str(shard))
    fb.measure(ctx, case, 4)
    assert shard.read_text().startswith(TORN_LINE)
    assert shard.read_text().endswith("\n")


# ---------------------------------------------------------------------------
# Store federation
# ---------------------------------------------------------------------------

def _campaign_into(path, backend, design, cases, name):
    store = ResultStore(path)
    res = Campaign(CampaignSpec(list(cases), design, name=name),
                   backend, store).run()
    return store, res


def test_merge_stores_is_idempotent_and_complete(tmp_path):
    spec, backend = _tiny_sweep()
    compiled = SweepScheduler(spec, backend).compile()
    shards = []
    for cell, b, design, _, fp in compiled:
        store, _ = _campaign_into(tmp_path / f"shard{cell.index}.jsonl",
                                  b, design, spec.cases, f"cell{cell.index}")
        shards.append((store, fp))

    dest = ResultStore(tmp_path / "fed.jsonl")
    stats = merge_stores(dest, [s for s, _ in shards])
    assert stats.n_campaigns == len(shards)
    assert stats.n_records == sum(len(s.records(fp)) for s, fp in shards)
    assert stats.n_duplicates == 0
    for s, fp in shards:
        assert _dump(dest)[fp] == _dump(s)[fp]
    # replaying the merge (a crashed-compaction recovery) is a no-op
    again = merge_stores(dest, [s for s, _ in shards])
    assert again.merged_nothing()
    assert again.n_duplicates == stats.n_records


def test_merge_stores_rejects_self_merge_and_counts_corruption(tmp_path):
    store, fp = _store_with_records(tmp_path / "a.jsonl")
    with pytest.raises(ValueError, match="among its own shards"):
        merge_stores(store, [store])
    raw = (tmp_path / "a.jsonl").read_bytes()
    (tmp_path / "a.jsonl").write_bytes(raw[:-15])       # torn shard tail
    dest = ResultStore(tmp_path / "b.jsonl")
    with pytest.warns(RuntimeWarning, match="undecodable"):
        stats = merge_stores(dest, [store])
    assert stats.n_corrupt == 1
    assert len(dest.records(fp)) == 2                   # intact lines merged


def test_archive_records_corruption_and_resolves_merged_baselines(tmp_path):
    """RunEntry carries n_corrupt, and baseline_for resolves a federated
    (merged-shard) candidate against a plain single-campaign baseline via
    their shared factor fingerprint."""
    spec, backend = _tiny_sweep()
    (c0, b0, d0, _, fp0), (c1, b1, d1, _, fp1) = \
        SweepScheduler(spec, backend).compile()
    arch = RunArchive(tmp_path / "arch")
    arch.root.mkdir(parents=True)

    base_store, _ = _campaign_into(arch.root / "base.jsonl", b0, d0,
                                   spec.cases, "cellA")
    base = arch.register(base_store.path, tag="reference")
    assert base.n_corrupt == 0

    s0, _ = _campaign_into(tmp_path / "h0.jsonl", b0, d0, spec.cases, "cellA")
    s1, _ = _campaign_into(tmp_path / "h1.jsonl", b1, d1, spec.cases, "cellB")
    fed = ResultStore(arch.root / "fed.jsonl")
    merge_stores(fed, [s0, s1])
    # tear the federated store's tail: registration must record the damage
    raw = fed.path.read_bytes()
    fed.path.write_bytes(raw + b'{"kind": "record", "fin')
    with pytest.warns(RuntimeWarning, match="n_corrupt"):
        cand = arch.register(fed.path)
    assert cand.n_corrupt == 1
    assert arch.entry(cand.run_id).n_corrupt == 1       # manifest round-trip
    assert set(cand.fingerprints) == {fp0, fp1}
    resolved = arch.baseline_for(cand)
    assert resolved is not None and resolved.run_id == base.run_id


# ---------------------------------------------------------------------------
# FleetScheduler, in-process mode: equivalence, quarantine, recovery
# ---------------------------------------------------------------------------

def _serial_reference(tmp, spec, backend):
    store = ResultStore(tmp / "serial.jsonl")
    SweepScheduler(spec, backend, store, n_workers=1).run()
    return _dump(store)


def test_inprocess_fleet_matches_serial_without_faults(tmp_path):
    spec, backend = _tiny_sweep(axes=("tuning", "dtype"))
    ref = _serial_reference(tmp_path, spec, backend)
    store = ResultStore(tmp_path / "fleet.jsonl")
    res = FleetScheduler(spec, backend, store, _fast_fleet()).run()
    assert res.n_cells_measured == 4 and not res.quarantined
    assert _dump(store) == ref
    # and a re-run is a pure resume
    res2 = FleetScheduler(spec, backend, store, _fast_fleet()).run()
    assert res2.n_cells_measured == 0 and res2.n_cells_resumed == 4


def test_inprocess_fleet_matches_serial_under_soft_faults(tmp_path):
    """Every cell's first attempt crashes (soft) — the retries converge to
    records bit-identical to the serial no-fault run."""
    spec, backend = _tiny_sweep(axes=("tuning", "dtype"))
    ref = _serial_reference(tmp_path, spec, backend)
    store = ResultStore(tmp_path / "fleet.jsonl")
    plan = FaultPlan(seed=0, p_crash=1.0, within_calls=1)
    res = FleetScheduler(spec, backend, store,
                         _fast_fleet(faults=plan)).run()
    assert not res.quarantined
    assert res.fleet["n_failed_attempts"] == 4    # one crash per cell
    assert _dump(store) == ref


def test_inprocess_fleet_quarantines_and_reports_poisoned_cells(tmp_path):
    """Seed 26 crashes cells 0 and 2 on *every* attempt: they quarantine
    (durably, with attempts and error), the others complete, and the
    surviving records still match the serial run — partial but honest."""
    spec, backend = _tiny_sweep(axes=("tuning", "dtype"))
    ref = _serial_reference(tmp_path, spec, backend)
    compiled = SweepScheduler(spec, backend).compile()
    fps = {cell.index: fp for cell, *_, fp in compiled}

    store = ResultStore(tmp_path / "fleet.jsonl")
    plan = FaultPlan(seed=26, p_crash=0.5, within_calls=1,
                     max_faulty_attempts=99)
    with pytest.warns(RuntimeWarning, match="quarantining sweep cell"):
        res = FleetScheduler(spec, backend, store,
                             _fast_fleet(faults=plan)).run()
    assert set(res.quarantined) == {0, 2} and res.degraded()
    for idx, info in res.quarantined.items():
        assert info["fingerprint"] == fps[idx]
        assert info["attempts"] == 3 and "CrashFault" in info["error"]
    assert sorted(c.cell.index for c in res.cells) == [1, 3]
    # the quarantine is durable and survives a fresh parse
    assert set(store.sweep_cells_failed(res.sweep_id)) == {0, 2}
    # all-or-nothing attempts: a quarantined cell leaves NO partial records
    got = _dump(store)
    for idx in (0, 2):
        assert fps[idx] not in got
    for idx in (1, 3):
        assert got[fps[idx]] == ref[fps[idx]]

    # recovery: resume without faults — quarantined cells are re-attempted,
    # success supersedes the quarantine, and the store now matches serial
    res2 = FleetScheduler(spec, backend, store, _fast_fleet()).run()
    assert res2.n_cells_measured == 2 and res2.n_cells_resumed == 2
    assert not res2.quarantined
    assert store.sweep_cells_failed(res2.sweep_id) == {}
    assert _dump(store) == ref


def test_fleet_requires_a_store():
    spec, backend = _tiny_sweep()
    with pytest.raises(ValueError, match="store is required"):
        FleetScheduler(spec, backend, None, _fast_fleet())


# ---------------------------------------------------------------------------
# FleetScheduler, multi-process chaos mode: the headline invariant
# ---------------------------------------------------------------------------

def test_chaos_fleet_store_is_record_identical_to_serial(tmp_path):
    """Three workers under injected hard crashes (real SIGKILL-equivalent
    ``os._exit`` mid-cell, torn shard tails included) and transient
    raises: the merged fleet store must be record-identical to the serial
    no-fault run, with zero quarantines and no silent serial fallback."""
    spec, backend = _tiny_sweep(axes=("tuning", "dtype"), n_launch_epochs=2,
                                nrep=8)
    ref = _serial_reference(tmp_path, spec, backend)
    store = ResultStore(tmp_path / "chaos.jsonl")
    plan = FaultPlan(seed=7, p_crash=0.5, p_raise=0.3, within_calls=2)
    cfg = FleetConfig(n_workers=3, lease_ttl=5.0, poll_s=0.02, faults=plan)
    res = FleetScheduler(spec, backend, store, cfg).run()
    assert not res.quarantined
    assert res.n_cells_measured == 4
    assert res.fleet["n_failed_attempts"] >= 1    # chaos actually struck
    assert _dump(store) == ref
    shard_dir = store.path.parent / (store.path.stem + "-shards")
    assert not shard_dir.exists()                 # shards were compacted


def test_fleet_survivable_torn_shard_lines_are_counted(tmp_path):
    """A torn line written *into* a successful worker's shard is skipped
    (with a warning) at merge time and surfaces in the fleet stats, not in
    the merged data."""
    spec, backend = _tiny_sweep(axes=("tuning",), n_launch_epochs=2, nrep=8)
    ref = _serial_reference(tmp_path, spec, backend)
    store = ResultStore(tmp_path / "torn.jsonl")
    plan = FaultPlan(seed=1, p_torn=1.0, within_calls=2)
    cfg = FleetConfig(n_workers=2, lease_ttl=5.0, poll_s=0.02, faults=plan)
    with pytest.warns(RuntimeWarning, match="undecodable"):
        res = FleetScheduler(spec, backend, store, cfg).run()
    assert res.fleet["n_corrupt_shard_lines"] == 2   # one per cell
    assert _dump(store) == ref                       # data unharmed


def test_fleet_straggler_loses_lease_and_cell_is_rerun(tmp_path):
    """A worker stalled past the lease TTL is killed and its cell re-run:
    the sweep completes correctly without waiting out the stall."""
    spec, backend = _tiny_sweep(axes=("tuning",), n_launch_epochs=2, nrep=8)
    ref = _serial_reference(tmp_path, spec, backend)
    store = ResultStore(tmp_path / "straggle.jsonl")
    plan = FaultPlan(seed=3, p_straggle=1.0, straggle_s=30.0,
                     within_calls=2)
    cfg = FleetConfig(n_workers=2, lease_ttl=0.8, poll_s=0.05, faults=plan)
    t0 = time.time()
    res = FleetScheduler(spec, backend, store, cfg).run()
    assert time.time() - t0 < 20                  # did not wait out 30s
    assert not res.quarantined
    assert res.fleet["n_failed_attempts"] >= 1    # a lease actually expired
    assert _dump(store) == ref


# ---------------------------------------------------------------------------
# Property: any byte prefix of the sweep store resumes identically,
# even with an active fault plan
# ---------------------------------------------------------------------------

_PREFIX_REF: dict = {}


def _prefix_reference():
    if not _PREFIX_REF:
        d = Path(tempfile.mkdtemp())
        spec, backend = _tiny_sweep()
        store = ResultStore(d / "ref.jsonl")
        SweepScheduler(spec, backend, store, n_workers=1).run()
        _PREFIX_REF["raw"] = store.path.read_bytes()
        _PREFIX_REF["dump"] = _dump(store)
    return _PREFIX_REF["raw"], _PREFIX_REF["dump"]


def _check_prefix_resume(cut: int):
    raw, ref = _prefix_reference()
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "cut.jsonl"
        path.write_bytes(raw[:cut])
        spec, backend = _tiny_sweep()
        plan = FaultPlan(seed=5, p_crash=1.0, within_calls=1)
        store = ResultStore(path)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # torn-tail warnings expected
            res = FleetScheduler(spec, backend, store,
                                 _fast_fleet(faults=plan)).run()
        assert not res.quarantined
        assert _dump(ResultStore(path)) == ref


def test_sampled_byte_prefixes_resume_identically_under_faults():
    """The deterministic always-runs slice of the property below: cut the
    sweep's JSONL at 0, mid-file bytes (mid-line included), one byte shy
    of the end, and the full length — every prefix, resumed through the
    fleet scheduler with crash faults active, converges to the identical
    serial store."""
    raw, _ = _prefix_reference()
    rng = np.random.default_rng(0)
    cuts = {0, len(raw), len(raw) - 1,
            *(int(c) for c in rng.integers(1, len(raw), size=5))}
    for cut in sorted(cuts):
        _check_prefix_resume(cut)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_any_byte_prefix_resumes_identically_under_faults(nonce):
    """Property form (hypothesis, when installed): an *arbitrary* byte
    prefix of the sweep store resumes identically under an active fault
    plan."""
    raw, _ = _prefix_reference()
    _check_prefix_resume(nonce % (len(raw) + 1))
