"""Factor-sweep subsystem: enumerable axes and grids (fingerprint
hygiene), the sharded/resumable scheduler, and the nonparametric
factor-impact analysis with its positive (injected defect) and negative
(dtype label) controls."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.campaign import (Campaign, CampaignSpec, ResultStore, SimBackend,
                            SweepScheduler, SweepSpec)
from repro.core import (ExperimentDesign, FactorAxis, FactorGrid, TestCase,
                        assert_comparable, capture_factors, compare_tables)
from repro.sweeps import (MISTUNED_PER_OP_KW, cells_from_result,
                          cells_from_store, default_sim_sweep,
                          format_factor_report, interaction_screen,
                          main_effects, sim_axes)

FAST_SYNC = dict(n_fitpts=60, n_exchanges=20)
ALL_AXIS_NAMES = tuple(ax.name for ax in sim_axes())


def _small_sweep(seed=0, axes=("tuning", "dtype"), n_launch_epochs=3,
                 nrep=20, msizes=(512,)):
    return default_sim_sweep(seed=seed, axes=axes, msizes=msizes,
                             n_launch_epochs=n_launch_epochs, nrep=nrep)


# ---------------------------------------------------------------------------
# Factor axes & grids
# ---------------------------------------------------------------------------

def test_axis_validation():
    with pytest.raises(ValueError, match="at least 2 levels"):
        FactorAxis("a", (1,))
    with pytest.raises(ValueError, match="target"):
        FactorAxis("a", (1, 2), target="nowhere")
    with pytest.raises(ValueError, match="labels"):
        FactorAxis("a", (1, 2), labels=("x",))
    with pytest.raises(ValueError, match="distinct"):
        FactorAxis("a", ({}, {}), labels=("x", "x"))


def test_grid_enumerates_full_cross_product():
    grid = FactorGrid(sim_axes(("tuning", "sync_method", "dtype")))
    assert grid.n_full() == 8 and len(grid) == 8
    cells = grid.cells()
    assert [c.index for c in cells] == list(range(8))
    seen = {tuple(sorted(c.levels().items())) for c in cells}
    assert len(seen) == 8


def test_grid_fractional_sampling_is_deterministic_and_nested():
    axes = sim_axes(("tuning", "sync_method", "window_us", "dtype"))
    full = FactorGrid(axes)
    half = FactorGrid(axes, design_seed=3, fraction=0.5)
    assert half.cell_indices() == FactorGrid(axes, design_seed=3,
                                             fraction=0.5).cell_indices()
    assert len(half) == 8 and set(half.cell_indices()) < set(
        full.cell_indices())
    assert half.cell_indices() != FactorGrid(axes, design_seed=4,
                                             fraction=0.5).cell_indices()
    # samples nest: raising the fraction only *adds* cells, so a persisted
    # fractional sweep keeps resuming after the fraction is raised
    quarter = FactorGrid(axes, design_seed=3, fraction=0.25)
    assert set(quarter.cell_indices()) < set(half.cell_indices())


def test_grid_cell_materializes_backend_and_design():
    grid = FactorGrid(sim_axes(("tuning", "shuffle")))
    base = SimBackend(p=4, seed0=1, sync_kw=dict(FAST_SYNC))
    design = ExperimentDesign(n_launch_epochs=2, nrep=5, seed=1)
    cell = grid.cells()[-1]          # tuning=mistuned, shuffle=False
    backend, dsn = cell.materialize(base, design)
    assert backend.per_op_kw == MISTUNED_PER_OP_KW
    assert dsn.shuffle is False and design.shuffle is True
    assert base.per_op_kw == {}      # the base objects are untouched


def test_grid_cell_bad_key_names_the_axis():
    grid = FactorGrid((FactorAxis("bogus", (1, 2), key="no_such_field"),
                       FactorAxis("dtype", ("float32", "float64"))))
    with pytest.raises(TypeError, match="no_such_field"):
        grid.cells()[0].materialize(SimBackend(), ExperimentDesign())


def test_all_stock_axes_yield_distinct_fingerprints():
    """The full 2^7 stock grid: every cell must map to its own store key,
    i.e. every axis level is reflected in the backend's FactorSet."""
    spec, backend = _small_sweep(axes=ALL_AXIS_NAMES)
    compiled = SweepScheduler(spec, backend).compile()
    fps = [fp for *_, fp in compiled]
    assert len(fps) == 2 ** len(ALL_AXIS_NAMES)
    assert len(set(fps)) == len(fps)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_grid_cells_distinct_fingerprints_property(seed):
    """Property: any subset of stock axes, any design seed — distinct
    grid cells always yield distinct factor fingerprints."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, len(ALL_AXIS_NAMES) + 1))
    names = tuple(rng.choice(ALL_AXIS_NAMES, size=k, replace=False))
    grid = FactorGrid(sim_axes(names), design_seed=seed)
    backend = SimBackend(p=4, seed0=seed, sync_kw=dict(FAST_SYNC))
    design = ExperimentDesign(n_launch_epochs=2, nrep=5, seed=seed)
    fps = [c.factors(backend, design).fingerprint() for c in grid.cells()]
    assert len(set(fps)) == len(fps)


def test_scheduler_rejects_fingerprint_collisions():
    """An axis whose levels the backend cannot express must fail loudly at
    compile time, not merge two experiments under one store key."""
    grid = FactorGrid((
        # ci_level is inert (and unfingerprinted) in fixed-nrep mode, so
        # its two "levels" collapse onto one factor set
        FactorAxis("ci_level", (0.95, 0.99), target="design"),
        FactorAxis("dtype", ("float32", "float64")),
    ))
    spec = SweepSpec(grid, [TestCase("allreduce", 256)], ExperimentDesign(2, 5))
    with pytest.raises(ValueError, match="share fingerprint"):
        SweepScheduler(spec, SimBackend(p=4, sync_kw=dict(FAST_SYNC))).compile()


# ---------------------------------------------------------------------------
# Factor-capture & comparability hygiene
# ---------------------------------------------------------------------------

def test_capture_failure_is_visible_in_factors(monkeypatch):
    """A degraded capture must record why, and must not fingerprint-match
    a healthy capture."""
    import jax

    healthy = capture_factors()

    def boom():
        raise RuntimeError("no backends")

    monkeypatch.setattr(jax, "default_backend", boom)
    degraded = capture_factors()
    assert degraded.backend == "unknown"
    reasons = dict(degraded.extra)
    assert "RuntimeError: no backends" in reasons["capture_failure"]
    assert degraded.fingerprint() != healthy.fingerprint()


def test_assert_comparable_names_exactly_the_differing_factors():
    a = capture_factors(sync_method="hca", dtype="float32")
    b = capture_factors(sync_method="skampi", dtype="float64")
    with pytest.raises(ValueError) as ei:
        assert_comparable(a, b, factor_under_test=("window_size_us",))
    msg = str(ei.value)
    assert "'sync_method'" in msg and "'dtype'" in msg
    for name in a.to_dict():
        if name not in ("sync_method", "dtype", "window_size_us"):
            assert f"'{name}'" not in msg, name
    # the declared factor under test is never reported as a conflict
    assert_comparable(a, b, factor_under_test=("sync_method", "dtype"))


# ---------------------------------------------------------------------------
# Sweep scheduler: execution, persistence, resume
# ---------------------------------------------------------------------------

def test_sweep_runs_and_persists(tmp_path):
    spec, backend = _small_sweep()
    store = ResultStore(tmp_path / "s.jsonl")
    res = SweepScheduler(spec, backend, store).run()
    assert len(res.cells) == 4
    assert res.n_cells_measured == 4 and res.n_cells_resumed == 0
    assert len({c.fingerprint for c in res.cells}) == 4
    assert store.sweeps() == [res.sweep_id]
    assert set(store.sweep_cells(res.sweep_id)) == {0, 1, 2, 3}
    man = store.sweep_manifest(res.sweep_id)
    assert [n["name"] for n in man["axes"]] == ["tuning", "dtype"]


def test_sweep_resume_measures_nothing(tmp_path, monkeypatch):
    spec, backend = _small_sweep()
    path = tmp_path / "s.jsonl"
    first = SweepScheduler(spec, backend, ResultStore(path)).run()

    calls = []
    orig = SimBackend.measure
    monkeypatch.setattr(
        SimBackend, "measure",
        lambda self, ctx, case, nrep: calls.append(case) or
        orig(self, ctx, case, nrep))
    again = SweepScheduler(spec, backend, ResultStore(path)).run()
    assert not calls
    assert again.n_cells_resumed == 4 and again.n_cells_measured == 0
    assert again.sweep_id == first.sweep_id
    for c0, c1 in zip(first.cells, again.cells):
        case = c0.table.cases()[0]
        np.testing.assert_array_equal(c0.table.medians(case),
                                      c1.table.medians(case))


def test_sweep_kill_resume_skips_completed_cells(tmp_path, monkeypatch):
    """The acceptance scenario: a sweep killed after two cells resumes
    without re-measuring them, and ends with the full run's results."""
    spec, backend = _small_sweep()
    path = tmp_path / "s.jsonl"
    full = SweepScheduler(spec, backend, ResultStore(path)).run()

    lines = path.read_text().splitlines()
    markers = [i for i, ln in enumerate(lines) if '"sweep-cell"' in ln]
    killed = tmp_path / "killed.jsonl"
    killed.write_text("\n".join(lines[:markers[1] + 1]) + "\n")

    calls = []
    orig = SimBackend.measure
    monkeypatch.setattr(
        SimBackend, "measure",
        lambda self, ctx, case, nrep: calls.append(case) or
        orig(self, ctx, case, nrep))
    res = SweepScheduler(spec, backend, ResultStore(killed)).run()
    assert res.n_cells_resumed == 2 and res.n_cells_measured == 2
    # only the two unfinished cells were measured: epochs x cases each
    d = spec.design
    assert len(calls) == 2 * d.n_launch_epochs * len(spec.cases)
    for c_full, c_res in zip(full.cells, res.cells):
        case = c_full.table.cases()[0]
        np.testing.assert_array_equal(c_full.table.medians(case),
                                      c_res.table.medians(case))


def test_sweep_parallel_matches_serial(tmp_path):
    spec, backend = _small_sweep()
    serial = SweepScheduler(spec, backend).run()
    store = ResultStore(tmp_path / "p.jsonl")
    par = SweepScheduler(spec, backend, store, n_workers=2).run()
    assert par.n_cells_measured == 4
    assert set(store.sweep_cells(par.sweep_id)) == {0, 1, 2, 3}
    for cs, cp in zip(serial.cells, par.cells):
        case = cs.table.cases()[0]
        np.testing.assert_array_equal(cs.table.medians(case),
                                      cp.table.medians(case))


def test_sweep_cell_round_trips_against_standalone_campaign(tmp_path):
    """A sweep cell's stored results are the *same experiment* as a
    standalone campaign built from the cell's own factors — they share a
    fingerprint and compare_tables sees identical distributions."""
    spec, backend = _small_sweep()
    store = ResultStore(tmp_path / "s.jsonl")
    res = SweepScheduler(spec, backend, store).run()

    cell_res = res.cells[-1]                     # the mistuned cell
    cell_backend, cell_design = cell_res.cell.materialize(backend,
                                                          spec.design)
    alone = ResultStore(tmp_path / "alone.jsonl")
    standalone = Campaign(CampaignSpec(spec.cases, cell_design, name="alone"),
                          cell_backend, alone).run()
    assert standalone.fingerprint == cell_res.fingerprint

    rows = compare_tables(store.to_table(cell_res.fingerprint),
                          alone.to_table(standalone.fingerprint))
    assert len(rows) == len(spec.cases)
    for row in rows:
        assert row.ratio == pytest.approx(1.0)
        assert row.verdict == "indistinguishable"


def test_sweep_fraction_raise_resumes_nested_cells(tmp_path, monkeypatch):
    """Raising a fractional grid's fraction re-declares a new sweep
    manifest, but the nested cells' measurements are the same experiments
    — they must resume, not re-measure."""
    from dataclasses import replace

    spec, backend = _small_sweep(axes=("tuning", "sync_method", "dtype"))
    half = replace(spec, grid=replace(spec.grid, fraction=0.5))
    path = tmp_path / "s.jsonl"
    first = SweepScheduler(half, backend, ResultStore(path)).run()
    assert first.n_cells_measured == 4

    calls = []
    orig = SimBackend.measure
    monkeypatch.setattr(
        SimBackend, "measure",
        lambda self, ctx, case, nrep: calls.append(case) or
        orig(self, ctx, case, nrep))
    full = SweepScheduler(spec, backend, ResultStore(path)).run()
    assert full.sweep_id != first.sweep_id
    assert full.n_cells_resumed == 4 and full.n_cells_measured == 4
    d = spec.design
    assert len(calls) == 4 * d.n_launch_epochs * len(spec.cases)
    # the resumed cells got markers under the new sweep id too
    assert len(ResultStore(path).sweep_cells(full.sweep_id)) == 8


def test_serial_fallback_skips_cells_persisted_by_parallel(tmp_path):
    """If the pool dies after persisting some cells, the serial fallback
    must load them from the (snapshot-coherent) store, not duplicate
    their records."""
    spec, backend = _small_sweep()
    path = tmp_path / "s.jsonl"
    store = ResultStore(path)
    sched = SweepScheduler(spec, backend, store)
    compiled = sched.compile()
    snapshot = store.snapshot()
    manifest = dict(spec.grid.manifest(), name=spec.name, cases=[],
                    cells=[[c.index, fp, c.levels()]
                           for c, _, _, _, fp in compiled])
    sweep_id = store.append_sweep(manifest, snapshot=snapshot)

    # simulate the parallel path persisting cell 0, then the pool dying:
    # run the serial fallback over the full pending list
    cell, cbackend, design, factors, fp = compiled[0]
    res = Campaign(spec.cell_spec(cell, design), cbackend).run()
    store.append_campaign(factors, snapshot=snapshot)
    for rec in res.records:
        store.append_record(fp, rec)
        snapshot.records.setdefault(fp, []).append(rec)
    store.append_sweep_cell(sweep_id, cell.index, fp)
    snapshot.sweep_cells_by_id.setdefault(sweep_id, {})[cell.index] = fp

    out = sched._run_serial(compiled, sweep_id, snapshot)
    assert out[0].n_measured == 0 and out[0].n_resumed == len(res.records)
    assert all(out[i].n_measured > 0 for i in range(1, 4))
    # no duplicate records for the pre-persisted cell
    assert len(ResultStore(path).records(fp)) == len(res.records)


def test_make_sync_rejects_mislabeled_hca_variant():
    from repro.core import make_sync

    assert make_sync("hca").hierarchical_intercepts is False
    assert make_sync("hca2").hierarchical_intercepts is True
    with pytest.raises(TypeError, match="implied by the algorithm name"):
        make_sync("hca", hierarchical_intercepts=True)


def test_sweep_records_carry_host(tmp_path):
    import platform

    spec, backend = _small_sweep()
    store = ResultStore(tmp_path / "s.jsonl")
    res = SweepScheduler(spec, backend, store).run()
    recs = store.records(res.cells[0].fingerprint)
    assert all(r.meta.get("host") == platform.node() for r in recs)
    rows = store.to_table(res.cells[0].fingerprint).to_rows()
    assert all(r["host"] == platform.node() for r in rows)


# ---------------------------------------------------------------------------
# Factor-impact analysis
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def impact_sweep(tmp_path_factory):
    spec, backend = _small_sweep(axes=("tuning", "sync_method", "dtype"),
                                 n_launch_epochs=5, nrep=30,
                                 msizes=(512, 4096))
    store = ResultStore(tmp_path_factory.mktemp("sweep") / "s.jsonl")
    return SweepScheduler(spec, backend, store).run(), store


def test_injected_factor_ranks_top_and_dtype_stays_null(impact_sweep):
    res, _ = impact_sweep
    effects = main_effects(cells_from_result(res))
    top = effects[0]
    assert top.axis == "tuning" and top.significant
    assert top.levels == ("mistuned", "stock")
    assert top.effect_size > 0.9
    dtype = [e for e in effects if e.axis == "dtype"][0]
    assert not dtype.significant
    assert dtype.effect_size == pytest.approx(0.0, abs=1e-12)
    assert effects[-1].axis == "dtype"


def test_effects_from_store_match_in_memory(impact_sweep):
    res, store = impact_sweep
    eff_mem = main_effects(cells_from_result(res))
    eff_disk = main_effects(cells_from_store(store))
    assert [e.axis for e in eff_mem] == [e.axis for e in eff_disk]
    for a, b in zip(eff_mem, eff_disk):
        assert a.p_holm == pytest.approx(b.p_holm)
        assert a.effect_size == pytest.approx(b.effect_size)


def test_pairwise_effects_are_directional(impact_sweep):
    res, _ = impact_sweep
    effects = main_effects(cells_from_result(res))
    pair = effects[0].pairs[0]
    assert pair.slower == "mistuned" and pair.faster == "stock"
    assert pair.p_holm <= 0.05 and pair.delta > 0.9


def test_interaction_screen_and_report_format(impact_sweep):
    res, _ = impact_sweep
    cells = cells_from_result(res)
    effects = main_effects(cells)
    inter = interaction_screen(cells)
    assert len(inter) == 3                      # 3 axis pairs
    assert all(0.0 <= it.score <= 2.0 for it in inter)
    report = format_factor_report(effects, inter)
    lines = report.splitlines()
    assert lines[1].split()[0] == "factor"
    assert lines[2].split()[0] == "tuning"      # ranked first
    assert "MATTERS" in lines[2]
    assert "dtype" in report and "factors matter" in report


def test_analysis_rejects_single_level_axis():
    from repro.sweeps.effects import CellData

    cells = [CellData(0, {"a": "x"}, {("op", 1): np.ones(3)}),
             CellData(1, {"a": "x"}, {("op", 1): np.ones(3) * 2})]
    with pytest.raises(ValueError, match="single level"):
        main_effects(cells)
