"""The benchmarks.run subcommand CLI: legacy-flag shim, argv mapping,
and the flag validation that guards the budgeted-sweep plumbing."""

import json
import warnings

import pytest

from benchmarks.run import SUBCOMMANDS, _legacy_argv, main


def _map_silently(argv):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return _legacy_argv(argv)


@pytest.mark.parametrize("argv,expected", [
    # suite flags with no mode flag -> run subcommand
    (["--list"], ["run", "--list"]),
    (["--only", "micro", "--json", "f.json"],
     ["run", "--only", "micro", "--json", "f.json"]),
    # mode flags -> their subcommand, flag removed
    (["--sweep", "--axes", "tuning,dtype", "--store", "s.jsonl"],
     ["sweep", "--axes", "tuning,dtype", "--store", "s.jsonl"]),
    (["--fleet", "3", "--sweep", "--store", "s.jsonl"],
     ["sweep", "--fleet", "3", "--store", "s.jsonl"]),
    (["--audit", "--archive", "runs", "--baseline", "ref"],
     ["audit", "--archive", "runs", "--baseline", "ref"]),
    (["--compare", "a.jsonl", "b.jsonl"], ["compare", "a.jsonl", "b.jsonl"]),
    # guidelines: --only meant the backend there, becomes --backend
    (["--guidelines", "--only", "kernel"],
     ["guidelines", "--backend", "kernel"]),
    (["--guidelines"], ["guidelines"]),
])
def test_legacy_argv_mapping(argv, expected):
    assert _map_silently(argv) == expected


def test_legacy_argv_passes_subcommands_through_unchanged():
    for cmd in SUBCOMMANDS:
        argv = [cmd, "--whatever", "x"]
        # no warning and no rewrite for the modern spelling
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            assert _legacy_argv(argv) == argv
            assert _legacy_argv([]) == ["run"]
            assert _legacy_argv(["--help"]) == ["--help"]


def test_legacy_argv_warns_deprecation():
    with pytest.deprecated_call(match="subcommand form"):
        _legacy_argv(["--list"])
    with pytest.deprecated_call(match="python -m benchmarks.run sweep"):
        _legacy_argv(["--sweep", "--axes", "tuning"])


def test_legacy_invocation_still_runs(capsys):
    with pytest.deprecated_call():
        main(["--list"])
    assert "bench_micro_sweeps" in capsys.readouterr().out


def test_legacy_warning_points_at_caller(capsys):
    """The DeprecationWarning's source location must be main()'s caller
    (this file), not a frame inside benchmarks.run — that location is
    what shows up in CI logs telling people *their* invocation to fix."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        main(["--list"])
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
           and "subcommand form" in str(w.message)]
    assert len(dep) == 1
    assert dep[0].filename == __file__, (
        f"legacy-CLI warning attributed to {dep[0].filename}, "
        f"expected {__file__}")


def test_run_list_subcommand(capsys):
    main(["run", "--list"])
    out = capsys.readouterr().out
    assert "bench_table1_variability" in out
    assert "bench_micro_sweeps" in out


@pytest.mark.parametrize("argv,msg", [
    (["sweep", "--policy", "racing"], "--policy needs --store"),
    (["sweep", "--budget", "100"], "--budget only makes sense"),
    (["sweep", "--faults", "crash=0.5"], "--faults only makes sense"),
    (["sweep", "--fleet", "2"], "--fleet needs --store"),
    (["run", "--seed", "-1"], "--seed must be"),
])
def test_flag_validation(argv, msg, capsys):
    with pytest.raises(SystemExit) as exc:
        main(argv)
    assert exc.value.code == 2
    assert msg in capsys.readouterr().err


def test_calibrate_subcommand_end_to_end(tmp_path, capsys):
    """The calibration loop through the real CLI: sim-as-target, tiny
    grid; must archive the store under the calibrated tag and exit 0
    (no DRIFTED held-out cell)."""
    archive = tmp_path / "arch"
    main(["calibrate", "--target", "sim", "--archive", str(archive),
          "--params", "op.alpha", "--rounds", "2", "--epochs", "6",
          "--nrep", "15", "--p", "4"])
    cap = capsys.readouterr()
    captured = cap.out + cap.err
    from repro.history import RunArchive
    entries = RunArchive(archive).entries()
    assert len(entries) == 1 and entries[0].tag == "calibrated"
    assert len(RunArchive(archive).calibrations()) == 1
    assert "calibration certification" in captured or "# fitted" in captured


def test_calibrate_rejects_unknown_param(tmp_path, capsys):
    with pytest.raises(SystemExit) as exc:
        main(["calibrate", "--archive", str(tmp_path / "a"),
              "--params", "op.nope"])
    assert exc.value.code == 2
    assert "unknown params" in capsys.readouterr().err


def test_missing_trajectory_artifacts(tmp_path):
    """check_regression must surface BENCH_PR*.json files the perf log
    references but that were never committed — a silently thinning
    trajectory used to pass without a word."""
    from benchmarks.check_regression import missing_trajectory_artifacts

    changes = tmp_path / "CHANGES.md"
    bench = tmp_path / "benchmarks"
    bench.mkdir()
    changes.write_text("committed BENCH_PR7.json; later BENCH_PR9.json\n")
    (bench / "BENCH_PR7.json").write_text("{}")
    assert missing_trajectory_artifacts(str(changes), str(bench)) \
        == ["BENCH_PR9.json"]
    # no log at all -> nothing referenced -> nothing missing
    assert missing_trajectory_artifacts(str(tmp_path / "nope.md"),
                                        str(bench)) == []
    # the real repo's trajectory must currently be hole-free
    import os

    import benchmarks.check_regression as cr
    bdir = os.path.dirname(os.path.abspath(cr.__file__))
    assert missing_trajectory_artifacts(
        os.path.join(os.path.dirname(bdir), "CHANGES.md"), bdir) == []


def test_sweep_policy_end_to_end(tmp_path, capsys):
    """The budgeted path through the real CLI: racing on the smoke grid,
    verdicts JSON written, allocation summary on stderr."""
    store = tmp_path / "s.jsonl"
    verdicts = tmp_path / "v.json"
    main(["sweep", "--axes", "tuning,dtype", "--store", str(store),
          "--policy", "racing", "--verdicts", str(verdicts)])
    err = capsys.readouterr().err
    assert "# alloc: policy=racing" in err
    data = json.loads(verdicts.read_text())
    assert data["axes"]["tuning"] == "MATTERS"
    assert data["axes"]["dtype"] == "null"
    assert data["alloc"]["savings"] > 1.0
