"""The benchmarks.run subcommand CLI: legacy-flag shim, argv mapping,
and the flag validation that guards the budgeted-sweep plumbing."""

import json
import warnings

import pytest

from benchmarks.run import SUBCOMMANDS, _legacy_argv, main


def _map_silently(argv):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return _legacy_argv(argv)


@pytest.mark.parametrize("argv,expected", [
    # suite flags with no mode flag -> run subcommand
    (["--list"], ["run", "--list"]),
    (["--only", "micro", "--json", "f.json"],
     ["run", "--only", "micro", "--json", "f.json"]),
    # mode flags -> their subcommand, flag removed
    (["--sweep", "--axes", "tuning,dtype", "--store", "s.jsonl"],
     ["sweep", "--axes", "tuning,dtype", "--store", "s.jsonl"]),
    (["--fleet", "3", "--sweep", "--store", "s.jsonl"],
     ["sweep", "--fleet", "3", "--store", "s.jsonl"]),
    (["--audit", "--archive", "runs", "--baseline", "ref"],
     ["audit", "--archive", "runs", "--baseline", "ref"]),
    (["--compare", "a.jsonl", "b.jsonl"], ["compare", "a.jsonl", "b.jsonl"]),
    # guidelines: --only meant the backend there, becomes --backend
    (["--guidelines", "--only", "kernel"],
     ["guidelines", "--backend", "kernel"]),
    (["--guidelines"], ["guidelines"]),
])
def test_legacy_argv_mapping(argv, expected):
    assert _map_silently(argv) == expected


def test_legacy_argv_passes_subcommands_through_unchanged():
    for cmd in SUBCOMMANDS:
        argv = [cmd, "--whatever", "x"]
        # no warning and no rewrite for the modern spelling
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            assert _legacy_argv(argv) == argv
            assert _legacy_argv([]) == ["run"]
            assert _legacy_argv(["--help"]) == ["--help"]


def test_legacy_argv_warns_deprecation():
    with pytest.deprecated_call(match="subcommand form"):
        _legacy_argv(["--list"])
    with pytest.deprecated_call(match="python -m benchmarks.run sweep"):
        _legacy_argv(["--sweep", "--axes", "tuning"])


def test_legacy_invocation_still_runs(capsys):
    with pytest.deprecated_call():
        main(["--list"])
    assert "bench_micro_sweeps" in capsys.readouterr().out


def test_run_list_subcommand(capsys):
    main(["run", "--list"])
    out = capsys.readouterr().out
    assert "bench_table1_variability" in out
    assert "bench_micro_sweeps" in out


@pytest.mark.parametrize("argv,msg", [
    (["sweep", "--policy", "racing"], "--policy needs --store"),
    (["sweep", "--budget", "100"], "--budget only makes sense"),
    (["sweep", "--faults", "crash=0.5"], "--faults only makes sense"),
    (["sweep", "--fleet", "2"], "--fleet needs --store"),
    (["run", "--seed", "-1"], "--seed must be"),
])
def test_flag_validation(argv, msg, capsys):
    with pytest.raises(SystemExit) as exc:
        main(argv)
    assert exc.value.code == 2
    assert msg in capsys.readouterr().err


def test_sweep_policy_end_to_end(tmp_path, capsys):
    """The budgeted path through the real CLI: racing on the smoke grid,
    verdicts JSON written, allocation summary on stderr."""
    store = tmp_path / "s.jsonl"
    verdicts = tmp_path / "v.json"
    main(["sweep", "--axes", "tuning,dtype", "--store", str(store),
          "--policy", "racing", "--verdicts", str(verdicts)])
    err = capsys.readouterr().err
    assert "# alloc: policy=racing" in err
    data = json.loads(verdicts.read_text())
    assert data["axes"]["tuning"] == "MATTERS"
    assert data["axes"]["dtype"] == "null"
    assert data["alloc"]["savings"] > 1.0
