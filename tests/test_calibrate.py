"""The sim↔real calibration loop: parameter surface, fitting engine,
held-out TOST certification, and the calib/calib-round store plumbing
that makes a killed fit resumable.

The fast tier exercises the machinery end to end with tiny designs
(sim-as-target, 1-2 knobs); the ``slow`` tier holds the soundness pins —
parameter recovery against a known truth, self-calibration EQUIVALENT,
and the frozen mis-fit positive control that must come back DRIFTED.
"""

import json

import numpy as np
import pytest

from repro.calibrate import (CALIBRATED_TAG, CalibrationParam,
                             CalibrationSpace, calibrate, certify_heldout,
                             default_space)
from repro.campaign import Campaign, CampaignSpec, ResultStore, SimBackend
from repro.core import ExperimentDesign, TestCase
from repro.history import RunArchive

FAST_SYNC = dict(n_fitpts=100, n_exchanges=20)


def _base(seed0=0, **kw):
    kw.setdefault("sync_kw", dict(FAST_SYNC))
    return SimBackend(p=4, seed0=seed0, **kw)


def _design(**kw):
    kw.setdefault("n_launch_epochs", 8)
    kw.setdefault("nrep", 20)
    kw.setdefault("seed", 3)
    return ExperimentDesign(**kw)


CASES = [TestCase("allreduce", 512), TestCase("bcast", 512)]


# ---------------------------------------------------------------------------
# CalibrationSpace: the declarative parameter surface


def test_param_rejects_typoed_field():
    # a typo'd knob would otherwise "fit" by never changing anything
    with pytest.raises(ValueError, match="not a SimCollective field"):
        CalibrationParam("op.noise_sgima", 0.0, 1.0)
    with pytest.raises(ValueError, match="not a ClockParams field"):
        CalibrationParam("clock.rw_sgima", 0.0, 1.0)


def test_param_rejects_malformed_names_and_bounds():
    with pytest.raises(ValueError, match="name must be"):
        CalibrationParam("noise_sigma", 0.0, 1.0)   # no prefix
    with pytest.raises(ValueError, match="name must be"):
        CalibrationParam("op.alpha.extra", 0.0, 1.0)
    with pytest.raises(ValueError, match="lo < hi"):
        CalibrationParam("op.alpha", 1.0, 1.0)
    with pytest.raises(ValueError, match="init"):
        CalibrationParam("op.alpha", 0.0, 1.0, init=2.0)


def test_param_clip_snaps_to_resolution():
    p = CalibrationParam("op.noise_sigma", 0.0, 1.0, resolution=0.01)
    assert p.clip(0.123456) == pytest.approx(0.12)
    assert p.clip(-5.0) == 0.0
    assert p.clip(5.0) == 1.0


def test_space_materialize_routes_all_three_kinds():
    space = CalibrationSpace(
        params=(CalibrationParam("op.noise_sigma", 0.0, 0.5),
                CalibrationParam("per_op.bcast.alpha", 1e-6, 9e-6),
                CalibrationParam("clock.rw_sigma", 0.0, 1e-6)),
        base=_base())
    b = space.materialize({"op.noise_sigma": 0.1,
                           "per_op.bcast.alpha": 4e-6,
                           "clock.rw_sigma": 2e-7})
    assert b.op_kw["noise_sigma"] == pytest.approx(0.1)
    assert b.per_op_kw["bcast"]["alpha"] == pytest.approx(4e-6)
    assert b.clock_kw["rw_sigma"] == pytest.approx(2e-7)
    # the base backend is untouched (dataclass replacement, not mutation)
    assert "noise_sigma" not in space.base.op_kw


def test_space_distinct_points_distinct_fingerprints():
    space = default_space(base=_base(), names=["op.noise_sigma"])
    design = _design()
    fp = lambda b: b.factors(design).fingerprint()  # noqa: E731
    assert fp(space.materialize({"op.noise_sigma": 0.05})) \
        != fp(space.materialize({"op.noise_sigma": 0.06}))
    # same point (after resolution snap) -> same fingerprint: resume works
    assert fp(space.materialize({"op.noise_sigma": 0.05})) \
        == fp(space.materialize({"op.noise_sigma": 0.05 + 1e-13}))


def test_default_space_subset_and_unknown():
    space = default_space(names=["op.alpha", "clock.rw_sigma"])
    assert space.names() == ["op.alpha", "clock.rw_sigma"]
    with pytest.raises(ValueError, match="unknown params"):
        default_space(names=["op.nope"])
    with pytest.raises(KeyError, match="unknown params"):
        default_space(names=["op.alpha"]).clip({"op.beta": 1.0})


def test_default_space_latency_scale_widens_alpha_gamma_only():
    # a dispatch-heavy real target (jax pmap: hundreds of µs/call) needs
    # wider absolute-latency bounds; the relative noise knobs must not move
    ref = {p.name: p for p in default_space().params}
    wide = {p.name: p for p in default_space(latency_scale=100.0).params}
    assert wide["op.alpha"].hi == pytest.approx(100 * ref["op.alpha"].hi)
    assert wide["op.gamma"].hi == pytest.approx(100 * ref["op.gamma"].hi)
    assert wide["op.noise_sigma"].hi == ref["op.noise_sigma"].hi
    assert wide["op.tail_prob"].hi == ref["op.tail_prob"].hi
    with pytest.raises(ValueError, match="latency_scale"):
        default_space(latency_scale=0)


# ---------------------------------------------------------------------------
# calib / calib-round store lines


def test_store_calib_lines_roundtrip(tmp_path):
    store = ResultStore(tmp_path / "c.jsonl")
    cid = store.append_calib(dict(name="x", space={"p": 1}))
    # idempotent on content: same manifest -> same id, no duplicate line
    assert store.append_calib(dict(name="x", space={"p": 1})) == cid
    store.append_calib_round(cid, 0, {"op.alpha": 2e-6}, 0.5, 0.25,
                             [[{"op.alpha": 2e-6}, 0.5]], 100)
    store.append_calib_round(cid, 1, {"op.alpha": 3e-6}, 0.3, 0.25, [], 200)
    # a torn/duplicated round line must not fork the replay trajectory
    store.append_calib_round(cid, 1, {"op.alpha": 9e-6}, 9.9, 0.9, [], 999)
    rounds = store.calib_rounds(cid)
    assert [r["round"] for r in rounds] == [0, 1]
    assert rounds[1]["objective"] == pytest.approx(0.3)  # first wins
    assert store.calib_manifest(cid)["name"] == "x"
    snap = store.snapshot()
    assert [r["round"] for r in snap.calib_rounds_by_id[cid]] == [0, 1]


def test_store_jsonable_recurses_into_containers(tmp_path):
    """Regression: numpy scalars nested inside dicts/lists/tuples used to
    reach json.dump unconverted and crash (or round-trip as repr strings
    via the fallback)."""
    store = ResultStore(tmp_path / "m.jsonl")
    store.append_meta(nested=dict(
        a=np.float64(1.5), b=[np.int64(2), (np.bool_(True),)],
        c={"deep": {"arr": np.arange(3)}}))
    with open(store.path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    meta = [ln for ln in lines if ln["kind"] == "meta"][0]
    assert meta["nested"] == dict(a=1.5, b=[2, [True]],
                                  c={"deep": {"arr": [0, 1, 2]}})
    # and the store's own reader agrees
    assert store.meta()["nested"]["c"]["deep"]["arr"] == [0, 1, 2]


# ---------------------------------------------------------------------------
# calibrate(): guards and end-to-end behavior (tiny designs)


def test_calibrate_requires_store():
    with pytest.raises(ValueError, match="store is required"):
        calibrate(default_space(base=_base(), names=["op.alpha"]),
                  _base(seed0=99))


def test_calibrate_rejects_shared_seed0(tmp_path):
    # same seed0 on both sides would fit one noise realization, not the
    # distribution
    with pytest.raises(ValueError, match="share seed0"):
        calibrate(default_space(base=_base(seed0=7), names=["op.alpha"]),
                  _base(seed0=7), cases=CASES, design=_design(),
                  store=ResultStore(tmp_path / "s.jsonl"))


def test_calibrate_needs_heldout_epochs(tmp_path):
    with pytest.raises(ValueError, match="n_fit_epochs"):
        calibrate(default_space(base=_base(), names=["op.alpha"]),
                  _base(seed0=99), cases=CASES,
                  design=_design(n_launch_epochs=4), n_fit_epochs=3,
                  store=ResultStore(tmp_path / "s.jsonl"))


def _fit_small(tmp_path, stem="a", **kw):
    """One tiny but complete fit: sim truth with a shifted alpha, one-knob
    space, archived."""
    truth = _base(seed0=1009, op_kw=dict(alpha=6e-6))
    space = default_space(base=_base(seed0=0), names=["op.alpha"])
    archive = RunArchive(tmp_path / f"arch-{stem}")
    store = ResultStore(tmp_path / f"store-{stem}.jsonl")
    kw.setdefault("design", _design())
    kw.setdefault("max_rounds", 3)
    res = calibrate(space, truth, cases=CASES, store=store, archive=archive,
                    seed=3, **kw)
    return res, store, archive


def test_calibrate_end_to_end_archives_and_reports(tmp_path):
    res, store, archive = _fit_small(tmp_path)
    assert res.report is not None and res.verdict != "UNCERTIFIED"
    assert not any(c.verdict == "DRIFTED" for c in res.report.cells)
    assert len(res.rounds) >= 1 and res.n_rounds_resumed == 0
    # objective trace is monotone non-increasing (first-improvement descent)
    objs = [r["objective"] for r in res.rounds]
    assert all(b <= a + 1e-12 for a, b in zip(objs, objs[1:]))
    # archived under the calibrated tag, report in the manifest
    assert res.run_entry.tag == CALIBRATED_TAG
    reports = archive.calibrations(res.run_entry.run_id)
    assert len(reports) == 1
    assert reports[0]["report"]["params"] == res.params
    # the report is also stamped on the store (excluded from content id)
    assert store.meta()["calibration"]["calib"] == res.calib_id


def test_calibrate_kill_resume_replays_identically(tmp_path):
    """Kill the fit after its first persisted round; the resumed fit must
    replay the round (not re-decide it) and converge to the identical
    params, objective, and store content."""
    res_full, store_full, _ = _fit_small(tmp_path, stem="full")

    # rebuild a "killed" store: everything up to and including the first
    # calib-round line, truncated at a line boundary
    with open(store_full.path) as f:
        lines = f.readlines()
    first_round = next(i for i, ln in enumerate(lines)
                       if json.loads(ln).get("kind") == "calib-round")
    killed = tmp_path / "store-killed.jsonl"
    killed.write_text("".join(lines[:first_round + 1]))

    truth = _base(seed0=1009, op_kw=dict(alpha=6e-6))
    space = default_space(base=_base(seed0=0), names=["op.alpha"])
    res2 = calibrate(space, truth, cases=CASES, design=_design(),
                     max_rounds=3, seed=3,
                     store=ResultStore(killed),
                     archive=RunArchive(tmp_path / "arch-resumed"))
    assert res2.n_rounds_resumed == 1
    assert res2.params == res_full.params
    assert res2.objective == pytest.approx(res_full.objective)
    assert res2.verdict == res_full.verdict

    def content(path):
        with open(path) as f:
            return [ln for ln in f
                    if json.loads(ln).get("kind") != "meta"]
    # byte-compatible replay: identical non-meta line sequences (run ids
    # still differ — the archive hashes the store's relative path in)
    assert content(killed) == content(store_full.path)


def test_calibrate_budget_stops_early(tmp_path):
    res, _, _ = _fit_small(tmp_path, stem="budget", budget=1)
    assert len(res.rounds) == 1          # checked at round boundaries
    assert res.spent_nrep >= 1


# ---------------------------------------------------------------------------
# soundness tier: recovery, self-calibration, positive control


@pytest.mark.slow
def test_parameter_recovery_within_tolerance(tmp_path):
    """Fit against a sim truth with a known shifted alpha: the fitted
    value must land within 10% of the truth and certify EQUIVALENT."""
    truth_alpha = 6e-6
    truth = _base(seed0=1009, op_kw=dict(alpha=truth_alpha))
    space = default_space(base=_base(seed0=0), names=["op.alpha"])
    store = ResultStore(tmp_path / "rec.jsonl")
    res = calibrate(space, truth, cases=CASES,
                    design=_design(n_launch_epochs=24, nrep=30),
                    store=store, seed=3, max_rounds=8)
    assert res.params["op.alpha"] == pytest.approx(truth_alpha, rel=0.10)
    assert res.verdict == "EQUIVALENT"


@pytest.mark.slow
def test_self_calibration_is_equivalent(tmp_path):
    """Target and base share every noise parameter (different seed0): the
    fit has nothing to move, and certification must say EQUIVALENT —
    the procedure's null case."""
    res = calibrate(
        default_space(base=_base(seed0=0), names=["op.noise_sigma"]),
        _base(seed0=4242), cases=CASES,
        design=_design(n_launch_epochs=24, nrep=30),
        store=ResultStore(tmp_path / "self.jsonl"), seed=5)
    assert res.verdict == "EQUIVALENT"


@pytest.mark.slow
def test_frozen_misfit_is_drifted_positive_control(tmp_path):
    """A deliberately mis-tuned frozen candidate (4x latency term) pushed
    through the same certification path must come back DRIFTED — if it
    does not, the certificate can never be trusted to fail."""
    design = _design(n_launch_epochs=24, nrep=30)
    store = ResultStore(tmp_path / "ctl.jsonl")
    target = _base(seed0=1009)
    misfit = _base(seed0=0, op_kw=dict(alpha=12e-6, gamma=6e-6))
    t_res = Campaign(CampaignSpec(CASES, design, name="ctl/target"),
                     target, store).run()
    m_res = Campaign(CampaignSpec(CASES, design, name="ctl/misfit"),
                     misfit, store).run()
    report = certify_heldout(t_res.records, m_res.records,
                             n_fit_epochs=16, design=design, seed=5)
    assert not report.ok
    assert any(c.verdict == "DRIFTED" for c in report.cells)
