"""Scalar <-> vectorized engine equivalence (the batch-engine contract).

The vectorized measurement engine must be indistinguishable from the
scalar semantic reference:

  * *exactly* (up to float-associativity noise, ~1e-12 s on second-scale
    timelines) when the noise samples are deterministic, which isolates
    the closed-form window scheduling and clock conversion;
  * *statistically* (Wilcoxon on the measured distributions) when the RNG
    is live, because batched draws consume the stream in a different order
    than interleaved scalar draws.

Also covers: epoch-parallel ``run_design`` reproducing serial records
bit-for-bit, the weakref epoch-bias cache, and the grouped ``ResultTable``
index.
"""

import gc
import warnings

import numpy as np
import pytest

from repro.core import (
    EpochSummary,
    ExperimentDesign,
    ResultTable,
    SimNet,
    TestCase,
    make_op,
    make_sync,
    run_design,
    run_windowed,
    wilcoxon_rank_sum,
)
from repro.campaign import FunctionBackend
from repro.core.design import analyze_records
from repro.core.mpi_ops import _ar1_filter
from repro.core.window import run_windowed_scalar

NOISE_FREE = dict(noise_sigma=0.0, tail_prob=0.0, spike_prob=0.0,
                  rank_imbalance=0.0, epoch_bias_sigma=0.0, autocorr=0.0)
SYNC_KW = dict(n_fitpts=100, n_exchanges=20)


def _synced(seed, p=8):
    net = SimNet(p, seed=seed)
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    return net, sync


# ---------------------------------------------------------------------------
# AR(1) closed form
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("coeff", [0.0, 0.35, 0.9, -0.5, 0.999])
def test_ar1_filter_matches_scalar_recurrence(coeff):
    rng = np.random.default_rng(3)
    eps = rng.normal(0.0, 0.04, size=4000)
    state = 0.7
    ref = np.empty(eps.size)
    s = state
    for i in range(eps.size):
        s = coeff * s + eps[i]
        ref[i] = s
    out = _ar1_filter(eps, coeff, state)
    np.testing.assert_allclose(out, ref, rtol=1e-10, atol=1e-14)


# ---------------------------------------------------------------------------
# run_windowed: batch vs scalar
# ---------------------------------------------------------------------------

def test_windowed_batch_exact_when_noise_free():
    """With deterministic noise the two engines compute the *same campaign*:
    same times, same error flags, same ground-truth timelines, same final
    simulator state — the closed-form scheduling is exact."""
    op_a = make_op("allreduce", **NOISE_FREE)
    op_b = make_op("allreduce", **NOISE_FREE)
    net_a, sync_a = _synced(5, p=16)
    net_b, sync_b = _synced(5, p=16)
    a = run_windowed_scalar(net_a, sync_a, op_a, 4096, 400, 300e-6)
    b = run_windowed(net_b, sync_b, op_b, 4096, 400, 300e-6, engine="batch")
    np.testing.assert_allclose(a.times, b.times, rtol=0, atol=1e-12)
    assert np.array_equal(a.errors, b.errors)
    np.testing.assert_allclose(a.start_true, b.start_true, rtol=0, atol=1e-12)
    np.testing.assert_allclose(a.end_true, b.end_true, rtol=0, atol=1e-12)
    np.testing.assert_allclose(a.start_global_est, b.start_global_est,
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(net_a.t, net_b.t, rtol=0, atol=1e-12)


def test_windowed_batch_exact_with_tight_windows():
    """Noise-free but with a window too small for the op: both engines must
    flag the same observations START_LATE/TOOK_TOO_LONG."""
    op = make_op("alltoall", **NOISE_FREE)
    base = op.base_time(16, 32768)
    for win in (0.9 * base, 1.2 * base, 3.0 * base):
        net_a, sync_a = _synced(11, p=16)
        net_b, sync_b = _synced(11, p=16)
        a = run_windowed_scalar(net_a, sync_a, make_op("alltoall", **NOISE_FREE),
                                32768, 200, win)
        b = run_windowed(net_b, sync_b, make_op("alltoall", **NOISE_FREE),
                         32768, 200, win, engine="batch")
        assert np.array_equal(a.errors, b.errors), f"win={win}"
        np.testing.assert_allclose(a.times, b.times, rtol=0, atol=1e-12)


def test_windowed_batch_matches_scalar_statistically():
    """Live RNG: the batched draws reorder the stream, so the campaigns are
    different samples of the same distribution — Wilcoxon must not tell
    them apart, and the means must agree to ~1%."""
    net_a, sync_a = _synced(7, p=16)
    net_b, sync_b = _synced(7, p=16)
    a = run_windowed_scalar(net_a, sync_a, make_op("allreduce"), 4096, 3000,
                            300e-6)
    b = run_windowed(net_b, sync_b, make_op("allreduce"), 4096, 3000,
                     300e-6, engine="batch")
    res = wilcoxon_rank_sum(a.valid_times, b.valid_times)
    assert res.p_value > 0.05, res.p_value
    assert abs(a.valid_times.mean() - b.valid_times.mean()) \
        < 0.02 * a.valid_times.mean()


def test_windowed_batch_invalid_fraction_tracks_scalar():
    """Fig. 21 regime (window barely fits the op): both engines must see
    comparable invalid fractions at every window size."""
    for win, tol in ((40e-6, 0.10), (100e-6, 0.05)):
        net_a, sync_a = _synced(1, p=16)
        net_b, sync_b = _synced(1, p=16)
        a = run_windowed_scalar(net_a, sync_a, make_op("alltoall"), 8192,
                                1500, win)
        b = run_windowed(net_b, sync_b, make_op("alltoall"), 8192,
                         1500, win, engine="batch")
        assert abs(a.invalid_fraction - b.invalid_fraction) < tol, win


def test_windowed_engine_dispatch():
    net, sync = _synced(2, p=4)
    wr = run_windowed(net, sync, make_op("bcast"), 256, 50, 300e-6)
    assert wr.times.size == 50          # auto -> batch on affine clocks
    with pytest.raises(ValueError):
        run_windowed(net, sync, make_op("bcast"), 256, 10, 300e-6,
                     engine="nope")


def test_windowed_auto_never_scalar_for_random_walk_clocks():
    """The historic silent scalar fallback is retired: ``auto`` resolves to
    the vectorized ``batch_rw`` engine on random-walk clocks, and the
    strict ``batch`` engine still refuses them."""
    from repro.core import ClockParams
    from repro.core.window import resolve_engine

    net = SimNet(4, seed=3, clocks=ClockParams(rw_sigma=1e-7))
    assert resolve_engine("auto", net) == ("batch_rw", None)
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    wr = run_windowed(net, sync, make_op("bcast"), 256, 30, 400e-6)
    assert wr.times.size == 30          # auto -> batch_rw, no crash
    with pytest.raises(ValueError):
        run_windowed(net, sync, make_op("bcast"), 256, 10, 400e-6,
                     engine="batch")


# ---------------------------------------------------------------------------
# run_windowed: batch_rw (random-walk clocks) vs scalar
# ---------------------------------------------------------------------------

def _synced_rw(seed, p=8, rw_sigma=1e-7):
    from repro.core import ClockParams

    net = SimNet(p, seed=seed, clocks=ClockParams(rw_sigma=rw_sigma))
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    return net, sync


def test_derive_stream_is_deterministic():
    """Key-derived streams are a pure function of (root, keys) — the one
    derivation helper shared by epoch biases, drift paths and the JAX
    engine's seeding."""
    from repro.core.clocks import derive_stream

    a = derive_stream(123, "drift-path").normal(size=4)
    b = derive_stream(123, "drift-path").normal(size=4)
    assert np.array_equal(a, b)
    c = derive_stream(123, "other-key").normal(size=4)
    assert not np.array_equal(a, c)
    # Generator parent: consumes exactly one draw, bit-stable
    g1, g2 = np.random.default_rng(9), np.random.default_rng(9)
    assert np.array_equal(derive_stream(g1).normal(size=4),
                          derive_stream(g2).normal(size=4))
    assert np.array_equal(g1.integers(2**31, size=3),
                          g2.integers(2**31, size=3))


def test_drift_path_roundtrip_inversion():
    """true_at_local(read(t)) == t on an active drift path: the batched
    piecewise-affine inversion is the exact inverse of the forward read."""
    from repro.core.clocks import SimClock

    clk = SimClock(offset=0.01, skew=3e-6, rw_sigma=1e-7, seed=5)
    clk.drift_path(400e-6)
    t = np.linspace(0.0, 2.0, 5000)
    local = clk.read(t)
    assert np.all(np.diff(local) > 0)   # monotone, hence invertible
    np.testing.assert_allclose(clk.true_at_local(local), t,
                               rtol=0, atol=1e-9)


def test_windowed_rw_batch_exact_on_frozen_paths():
    """Scalar vs batched-bisection engine over the *same frozen drift
    paths* (identical seeds pin identical walks): noise-free, the two
    engines compute the same campaign to float-associativity noise."""
    win = 300e-6
    net_a, sync_a = _synced_rw(5, p=16)
    net_b, sync_b = _synced_rw(5, p=16)
    net_a.freeze_drift_paths(win)
    net_b.freeze_drift_paths(win)
    a = run_windowed_scalar(net_a, sync_a, make_op("allreduce", **NOISE_FREE),
                            4096, 300, win)
    b = run_windowed(net_b, sync_b, make_op("allreduce", **NOISE_FREE),
                     4096, 300, win, engine="batch_rw")
    assert np.array_equal(a.errors, b.errors)
    np.testing.assert_allclose(a.times, b.times, rtol=0, atol=1e-12)
    np.testing.assert_allclose(a.end_true, b.end_true, rtol=0, atol=1e-12)
    np.testing.assert_allclose(a.start_global_est, b.start_global_est,
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(net_a.t, net_b.t, rtol=0, atol=1e-12)


def test_windowed_rw_batch_matches_scalar_statistically():
    """Live RNG on random-walk clocks: pre-sampled path vs lazy walk are
    different draws of the same process — Wilcoxon must not tell the
    engines apart."""
    net_a, sync_a = _synced_rw(7, p=8)
    net_b, sync_b = _synced_rw(7, p=8)
    a = run_windowed_scalar(net_a, sync_a, make_op("allreduce"), 4096, 2500,
                            300e-6)
    b = run_windowed(net_b, sync_b, make_op("allreduce"), 4096, 2500,
                     300e-6, engine="batch_rw")
    res = wilcoxon_rank_sum(a.valid_times, b.valid_times)
    assert res.p_value > 0.05, res.p_value
    assert abs(a.valid_times.mean() - b.valid_times.mean()) \
        < 0.02 * a.valid_times.mean()


# ---------------------------------------------------------------------------
# run_windowed: jax engine vs numpy
# ---------------------------------------------------------------------------

def test_simjax_matches_numpy_statistically():
    """Cross-engine equivalence: the jit-compiled engine samples with JAX's
    counter-based PRNG, so campaigns are different draws of the same
    distribution — Wilcoxon-indistinguishable from the numpy batch engine."""
    pytest.importorskip("jax")
    net_a, sync_a = _synced(7, p=16)
    net_b, sync_b = _synced(7, p=16)
    a = run_windowed(net_a, sync_a, make_op("allreduce"), 4096, 3000,
                     300e-6, engine="batch")
    b = run_windowed(net_b, sync_b, make_op("allreduce"), 4096, 3000,
                     300e-6, engine="jax")
    res = wilcoxon_rank_sum(a.valid_times, b.valid_times)
    assert res.p_value > 0.05, res.p_value
    assert abs(a.valid_times.mean() - b.valid_times.mean()) \
        < 0.02 * a.valid_times.mean()
    assert abs(a.invalid_fraction - b.invalid_fraction) < 0.05


def test_simjax_composite_chunking_and_state():
    """Composite op expressions run per-term through the jitted sampler;
    consecutive chunks (small nrep exercises the compile-shape bucketing)
    stay on one monotone timeline and advance each term's AR(1) state."""
    pytest.importorskip("jax")
    from repro.core.mpi_ops import make_composite_op

    net, sync = _synced(11, p=4)
    op = make_composite_op("allreduce + bcast*0.5")
    w1 = run_windowed(net, sync, op, 512, 40, 400e-6, engine="jax")
    states = [term._ar_state for term, _, _ in op.terms]
    w2 = run_windowed(net, sync, op, 512, 37, 400e-6, engine="jax")
    assert w1.times.shape == (40,) and w2.times.shape == (37,)
    assert w2.start_true.min() > w1.end_true.max() - 1e-9
    assert all(s1 != s2 for s1, s2 in
               zip(states, [term._ar_state for term, _, _ in op.terms]))


def test_simjax_strict_on_random_walk_clocks():
    """The explicit jax engine never silently degrades: random-walk clocks
    raise; ``resolve_engine`` is the sanctioned soft-fallback path."""
    pytest.importorskip("jax")
    from repro.core.window import resolve_engine
    from repro.simjax import SimJaxUnavailable

    net, sync = _synced_rw(3, p=4)
    with pytest.raises(SimJaxUnavailable):
        run_windowed(net, sync, make_op("bcast"), 256, 10, 400e-6,
                     engine="jax")
    resolved, note = resolve_engine("jax", net)
    assert resolved == "batch_rw" and note is not None


# ---------------------------------------------------------------------------
# execute vs execute_batch
# ---------------------------------------------------------------------------

def test_execute_batch_exact_when_noise_free():
    net_a = SimNet(8, seed=9)
    net_b = SimNet(8, seed=9)
    op_a = make_op("scan", **NOISE_FREE)
    op_b = make_op("scan", **NOISE_FREE)
    ends_a = []
    for _ in range(50):
        ends_a.append(op_a.execute(net_a, 1024).end_true)
    ex = op_b.execute_batch(net_b, 1024, 50)
    np.testing.assert_allclose(np.asarray(ends_a), ex.end_true,
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(net_a.t, net_b.t, rtol=0, atol=1e-12)


def test_execute_batch_matches_execute_statistically():
    net_a = SimNet(8, seed=4)
    net_b = SimNet(8, seed=4)
    op_a = make_op("allreduce")
    op_b = make_op("allreduce")
    dur_a = np.empty(2500)
    for i in range(2500):
        start = net_a.t.copy()
        ex = op_a.execute(net_a, 4096)
        dur_a[i] = np.max(ex.end_true) - np.max(start)
    ex_b = op_b.execute_batch(net_b, 4096, 2500)
    dur_b = np.max(ex_b.end_true, axis=1) - np.max(ex_b.start_true, axis=1)
    res = wilcoxon_rank_sum(dur_a, dur_b)
    assert res.p_value > 0.05, res.p_value
    assert abs(dur_a.mean() - dur_b.mean()) < 0.02 * dur_a.mean()


def test_execute_batch_respects_ar_state_across_boundary():
    """AR(1) state carries across scalar->batch boundaries, so lag-1
    correlation survives mixing the two paths."""
    net = SimNet(4, seed=8)
    op = make_op("bcast", autocorr=0.9, tail_prob=0.0, spike_prob=0.0)
    op.execute(net, 256)
    state_before = op._ar_state
    op.execute_batch(net, 256, 10)
    assert op._ar_state != state_before  # advanced, not reset


# ---------------------------------------------------------------------------
# barriers
# ---------------------------------------------------------------------------

def test_dissemination_barrier_vectorized_matches_scalar():
    """Exit-skew distributions of the vectorized and per-rank scalar
    barrier are statistically indistinguishable."""
    net_a = SimNet(16, seed=3)
    net_b = SimNet(16, seed=3)
    skew_a = np.empty(400)
    skew_b = np.empty(400)
    for i in range(400):
        ea = net_a._dissemination_barrier_scalar()
        eb = net_b.dissemination_barrier()
        skew_a[i] = ea.max() - ea.min()
        skew_b[i] = eb.max() - eb.min()
        net_a.sleep_all(5e-6)
        net_b.sleep_all(5e-6)
    res = wilcoxon_rank_sum(skew_a, skew_b)
    assert res.p_value > 0.05, res.p_value
    # medians, not means: the OS-noise spike tail makes means of 400
    # samples swing by more than the engines differ
    med_a, med_b = np.median(skew_a), np.median(skew_b)
    assert abs(med_a - med_b) < 0.1 * med_a


def test_library_barrier_exit_skew_profile_preserved():
    """The vectorized library barrier still produces the linear-in-rank
    MVAPICH-like exit profile of Fig. 12."""
    net = SimNet(16, seed=12)
    prof = np.empty((300, 16))
    for i in range(300):
        e = net.library_barrier(exit_skew=40e-6)
        prof[i] = e - e.min()
        net.sleep_all(5e-6)
    means = prof.mean(axis=0)
    assert means[1:].max() > 20e-6
    # increasing trend in rank (compare first and last third)
    assert means[-5:].mean() > means[:5].mean()


# ---------------------------------------------------------------------------
# epoch-parallel run_design
# ---------------------------------------------------------------------------

class _EpochFactory:
    """Top-level (picklable) simulated epoch factory."""

    def __init__(self, seed0):
        self.seed0 = seed0

    def __call__(self, epoch):
        net = SimNet(4, seed=self.seed0 + 1000 * epoch)
        sync = make_sync("hca", n_fitpts=30, n_exchanges=10).synchronize(net)
        return (net, sync, make_op("allreduce"))


class _Measure:
    def __call__(self, ctx, case, nrep):
        net, sync, op = ctx
        wr = run_windowed(net, sync, op, case.msize, nrep, win_size=400e-6)
        return wr.times


def test_epoch_parallel_run_design_reproduces_serial():
    design = ExperimentDesign(n_launch_epochs=6, nrep=25, seed=3)
    cases = [TestCase("allreduce", m) for m in (256, 4096)]
    backend = FunctionBackend(_EpochFactory(50), _Measure(), name="sim-pair")
    serial = run_design(design, backend, cases=cases, n_workers=1)
    parallel = run_design(design, backend, cases=cases, n_workers=2)
    assert len(serial) == len(parallel) == 12
    for a, b in zip(serial, parallel):
        assert a.case == b.case
        assert a.epoch == b.epoch
        assert np.array_equal(a.times, b.times)


def test_run_design_unpicklable_falls_back_to_serial():
    design = ExperimentDesign(n_launch_epochs=2, nrep=5, seed=0)
    cases = [TestCase("allreduce", 256)]
    factory = _EpochFactory(10)
    measure = lambda ctx, case, nrep: _Measure()(ctx, case, nrep)  # noqa: E731
    backend = FunctionBackend(factory, measure)  # lambda => not picklable
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        records = run_design(design, backend, cases=cases, n_workers=2)
    assert len(records) == 2
    assert any("not picklable" in str(w.message) for w in caught)


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------

def test_epoch_bias_cache_is_weak():
    """The per-epoch bias cache must not alias a new SimNet that reuses a
    dead net's memory address (the old ``id(net)`` bug)."""
    op = make_op("allreduce")
    net = SimNet(2, seed=0)
    op._bias_for(net)
    assert len(op._epoch_bias) == 1
    del net
    gc.collect()
    assert len(op._epoch_bias) == 0
    # distinct live nets get distinct cache slots
    nets = [SimNet(2, seed=s) for s in range(3)]
    biases = {op._bias_for(n) for n in nets}
    assert len(op._epoch_bias) == 3
    assert len(biases) == 3  # a.s. distinct draws


def test_result_table_grouped_index_matches_scan():
    cases = [TestCase("a", 1), TestCase("b", 2)]
    summaries = []
    for epoch in range(4):
        for c in cases:
            summaries.append(EpochSummary(
                case=c, epoch=epoch, mean=epoch + hash(c.op) % 7,
                median=epoch * 2.0, n_kept=10, n_raw=10))
    table = ResultTable(summaries=summaries)
    for c in cases:
        want_means = [s.mean for s in summaries if s.case.key() == c.key()]
        want_meds = [s.median for s in summaries if s.case.key() == c.key()]
        assert table.means(c).tolist() == want_means
        assert table.medians(c).tolist() == want_meds
    assert [c.key() for c in table.cases()] == [("a", 1), ("b", 2)]
    # index rebuilds when summaries grow
    table.summaries.append(EpochSummary(
        case=cases[0], epoch=4, mean=99.0, median=98.0, n_kept=1, n_raw=1))
    assert table.means(cases[0])[-1] == 99.0
    # unknown case -> empty
    assert table.means(TestCase("zzz", 0)).size == 0


def test_analyze_records_roundtrip_unchanged():
    """analyze_records output is unaffected by the index (regression)."""
    rng = np.random.default_rng(0)
    from repro.core import MeasurementRecord
    recs = [
        MeasurementRecord(case=TestCase("op", 64), epoch=e,
                          times=rng.normal(10.0, 1.0, 50))
        for e in range(5)
    ]
    table = analyze_records(recs)
    assert table.means(TestCase("op", 64)).size == 5
    assert np.all(table.medians(TestCase("op", 64)) > 5)
