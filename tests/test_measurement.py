"""Window-based measurement, barrier skew (Figs. 11-12, 21-22), the
experimental design (Alg. 5/6) and comparison engine (Figs. 27-30)."""

import numpy as np
import pytest

from repro.core import (
    ExperimentDesign,
    SimNet,
    TestCase,
    analyze_records,
    assert_comparable,
    capture_factors,
    compare_tables,
    make_op,
    make_sync,
    probe_barrier_skew,
    run_barrier_timed,
    run_design,
    run_windowed,
)
from repro.campaign import FunctionBackend

SYNC_KW = dict(n_fitpts=200, n_exchanges=40)


def _synced_net(p=8, seed=0):
    net = SimNet(p, seed=seed)
    sync = make_sync("hca", **SYNC_KW).synchronize(net)
    return net, sync


def test_windowed_measurement_sane():
    net, sync = _synced_net()
    op = make_op("allreduce")
    wr = run_windowed(net, sync, op, 8192, 200, win_size=300e-6)
    base = op.base_time(net.p, 8192)
    mean = wr.valid_times.mean()
    assert 0.8 * base < mean < 2.5 * base
    assert wr.invalid_fraction < 0.2


def test_window_too_small_discards_measurements():
    """Fig. 21: shrinking the window raises the invalid fraction."""
    net, sync = _synced_net(seed=1)
    op = make_op("alltoall")
    big = run_windowed(net, sync, op, 8192, 150, win_size=500e-6)
    net2, sync2 = _synced_net(seed=1)
    small = run_windowed(net2, sync2, op, 8192, 150, win_size=18e-6)
    assert small.invalid_fraction > big.invalid_fraction


def test_barrier_skew_biases_measurement():
    """§4.6 / Figs. 11+13: measuring through a skewed library barrier
    changes the result by ~the exit skew, while window-based measurement
    (aligned starts) reports ~the true op duration — so the barrier
    implementation is part of what you measure."""
    op_kw = dict(rank_imbalance=0.01, noise_sigma=0.01, tail_prob=0.0)
    skew = 40e-6

    net, sync = _synced_net(p=16, seed=2)
    wr = run_windowed(net, sync, make_op("allreduce", **op_kw), 1024, 150,
                      win_size=400e-6)
    mean_window = wr.valid_times.mean()

    net2, _ = _synced_net(p=16, seed=2)
    br_skewed = run_barrier_timed(net2, make_op("allreduce", **op_kw), 1024,
                                  150, barrier_exit_skew=skew)
    net3, _ = _synced_net(p=16, seed=2)
    br_clean = run_barrier_timed(net3, make_op("allreduce", **op_kw), 1024,
                                 150, use_library_barrier=False)

    mean_skewed = np.mean(br_skewed.times_local)
    mean_clean = np.mean(br_clean.times_local)
    # the library's extra exit skew shows up ~1:1 in the measurement
    assert mean_skewed - mean_clean > 0.5 * skew
    # any barrier leaves residual skew vs. window-aligned starts
    assert mean_clean > mean_window
    base = make_op("allreduce", **op_kw).base_time(16, 1024)
    assert mean_window < base * 1.6


def test_probe_barrier_skew_profile():
    net = SimNet(16, seed=3)
    prof = probe_barrier_skew(net, nrep=200, barrier_exit_skew=40e-6)
    means = prof.mean(axis=0)
    assert means.max() > 20e-6              # rank-dependent exit skew visible
    net2 = SimNet(16, seed=3)
    prof2 = probe_barrier_skew(net2, nrep=200, use_library_barrier=False)
    assert prof2.mean(axis=0).max() < means.max()


# ---------------------------------------------------------------------------
# Experimental design (Algorithm 5/6)
# ---------------------------------------------------------------------------

def _sim_campaign(seed0, op_kw=None, n=12, nrep=60):
    """Run the full paper method against the simulator."""
    cases = [TestCase("allreduce", m) for m in (256, 4096)]
    op_kw = op_kw or {}

    def epoch_factory(epoch):
        net = SimNet(8, seed=seed0 + 1000 * epoch)
        sync = make_sync("hca", **SYNC_KW).synchronize(net)
        return (net, sync, make_op("allreduce", **op_kw))

    def measure(ctx, case, nrep):
        net, sync, op = ctx
        wr = run_windowed(net, sync, op, case.msize, nrep, win_size=400e-6)
        times = wr.valid_times
        return times if times.size else wr.times

    design = ExperimentDesign(n_launch_epochs=n, nrep=nrep, seed=seed0)
    backend = FunctionBackend(epoch_factory, measure, name="sim-pair")
    records = run_design(design, backend, cases=cases)
    return analyze_records(records)


def test_legacy_pair_form_of_run_design_is_deprecated():
    """The bare (epoch_factory, measure) pair still runs — behind a
    DeprecationWarning pointing at FunctionBackend."""
    def epoch_factory(epoch):
        return epoch

    def measure(ctx, case, nrep):
        return np.full(nrep, 1e-6 * (1 + ctx))

    design = ExperimentDesign(n_launch_epochs=2, nrep=4, seed=0)
    cases = [TestCase("op", 1)]
    with pytest.deprecated_call(match="FunctionBackend"):
        legacy = run_design(design, epoch_factory, measure, cases)
    modern = run_design(design, FunctionBackend(epoch_factory, measure),
                        cases=cases)
    assert len(legacy) == len(modern) == 2
    for a, b in zip(legacy, modern):
        assert np.array_equal(a.times, b.times)


def test_design_produces_distribution_of_epoch_averages():
    table = _sim_campaign(0, n=6, nrep=40)
    for case in table.cases():
        med = table.medians(case)
        assert med.size == 6
        assert np.all(med > 0)


def test_launch_epoch_is_a_factor():
    """§5.2: per-epoch means differ more across epochs than within."""
    table = _sim_campaign(7, op_kw=dict(epoch_bias_sigma=0.05), n=10, nrep=60)
    case = table.cases()[0]
    med = table.medians(case)
    assert np.std(med) / np.mean(med) > 0.005


def test_comparison_detects_real_difference():
    """Figs. 28/30: Wilcoxon on per-epoch medians separates a 12% slowdown
    and stays silent on identical implementations."""
    fast = _sim_campaign(20, op_kw=dict(gamma=2.0e-6), n=10, nrep=60)
    slow = _sim_campaign(40, op_kw=dict(gamma=2.0e-6, alpha=4.5e-6), n=10, nrep=60)
    same = _sim_campaign(60, op_kw=dict(gamma=2.0e-6), n=10, nrep=60)

    rows = compare_tables(fast, slow)
    assert any(r.p_a_less <= 0.05 for r in rows), \
        [(r.case.msize, r.p_a_less) for r in rows]
    rows_same = compare_tables(fast, same)
    assert all(r.p_two_sided > 0.001 for r in rows_same)


def test_factor_comparability_guard():
    a = capture_factors(sync_method="hca", nrep=100)
    b = capture_factors(sync_method="barrier", nrep=100)
    assert_comparable(a, b, ("sync_method",))
    c = capture_factors(sync_method="barrier", nrep=200)
    with pytest.raises(ValueError):
        assert_comparable(a, c, ("sync_method",))


def test_reproducibility_of_method():
    """Fig. 31(c): the full method's normalized run-times disperse <~10%
    across independent trials."""
    means = []
    for trial in range(4):
        table = _sim_campaign(100 + 17 * trial, n=6, nrep=50)
        case = table.cases()[0]
        means.append(np.mean(table.means(case)))
    means = np.array(means)
    norm = means / means.min()
    assert norm.max() < 1.10
