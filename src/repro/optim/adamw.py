"""AdamW with sharded f32 moments, global-norm clipping and LR schedules.

Pure-functional, pjit-friendly: optimizer state is a pytree with the same
structure (and sharding) as the parameters, so FSDP sharding of the states
(ZeRO) falls out of the parameter PartitionSpecs.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "init_opt_state", "adamw_update", "lr_at_step"]


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"    # cosine | linear | constant


def lr_at_step(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(1.0, cfg.warmup_steps), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
        decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = lr_at_step(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm, "lr": lr}
