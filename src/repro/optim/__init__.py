"""Optimizers and distributed-optimization tricks."""

from .adamw import OptimizerConfig, adamw_update, global_norm, init_opt_state, lr_at_step

__all__ = ["OptimizerConfig", "adamw_update", "init_opt_state", "lr_at_step",
           "global_norm"]
