"""Gradient compression with error feedback (cross-pod hop optimization).

At 1000+ nodes the pod-to-pod (DCN) gradient reduction is the scarcest
bandwidth. ``compress_tree``/``decompress_tree`` implement int8 blockwise
quantization with an error-feedback residual (1-bit-Adam style memory):
the quantization error of step ``t`` is added back into the gradient at
``t+1``, keeping SGD/Adam convergence unaffected to first order.

Used by the shard_map-based cross-pod reduction variant in
``examples/compressed_dp.py`` and unit-tested for the error-feedback
contraction property in ``tests/test_optim.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_tree",
           "decompress_tree", "error_feedback_update"]

BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization along the flattened array."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape,
                    dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads):
    return jax.tree.map(lambda g: quantize_int8(g), grads,
                        is_leaf=lambda x: hasattr(x, "shape"))


def decompress_tree(compressed, like):
    return jax.tree.map(
        lambda qs, g: dequantize_int8(qs[0], qs[1], g.shape, g.dtype),
        compressed, like,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def error_feedback_update(grads, residual):
    """(grads + residual) -> (quantized-communicable grads, new residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, residual)
    comp = compress_tree(corrected)
    decomp = decompress_tree(comp, corrected)
    new_residual = jax.tree.map(lambda c, d: c - d, corrected, decomp)
    return comp, decomp, new_residual
