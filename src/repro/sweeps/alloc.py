"""Budgeted sweep allocation: spend nrep where the verdict is undecided.

The paper's design (and :func:`~repro.sweeps.effects.main_effects`)
spreads measurement budget uniformly over the factor grid, but the
deliverable is a set of per-axis *verdicts* — MATTERS or null — and most
cells stop informing any undecided verdict long before the uniform
budget is spent. This module treats the sweep as a best-arm/ranking
problem: an :class:`AllocationPolicy` plans *rounds* (a launch-epoch
window over the currently surviving cells), looks at the accumulated
data after each round through the anytime-valid
:func:`~repro.sweeps.effects.axis_decisions` check, and retires an axis
the moment its verdict resolves — reallocating the remaining budget to
the axes still in play by *pinning* every decided axis at its reference
level (dropping the cells that only exist to vary it).

Three policies, one protocol:

``uniform``
    one round, every cell, all epochs — the paper's design expressed as
    a policy, the reference the others are validated against;
``racing``
    geometrically growing epoch windows (1, 2, 4, ... capped at the
    design's epoch count) with a Holm + alpha-spending test at every
    look; axes retire only when the *statistics* resolve them;
``successive_halving``
    racing plus a fixed-schedule rule: from the second look onward the
    weakest half (by observed |Cliff's delta|) of the still-undecided
    axes is force-retired as null. Cheaper tail, but the forced
    retirements are a budget heuristic, not a test — the ``forced``
    flag on the decision keeps the two kinds of "null" distinguishable.

Policies are **pure**: ``plan_round`` and ``decide`` are deterministic
functions of the :class:`AllocState` (itself a pure function of the
store snapshot), with no RNG and no clock. That is the load-bearing
property — it makes a killed sweep resumable by replay (persisted
``sweep-alloc`` lines short-circuit ``decide``), keeps fleet == serial
bit-identity, and gives the budget a *prefix* semantics: raising
``nrep_budget`` at the same seed extends the allocation sequence, it
never reorders it (the budget is only ever consulted as a stop
criterion, never as an input to a decision).
"""

from __future__ import annotations

import math
from dataclasses import asdict, dataclass, replace
from typing import ClassVar, Protocol

from repro.sweeps.effects import AxisDecision, CellData, axis_decisions

__all__ = [
    "RoundPlan",
    "AllocState",
    "AllocationPolicy",
    "UniformPolicy",
    "RacingPolicy",
    "SuccessiveHalvingPolicy",
    "POLICIES",
    "make_policy",
    "build_state",
]


@dataclass(frozen=True)
class RoundPlan:
    """One budget slice: measure launch epochs ``[epochs[0], epochs[1])``
    of every cell in ``cells``."""

    round: int
    epochs: tuple[int, int]
    cells: tuple[int, ...]

    def n_cell_epochs(self) -> int:
        return len(self.cells) * (self.epochs[1] - self.epochs[0])


@dataclass
class AllocState:
    """Everything a policy is allowed to look at: the grid's shape, the
    data measured so far, and the verdicts already persisted. Built from
    a store snapshot by :func:`build_state` — never from in-process
    state, so a resumed sweep sees exactly what the killed one saw."""

    axes: list[dict]               # [{name, labels}], manifest order
    cell_levels: dict[int, dict]   # cell index -> {axis: label}
    cells: list[CellData]          # cumulative measured data, cell order
    decided: dict[str, str]        # axis -> resolved verdict
    round: int                     # completed (persisted) rounds
    spent_nrep: int                # raw repetitions in the store so far
    n_epochs_max: int              # the design's n_launch_epochs

    def undecided(self) -> list[str]:
        return [ax["name"] for ax in self.axes
                if ax["name"] not in self.decided]

    def reference_level(self, axis: str) -> str:
        for ax in self.axes:
            if ax["name"] == axis:
                return ax["labels"][0]
        raise KeyError(axis)

    def active_cells(self) -> list[int]:
        """Cells still worth budget: every *decided* axis pinned at its
        reference level (the first label — by stock-axis convention the
        non-defective setting), the undecided axes still fully crossed."""
        pins = {a: self.reference_level(a) for a in self.decided}
        return sorted(
            idx for idx, levels in self.cell_levels.items()
            if all(levels.get(a) == ref for a, ref in pins.items()))


class AllocationPolicy(Protocol):
    """The sequential-allocation strategy of a budgeted sweep.

    ``plan_round`` maps the current state to the next :class:`RoundPlan`
    (or ``None``: the sweep is finished — all verdicts resolved, epochs
    exhausted, or budget spent). ``decide`` maps the post-round state to
    per-axis :class:`~repro.sweeps.effects.AxisDecision`\\ s for the
    still-undecided family. Both must be pure functions of the state.
    """

    name: str

    def plan_round(self, state: AllocState) -> RoundPlan | None: ...

    def decide(self, state: AllocState) -> dict[str, AxisDecision]: ...

    def manifest(self) -> dict: ...


@dataclass(frozen=True)
class UniformPolicy:
    """The paper's design as a policy: one round, every cell, the full
    epoch window. ``decide`` still runs (its verdicts land in the
    ``sweep-alloc`` line for provenance), but nothing is retired —
    there is no later round to save budget in."""

    alpha: float = 0.05
    nrep_budget: int | None = None

    name: ClassVar[str] = "uniform"

    def plan_round(self, state: AllocState) -> RoundPlan | None:
        if state.round >= 1:
            return None
        if self.nrep_budget is not None \
                and state.spent_nrep >= self.nrep_budget:
            return None
        return RoundPlan(round=0, epochs=(0, state.n_epochs_max),
                         cells=tuple(sorted(state.cell_levels)))

    def decide(self, state: AllocState) -> dict[str, AxisDecision]:
        if not state.cells:
            return {}
        return axis_decisions(state.cells, axes=state.undecided(),
                              alpha=self.alpha, look=state.round)

    def manifest(self) -> dict:
        return dict(name=self.name, **asdict(self))


@dataclass(frozen=True)
class RacingPolicy:
    """Race the axes: geometrically growing epoch windows, an
    anytime-valid look after each, survivors keep the budget.

    The cumulative epoch target after round *k* is
    ``ceil(epochs0 * growth**k)`` capped at the design's epoch count, so
    the default schedule measures epoch windows of width 1, 1, 2, 4, ...
    Early looks are cheap and decide the loud axes (and with the stock
    grids, usually *all* axes); late looks only happen while something
    is still genuinely undecided.
    """

    alpha: float = 0.05
    epochs0: int = 1
    growth: float = 2.0
    n_min_null: int = 24
    delta_null: float = 0.3
    nrep_budget: int | None = None
    max_rounds: int = 16

    name: ClassVar[str] = "racing"

    def cum_epochs(self, round_index: int, n_epochs_max: int) -> int:
        e = int(math.ceil(self.epochs0 * self.growth ** round_index))
        return max(1, min(int(n_epochs_max), e))

    def plan_round(self, state: AllocState) -> RoundPlan | None:
        k = state.round
        if k >= self.max_rounds:
            return None
        if self.nrep_budget is not None \
                and state.spent_nrep >= self.nrep_budget:
            return None
        if k > 0 and not state.undecided():
            return None                      # every verdict resolved
        prev = 0 if k == 0 else self.cum_epochs(k - 1, state.n_epochs_max)
        cur = self.cum_epochs(k, state.n_epochs_max)
        if cur <= prev:
            return None                      # epoch window exhausted
        return RoundPlan(round=k, epochs=(prev, cur),
                         cells=tuple(state.active_cells()))

    def decide(self, state: AllocState) -> dict[str, AxisDecision]:
        und = state.undecided()
        if not und or not state.cells:
            return {}
        return axis_decisions(state.cells, axes=und, alpha=self.alpha,
                              look=state.round, n_min_null=self.n_min_null,
                              delta_null=self.delta_null)

    def manifest(self) -> dict:
        return dict(name=self.name, **asdict(self))


@dataclass(frozen=True)
class SuccessiveHalvingPolicy(RacingPolicy):
    """Racing plus a halving schedule: from the second look onward, the
    weakest half of the still-undecided axes (smallest observed |Cliff's
    delta|, ties broken by name for determinism) is force-retired as
    null. The forced decisions carry ``forced=True`` — they are budget
    heuristics, not test outcomes, and the soundness tier only vouches
    for the un-forced kind."""

    name: ClassVar[str] = "successive_halving"

    def decide(self, state: AllocState) -> dict[str, AxisDecision]:
        out = dict(super().decide(state))
        if state.round < 1:
            return out                       # every axis gets two looks
        und = [a for a, d in out.items() if d.verdict == "undecided"]
        n_retire = len(und) // 2
        for axis in sorted(und, key=lambda a: (out[a].effect_size,
                                               a))[:n_retire]:
            out[axis] = replace(out[axis], verdict="null", forced=True)
        return out


POLICIES: dict[str, type] = {
    "uniform": UniformPolicy,
    "racing": RacingPolicy,
    "successive_halving": SuccessiveHalvingPolicy,
}


def make_policy(name: str, **overrides) -> AllocationPolicy:
    """Instantiate a policy by registry name; ``None`` overrides are
    dropped so CLI plumbing can pass optional flags straight through."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown allocation policy {name!r} "
                         f"(have: {', '.join(sorted(POLICIES))})") from None
    return cls(**{k: v for k, v in overrides.items() if v is not None})


def build_state(manifest: dict, snapshot, sweep_id: str,
                n_epochs_max: int, outlier_filter: bool = True) -> AllocState:
    """The policy's view of the world, rebuilt from a store snapshot.

    ``spent_nrep`` counts every raw repetition stored under the sweep's
    cell fingerprints — including records inherited from earlier sweeps
    of the same cells, which a resumed or overlapping sweep rightly does
    not pay for again. ``decided`` replays the persisted ``sweep-alloc``
    verdicts (first resolution wins), and ``round`` is the number of
    persisted rounds — so a killed sweep re-plans exactly the round it
    died in.
    """
    from repro.core.design import analyze_records

    axes = [dict(name=a["name"], labels=list(a["labels"]))
            for a in manifest["axes"]]
    cell_levels = {int(i): dict(lv) for i, _, lv in manifest["cells"]}
    cells: list[CellData] = []
    spent = 0
    for index, fp, levels in manifest["cells"]:
        records = snapshot.records.get(fp, [])
        spent += sum(int(r.times.size) for r in records)
        if not records:
            continue
        table = analyze_records(records, outlier_filter)
        meds = {case.key(): table.medians(case) for case in table.cases()}
        cells.append(CellData(index=int(index), levels=dict(levels),
                              medians=meds))
    allocs = snapshot.sweep_alloc_by_id.get(sweep_id, [])
    decided: dict[str, str] = {}
    for line in allocs:
        for axis, d in (line.get("decisions") or {}).items():
            verdict = d.get("verdict") if isinstance(d, dict) else str(d)
            if verdict and verdict != "undecided" and axis not in decided:
                decided[axis] = verdict
    return AllocState(axes=axes, cell_levels=cell_levels, cells=cells,
                      decided=decided, round=len(allocs), spent_nrep=spent,
                      n_epochs_max=int(n_epochs_max))
