"""repro.sweeps — the factor registry made executable, end to end.

The third consumer of the campaign layer (after the suite and guideline
verification): enumerable factor axes (:class:`~repro.core.factors.
FactorAxis` / :class:`~repro.core.factors.FactorGrid`) are compiled by the
:class:`~repro.campaign.SweepScheduler` into per-cell campaigns — sharded,
persistent, resumable — and :mod:`repro.sweeps.effects` distills the
measured grid into the paper's "which factors matter" table
(Kruskal-Wallis + Holm main effects, Cliff's-delta ranking, pairwise
interaction screen). ::

    from repro.campaign import ResultStore, SweepScheduler
    from repro.sweeps import (default_sim_sweep, cells_from_result,
                              main_effects, format_factor_report)

    spec, backend = default_sim_sweep(seed=0)
    res = SweepScheduler(spec, backend, ResultStore("sweep.jsonl")).run()
    print(format_factor_report(main_effects(cells_from_result(res))))
"""

from .alloc import (POLICIES, AllocationPolicy, AllocState, RacingPolicy,
                    RoundPlan, SuccessiveHalvingPolicy, UniformPolicy,
                    make_policy)
from .axes import (DEFAULT_SWEEP_AXES, MISTUNED_PER_OP_KW, default_sim_sweep,
                   sim_axes)
from .effects import (DEFAULT_QUANTILES, AxisDecision, AxisEffect, CellData,
                      InteractionEffect, PairEffect, alpha_spending,
                      axis_decisions, cells_from_result, cells_from_store,
                      format_factor_report, interaction_screen, main_effects,
                      quantile_distance)

__all__ = [
    "sim_axes",
    "default_sim_sweep",
    "DEFAULT_SWEEP_AXES",
    "MISTUNED_PER_OP_KW",
    "CellData",
    "PairEffect",
    "AxisEffect",
    "AxisDecision",
    "InteractionEffect",
    "cells_from_result",
    "cells_from_store",
    "main_effects",
    "axis_decisions",
    "alpha_spending",
    "interaction_screen",
    "format_factor_report",
    "quantile_distance",
    "DEFAULT_QUANTILES",
    "AllocationPolicy",
    "AllocState",
    "RoundPlan",
    "UniformPolicy",
    "RacingPolicy",
    "SuccessiveHalvingPolicy",
    "POLICIES",
    "make_policy",
]
