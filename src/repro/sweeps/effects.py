"""Nonparametric factor-impact analysis of a completed sweep.

The analysis that turns a grid of measured cells back into the paper's
headline table — *which experimental factors have an impact on run-time*:

  * per-axis **main effects**: Kruskal-Wallis across the axis levels on
    *aligned* per-case-normalized per-epoch medians (the paper's unit of
    analysis, §6.2) — aligned meaning each observation is centered on the
    median of its complementary-factor stratum (the cells that agree on
    every *other* axis), the aligned-rank device for factorial designs
    that keeps a huge factor from drowning the contrast of a modest one —
    with Holm step-down across the axis family so the report's
    false-"factor matters" rate is bounded by alpha, pairwise one-sided
    Wilcoxon between levels, and Cliff's-delta effect sizes — the |delta|
    is the ranking key ("which factor matters *most*"), because unlike a
    p-value it does not inflate with sample size;
  * a pairwise **interaction screen**: for each axis pair, how much the
    conditional Cliff's delta of one axis moves across the levels of the
    other. A screen, not a test — it ranks candidate interactions for a
    follow-up sweep, it does not assign them p-values.

Normalization: every per-epoch median is divided by the grand median of
its own test case across all cells, so observations from different
message sizes pool on a common dimensionless scale and a factor's effect
is measured *relative* to typical run-time.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.stats import (cliffs_delta, holm_bonferroni, kruskal_wallis,
                              significance_stars, wilcoxon_rank_sum)

__all__ = [
    "CellData",
    "PairEffect",
    "AxisEffect",
    "AxisDecision",
    "InteractionEffect",
    "cells_from_result",
    "cells_from_store",
    "main_effects",
    "axis_decisions",
    "alpha_spending",
    "interaction_screen",
    "format_factor_report",
    "quantile_distance",
    "DEFAULT_QUANTILES",
]

#: Quantiles a distribution match is scored on: the body (median and
#: quartiles) plus the 10/90 shoulders where the simulator's bimodal-tail
#: and spike mixture actually shows. Deliberately not the extreme tail —
#: per-epoch medians of a short campaign estimate q=0.99 with pure noise.
DEFAULT_QUANTILES = (0.1, 0.25, 0.5, 0.75, 0.9)


def quantile_distance(ref: np.ndarray, cand: np.ndarray,
                      quantiles: tuple = DEFAULT_QUANTILES) -> float:
    """Distance between two samples of per-epoch medians (the paper's
    aligned unit of analysis, the same arrays :class:`CellData` carries):
    the mean absolute log-ratio of their quantiles.

    The log-ratio scale makes the distance symmetric, unit-free and
    additive across cells of very different magnitude — a 10% mismatch at
    q=0.9 costs the same for a 5 us bcast as for a 5 ms alltoall — which
    is what lets a calibration objective sum it over (op, msize) cells.
    """
    ref = np.asarray(ref, np.float64)
    cand = np.asarray(cand, np.float64)
    if ref.size == 0 or cand.size == 0:
        raise ValueError("quantile_distance: empty sample")
    if np.any(ref <= 0) or np.any(cand <= 0):
        raise ValueError("quantile_distance: run-times must be positive")
    qs = np.asarray(quantiles, np.float64)
    qr = np.quantile(ref, qs)
    qc = np.quantile(cand, qs)
    return float(np.mean(np.abs(np.log(qc / qr))))


@dataclass
class CellData:
    """The analysis view of one grid cell: its level labels and the
    per-epoch medians of every case it measured."""

    index: int
    levels: dict[str, str]
    medians: dict[tuple[str, int], np.ndarray]


def cells_from_result(result) -> list[CellData]:
    """Adapt a :class:`~repro.campaign.SweepResult` for analysis."""
    out = []
    for c in result.cells:
        meds = {case.key(): c.table.medians(case) for case in c.table.cases()}
        out.append(CellData(index=c.cell.index, levels=c.levels(),
                            medians=meds))
    return out


def cells_from_store(store, sweep_id: str | None = None) -> list[CellData]:
    """Rebuild the analysis view from a persisted sweep (default: the last
    sweep declared in the store). Only *completed* cells — those with a
    ``sweep-cell`` marker — are included, so analyzing a killed sweep
    never mixes half-measured cells into the effect estimates. The store
    file is parsed once (one snapshot), not once per cell."""
    from repro.core.design import analyze_records

    snap = store.snapshot()
    if sweep_id is None:
        if not snap.sweeps:
            raise KeyError(f"no sweep in {store.path}")
        sweep_id = snap.sweeps[-1]
    if sweep_id not in snap.manifests:
        raise KeyError(f"no sweep {sweep_id!r} in {store.path}")
    manifest = snap.manifests[sweep_id]
    done = snap.sweep_cells_by_id.get(sweep_id, {})
    out = []
    for index, fp, levels in manifest["cells"]:
        if int(index) not in done:
            continue
        table = analyze_records(snap.records.get(fp, []))
        meds = {case.key(): table.medians(case) for case in table.cases()}
        out.append(CellData(index=int(index), levels=dict(levels),
                            medians=meds))
    return out


@dataclass
class PairEffect:
    """One level pair of one axis: the one-sided Wilcoxon question
    "is `slower` really slower than `faster`?" plus the effect size."""

    slower: str
    faster: str
    p_wilcoxon: float              # one-sided, direction chosen by medians
    p_holm: float                  # Holm-adjusted within the axis' pairs
    delta: float                   # Cliff's delta of slower vs faster

    @property
    def stars(self) -> str:
        return significance_stars(self.p_holm)


@dataclass
class AxisEffect:
    """Main effect of one factor axis."""

    axis: str
    levels: tuple[str, ...]        # ordered slowest -> fastest
    level_medians: dict[str, float]   # normalized group medians
    h_stat: float
    p_kw: float                    # raw Kruskal-Wallis p
    p_holm: float = 1.0            # Holm-adjusted across the axis family
    pairs: list[PairEffect] = field(default_factory=list)
    effect_size: float = 0.0       # max |Cliff's delta| over level pairs
    n_obs: int = 0
    alpha: float = 0.05

    @property
    def significant(self) -> bool:
        return self.p_holm <= self.alpha

    @property
    def verdict(self) -> str:
        return "MATTERS" if self.significant else "-"

    def ordering(self) -> str:
        return " > ".join(self.levels)


@dataclass
class InteractionEffect:
    """One axis pair of the interaction screen: how much axis_a's
    conditional effect moves across axis_b's levels."""

    axis_a: str
    axis_b: str
    score: float                   # spread of conditional Cliff's deltas
    detail: str = ""


def _normalized_pools(cells: list[CellData]) -> list[tuple[CellData, np.ndarray]]:
    """Each cell's observations pooled across cases on the dimensionless
    per-case-normalized scale."""
    if not cells:
        raise ValueError("no cells to analyze")
    keys = sorted({k for c in cells for k in c.medians})
    grand: dict[tuple, float] = {}
    for k in keys:
        allv = np.concatenate([c.medians[k] for c in cells if k in c.medians
                               and c.medians[k].size])
        if allv.size == 0:
            continue
        grand[k] = float(np.median(allv)) or 1.0
    out = []
    for c in cells:
        parts = [c.medians[k] / grand[k] for k in keys
                 if k in c.medians and k in grand and c.medians[k].size]
        if not parts:
            raise ValueError(f"cell {c.index} ({c.levels}) has no "
                             "observations to analyze")
        out.append((c, np.concatenate(parts)))
    return out


def _axis_names(cells: list[CellData]) -> list[str]:
    names = list(cells[0].levels)
    for c in cells:
        if list(c.levels) != names:
            raise ValueError(f"cells disagree on the axis set: {names} vs "
                             f"{list(c.levels)}")
    return names


def _aligned_level_pools(pools, axis: str) -> dict[str, np.ndarray]:
    """Per-level pools *aligned on the complementary strata*: every
    observation is centered on the median of its stratum (the cells that
    share its levels on all other axes), so variance contributed by the
    other factors cancels out of this axis' contrast. Observations end up
    in units of "fraction of typical run-time, relative to the stratum"."""
    strata: dict[tuple, list[tuple[str, np.ndarray]]] = {}
    order: list[str] = []
    for c, x in pools:
        lab = c.levels[axis]
        if lab not in order:
            order.append(lab)
        key = tuple((k, v) for k, v in c.levels.items() if k != axis)
        strata.setdefault(key, []).append((lab, x))
    grouped: dict[str, list[np.ndarray]] = {lab: [] for lab in order}
    for entries in strata.values():
        center = float(np.median(np.concatenate([x for _, x in entries])))
        for lab, x in entries:
            grouped[lab].append(x - center)
    return {lab: np.concatenate(v) for lab, v in grouped.items() if v}


def _axis_effect(pools, axis: str, alpha: float) -> AxisEffect:
    """The raw (un-Holm'd) main effect of one axis on aligned pools —
    shared by the one-shot report (:func:`main_effects`) and the
    sequential looks (:func:`axis_decisions`), so a budgeted sweep's
    verdicts come from exactly the statistic the final table prints."""
    by_level = _aligned_level_pools(pools, axis)
    labels = list(by_level)
    if len(labels) < 2:
        # fractional sampling can starve an axis down to one level;
        # skipping it silently would misreport the swept space
        raise ValueError(f"axis {axis!r} has a single level in the "
                         "analyzed cells — grid fraction too small")
    h, p_kw = kruskal_wallis([by_level[lab] for lab in labels])
    medians = {lab: float(np.median(by_level[lab])) for lab in labels}
    pairs: list[PairEffect] = []
    for i in range(len(labels)):
        for j in range(i + 1, len(labels)):
            a, b = labels[i], labels[j]
            slower, faster = (a, b) if medians[a] >= medians[b] else (b, a)
            res = wilcoxon_rank_sum(by_level[slower], by_level[faster],
                                    alternative="greater")
            pairs.append(PairEffect(
                slower=slower, faster=faster, p_wilcoxon=res.p_value,
                p_holm=1.0,
                delta=cliffs_delta(by_level[slower], by_level[faster])))
    for pair, adj in zip(pairs, holm_bonferroni(
            [p.p_wilcoxon for p in pairs])):
        pair.p_holm = float(adj)
    return AxisEffect(
        axis=axis,
        levels=tuple(sorted(labels, key=lambda L: -medians[L])),
        level_medians=medians, h_stat=h, p_kw=p_kw, pairs=pairs,
        effect_size=max(abs(p.delta) for p in pairs),
        n_obs=sum(v.size for v in by_level.values()), alpha=alpha)


def main_effects(cells: list[CellData], alpha: float = 0.05) -> list[AxisEffect]:
    """Per-axis main effects on aligned observations, ranked
    most-impactful first.

    Ranking key: Holm-significant axes before non-significant ones, then
    descending |Cliff's delta|. The returned list is exactly the row order
    of :func:`format_factor_report`.
    """
    pools = _normalized_pools(cells)
    effects = [_axis_effect(pools, axis, alpha) for axis in _axis_names(cells)]
    for eff, adj in zip(effects, holm_bonferroni([e.p_kw for e in effects])):
        eff.p_holm = float(adj)
    effects.sort(key=lambda e: (not e.significant, -e.effect_size))
    return effects


@dataclass(frozen=True)
class AxisDecision:
    """The sequential verdict on one axis at one *look* of a budgeted
    sweep: ``MATTERS`` (Holm-corrected effect confirmed at this look's
    spent alpha), ``null`` (enough data, effect too small to chase), or
    ``undecided`` (keep allocating budget to this axis)."""

    axis: str
    verdict: str                   # "MATTERS" | "null" | "undecided"
    p_holm: float                  # Holm-adjusted within the tested family
    effect_size: float             # max |Cliff's delta| over level pairs
    n_obs: int
    look: int
    alpha_spent: float             # the threshold this look tested against
    forced: bool = False           # retired by a halving rule, not the test

    @property
    def resolved(self) -> bool:
        return self.verdict != "undecided"


def alpha_spending(alpha: float, look: int) -> float:
    """Geometric alpha-spending schedule: look *k* (0-based) may spend
    ``alpha * 2**-(k+1)``. The spends sum to at most ``alpha`` over any
    number of looks, so peeking at the data every round — the whole point
    of a racing sweep — cannot inflate the family-wise false-MATTERS
    rate above the one-shot analysis' bound. The price is conservatism,
    paid mostly at early looks where the savings are largest anyway."""
    return alpha * 0.5 ** (look + 1)


def axis_decisions(cells: list[CellData], axes: list[str] | None = None,
                   alpha: float = 0.05, look: int = 0,
                   n_min_null: int = 24,
                   delta_null: float = 0.3) -> dict[str, AxisDecision]:
    """Anytime-valid early-stop check: test the (still-undecided) axis
    family on the data available now, spending :func:`alpha_spending`
    of the alpha budget at this look.

      * ``MATTERS`` — the axis' Holm-adjusted Kruskal-Wallis p (adjusted
        within the *tested* family, i.e. the axes passed in) clears the
        spent alpha. Valid at any look; the spending schedule keeps the
        overall false-MATTERS rate <= alpha.
      * ``null`` — a futility rule, not a significance test: at least
        ``n_min_null`` aligned observations and a maximal |Cliff's
        delta| below ``delta_null`` means the effect, if any, is too
        small to change the factor ranking — stop spending budget on it.
      * ``undecided`` — neither; the axis keeps its budget.

    Decisions are a pure function of ``(cells, axes, parameters)`` — no
    RNG, no clock — which is what lets a killed sweep replay them from
    the store and land on the identical allocation sequence.
    """
    pools = _normalized_pools(cells)
    names = _axis_names(cells)
    if axes is not None:
        missing = sorted(set(axes) - set(names))
        if missing:
            raise ValueError(f"axes {missing} not present in the cells "
                             f"(have {names})")
        names = [n for n in names if n in set(axes)]
    a_k = alpha_spending(alpha, look)
    effects = [_axis_effect(pools, axis, alpha) for axis in names]
    adjusted = holm_bonferroni([e.p_kw for e in effects])
    out: dict[str, AxisDecision] = {}
    for eff, p_holm in zip(effects, adjusted):
        p_holm = float(p_holm)
        if p_holm <= a_k:
            verdict = "MATTERS"
        elif eff.n_obs >= n_min_null and eff.effect_size < delta_null:
            verdict = "null"
        else:
            verdict = "undecided"
        out[eff.axis] = AxisDecision(
            axis=eff.axis, verdict=verdict, p_holm=p_holm,
            effect_size=eff.effect_size, n_obs=eff.n_obs, look=look,
            alpha_spent=a_k)
    return out


def interaction_screen(cells: list[CellData]) -> list[InteractionEffect]:
    """Rank axis pairs by how non-additive their joint effect looks.

    For each ordered level pair of axis A, Cliff's delta is computed
    *within* each level of axis B; the pair's score is the largest spread
    of those conditional deltas (0 = perfectly additive on the ordinal
    scale). Pairs of levels that never co-occur (fractional grids) are
    skipped.
    """
    pools = _normalized_pools(cells)
    axes = _axis_names(cells)
    out: list[InteractionEffect] = []
    for ai in range(len(axes)):
        for aj in range(ai + 1, len(axes)):
            a, b = axes[ai], axes[aj]
            a_levels = list(dict.fromkeys(c.levels[a] for c, _ in pools))
            b_levels = list(dict.fromkeys(c.levels[b] for c, _ in pools))
            score, detail = 0.0, ""
            for x in range(len(a_levels)):
                for y in range(x + 1, len(a_levels)):
                    la, lb = a_levels[x], a_levels[y]
                    deltas = {}
                    for cond in b_levels:
                        pa = [v for c, v in pools
                              if c.levels[a] == la and c.levels[b] == cond]
                        pb = [v for c, v in pools
                              if c.levels[a] == lb and c.levels[b] == cond]
                        if pa and pb:
                            deltas[cond] = cliffs_delta(
                                np.concatenate(pa), np.concatenate(pb))
                    if len(deltas) < 2:
                        continue
                    spread = max(deltas.values()) - min(deltas.values())
                    if spread > score:
                        score = spread
                        detail = (f"delta({la} vs {lb}) spans "
                                  f"{min(deltas.values()):+.2f}.."
                                  f"{max(deltas.values()):+.2f} across {b}")
            out.append(InteractionEffect(axis_a=a, axis_b=b, score=score,
                                         detail=detail))
    out.sort(key=lambda e: -e.score)
    return out


def format_factor_report(effects: list[AxisEffect],
                         interactions: list[InteractionEffect] | None = None,
                         title: str = "factor impact") -> str:
    """The paper's "factors that matter" table from sweep data."""
    lines = [f"# {title} (Kruskal-Wallis + Holm on aligned normalized "
             "per-epoch medians; ranked by |Cliff's delta|)"]
    lines.append(
        f"{'factor':<16} {'levels (slow > fast)':<28} {'H':>8} {'p(KW)':>9} "
        f"{'p(holm)':>9} {'sig':>4} {'|delta|':>8} {'n':>6} {'verdict':>8}")
    for e in effects:
        stars = significance_stars(e.p_holm)
        lines.append(
            f"{e.axis:<16} {e.ordering():<28} {e.h_stat:>8.2f} "
            f"{e.p_kw:>9.2e} {e.p_holm:>9.2e} {stars:>4} "
            f"{e.effect_size:>8.3f} {e.n_obs:>6} {e.verdict:>8}")
    n_sig = sum(e.significant for e in effects)
    lines.append(f"# {n_sig}/{len(effects)} factors matter at family-wise "
                 f"alpha={effects[0].alpha if effects else 0.05}")
    if interactions:
        lines.append("# interaction screen (spread of conditional deltas; "
                     "ranking only, no p-values)")
        for it in interactions:
            if not it.detail:
                continue
            lines.append(f"  {it.axis_a} x {it.axis_b:<16} "
                         f"score={it.score:.2f}  {it.detail}")
    return "\n".join(lines)
