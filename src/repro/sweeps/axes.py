"""Stock factor axes for the simulated MPI library.

The executable version of the paper's Table 4: each axis names one
experimental factor and the levels a sweep varies it over, mapped onto a
:class:`~repro.campaign.SimBackend` / :class:`~repro.core.design.
ExperimentDesign` constructor field. Five of the stock axes genuinely
change what is measured (synchronization method, window size, buffer
policy, epoch isolation, randomization); ``dtype`` (a pure label in the
simulator) and ``engine`` (statistically-equivalent numpy vs JAX window
engines) are deliberate *null factors*, so the factor-impact analysis
always carries its own negative controls. The ``tuning`` axis seeds the
one defect the whole pipeline exists to find: a single mis-tuned
collective (``SimBackend.per_op_kw``), which must come out as the
top-ranked main effect of :func:`repro.sweeps.effects.main_effects`.
"""

from __future__ import annotations

from repro.campaign import SimBackend, SweepSpec
from repro.core.design import ExperimentDesign, TestCase
from repro.core.factors import FactorAxis, FactorGrid

__all__ = [
    "MISTUNED_PER_OP_KW",
    "DEFAULT_SWEEP_AXES",
    "sim_axes",
    "default_sim_sweep",
]

#: The seeded defect: allreduce with a 4x latency term and a 3x fixed
#: overhead — the "one collective shipped with a bad algorithm switch"
#: scenario of the guideline papers, expressed as a sweepable level.
MISTUNED_PER_OP_KW: dict = {"allreduce": dict(alpha=12e-6, gamma=6e-6)}

#: Axes of the default CLI sweep: the injected factor, one real factor of
#: each flavor (algorithmic, measurement-mechanical), and the null label.
DEFAULT_SWEEP_AXES: tuple[str, ...] = ("tuning", "sync_method", "window_us",
                                       "dtype")


def _stock_axes() -> tuple[FactorAxis, ...]:
    return (
        FactorAxis("tuning", ({}, MISTUNED_PER_OP_KW), key="per_op_kw",
                   labels=("stock", "mistuned")),
        FactorAxis("sync_method", ("hca", "skampi"), key="sync_name"),
        FactorAxis("window_us", (400e-6, 50e-6), key="win_size",
                   labels=("400", "50")),
        FactorAxis("buffer_policy", ("warm", "cold")),
        FactorAxis("epoch_isolation", ("process", "none")),
        FactorAxis("shuffle", (True, False), target="design"),
        FactorAxis("dtype", ("float32", "float64")),
        # Like dtype, a by-design null factor: the numpy and JAX window
        # engines are statistically equivalent, so an "engine" main effect
        # flags an engine-port bug, not a real factor. (`"jax"` resolves to
        # the numpy batch engine, with a warning, where jax is absent.)
        FactorAxis("engine", ("auto", "jax")),
    )


def sim_axes(include=None) -> tuple[FactorAxis, ...]:
    """The stock simulator axes, optionally restricted (and ordered) by
    name. Unknown names raise with the available set — a sweep that
    silently dropped an axis would report on a different factor space than
    the one asked for."""
    axes = _stock_axes()
    if include is None:
        return axes
    by_name = {ax.name: ax for ax in axes}
    include = list(include)
    unknown = sorted(set(include) - set(by_name))
    if unknown:
        raise ValueError(f"unknown factor axes {unknown}; "
                         f"available: {sorted(by_name)}")
    return tuple(by_name[n] for n in include)


def default_sim_sweep(seed: int = 0, axes=None, msizes=(512, 4096),
                      n_launch_epochs: int = 6, nrep: int = 40,
                      p: int = 8) -> tuple[SweepSpec, SimBackend]:
    """The stock sim factor sweep: a grid over ``axes`` (default
    :data:`DEFAULT_SWEEP_AXES`) measured on allreduce at ``msizes``.

    The base backend uses a light fitpoint budget (a sweep pays the sync
    cost once per cell per epoch) and a nonzero launch-epoch bias so the
    ``epoch_isolation`` axis has something to bias.
    """
    grid = FactorGrid(sim_axes(axes or DEFAULT_SWEEP_AXES), design_seed=seed)
    backend = SimBackend(p=p, seed0=seed,
                         sync_kw=dict(n_fitpts=60, n_exchanges=20),
                         op_kw=dict(epoch_bias_sigma=0.03))
    spec = SweepSpec(
        grid=grid,
        cases=[TestCase("allreduce", m) for m in msizes],
        design=ExperimentDesign(n_launch_epochs=n_launch_epochs, nrep=nrep,
                                seed=seed),
        name="factor-sweep",
    )
    return spec, backend
