"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts each ``while``-loop body **once**
(verified in ``tests/test_dryrun_infra.py``) — useless for scanned-layer
models where >95% of the work lives inside loops. This module re-derives

  * FLOPs            (``dot`` ops, 2 * prod(result) * prod(contracting)),
  * bytes accessed   (operand + result bytes of every memory-touching op;
                      fusion computations count as one access at the call
                      site, matching what reaches HBM),
  * collective bytes (operand bytes of all-reduce / all-gather /
                      reduce-scatter / all-to-all / collective-permute,
                      including async ``-start`` forms),

from the HLO text itself, scaling every computation by the product of the
``known_trip_count`` of the while-loops enclosing it and resolving operand
shapes through a per-computation symbol table (operands are printed without
shapes in optimized HLO).

All numbers are per-device (the SPMD module is the per-device program).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

__all__ = ["HloCost", "analyze_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "token": 0,
    "u1": 1, "s1": 1,
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}

_OPCODE_RE = re.compile(r"([\w\-]+)\((.*)$")


def _parse_instr_line(line: str):
    """Parse ``[ROOT] %name = <shape|tuple> opcode(operands), attrs``.

    Tuple results may contain ``/*index=N*/`` comments (with ``=``), so the
    result is extracted with a paren-balance scan, not a regex.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rhs = s[eq + 3:]
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        result = rhs[: end + 1]
        rest0 = rhs[end + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        result = rhs[:sp]
        rest0 = rhs[sp + 1:]
    m = _OPCODE_RE.match(rest0)
    if not m:
        return None
    return name, result, m.group(1), m.group(2)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLED_LIST_RE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
_CALLED_ONE_RE = re.compile(
    r"(?:calls|to_apply|body|condition|true_computation|false_computation)=%([\w.\-]+)")


def _called_names(rest: str) -> list[str]:
    out = []
    for m in _CALLED_LIST_RE.finditer(rest):
        out += [x.strip().lstrip("%") for x in m.group(1).split(",") if x.strip()]
    for m in _CALLED_ONE_RE.finditer(rest):
        out.append(m.group(1))
    return out
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_list(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in DTYPE_BYTES:
            d = tuple(int(x) for x in dims.split(",")) if dims else ()
            out.append((dt, d))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        total += DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1)
    return total


@dataclass
class _Instr:
    name: str
    opcode: str
    result_shapes: list
    rest: str                   # operand list + attributes


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # %name -> result shapes


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_ops: dict = field(default_factory=dict)
    collective_bytes_by_op: dict = field(default_factory=dict)
    dot_flops_by_comp: dict = field(default_factory=dict)
    warnings: list = field(default_factory=list)


def _parse_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, _Comp] = {}
    entry = None
    current: _Comp | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                current = _Comp(m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    entry = current.name
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        parsed = _parse_instr_line(line)
        if not parsed:
            continue
        name, result, opcode, rest = parsed
        shapes = _shape_list(result)
        current.instrs.append(_Instr(name, opcode, shapes, rest))
        current.shapes[name] = shapes
    return comps, entry


_SKIP_BYTES = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
    "async-done", "copy-done", "opt-barrier",
}

_LAYOUT_OPS = {
    "convert", "copy", "bitcast", "transpose", "reshape", "broadcast",
    "parameter", "tuple", "get-tuple-element", "constant", "slice", "pad",
    "reduce-precision",
}

# Elementwise/layout ops that a TPU fusion pass would merge into their
# producer/consumer kernels: count the *result* bytes only (one write), not
# operands — otherwise a k-op unfused chain in the CPU module counts the
# same tensor 2k times and the memory term is inflated ~10x vs what the
# TPU executable would do. Documented convention of the §Roofline table.
_RESULT_ONLY_BYTES = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exp", "log", "tanh", "negate", "power", "sqrt", "rsqrt", "cbrt",
    "convert", "compare", "select", "and", "or", "not", "xor", "floor",
    "ceil", "sign", "cosine", "sine", "logistic", "clamp",
    "reduce-precision", "broadcast", "reshape", "pad", "reverse", "erf",
    "expm1", "log1p", "atan2", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "is-finite",
    "round-nearest-even", "round-nearest-afz", "stochastic-convert", "copy",
    "exponential", "exponential-minus-one", "rng-bit-generator",
}


def _dot_flops(instr: _Instr, comp: _Comp) -> float:
    result_elems = math.prod(instr.result_shapes[0][1]) if instr.result_shapes else 0
    m = _CONTRACT_RE.search(instr.rest)
    # lhs operand shape: first operand reference
    ops = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
    k = 1
    if m and ops:
        lhs_shapes = comp.shapes.get(ops[0])
        if lhs_shapes:
            dims = lhs_shapes[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * result_elems * k


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    cost = HloCost()
    if entry is None:
        cost.warnings.append("no ENTRY computation found")
        return cost

    memo: dict[tuple[str, bool], tuple] = {}

    def comp_cost(cname: str, count_bytes: bool) -> tuple:
        """Returns (flops, bytes, coll_bytes, coll_ops, coll_bytes_by_op)."""
        key = (cname, count_bytes)
        if key in memo:
            return memo[key]
        comp = comps.get(cname)
        if comp is None:
            return (0.0, 0.0, 0.0, {}, {})
        fl = by = cb = 0.0
        cops: dict[str, float] = {}
        cbb: dict[str, float] = {}

        for ins in comp.instrs:
            opcode = ins.opcode
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            # --- operand byte resolution -----------------------------------
            call_part = ins.rest
            operand_names = _OPERAND_RE.findall(call_part.split("),", 1)[0])
            operand_bytes = 0
            for on in operand_names:
                shp = comp.shapes.get(on)
                if shp:
                    operand_bytes += _shape_bytes(shp)
            result_bytes = _shape_bytes(ins.result_shapes)

            # --- multiplier for called computations -------------------------
            called = _called_names(ins.rest)
            if opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    cost.warnings.append(f"while without known_trip_count in {cname}")
                for cn in called:
                    f2, b2, c2, o2, bb2 = comp_cost(cn, count_bytes)
                    fl += f2 * trip
                    by += b2 * trip
                    cb += c2 * trip
                    for k, v in o2.items():
                        cops[k] = cops.get(k, 0) + v * trip
                    for k, v in bb2.items():
                        cbb[k] = cbb.get(k, 0) + v * trip
                continue
            if opcode == "conditional":
                # count the most expensive branch (upper bound)
                branch_costs = [comp_cost(cn, count_bytes) for cn in called]
                if branch_costs:
                    best = max(branch_costs, key=lambda t: t[0] + t[1])
                    fl += best[0]
                    by += best[1]
                    cb += best[2]
                    for k, v in best[3].items():
                        cops[k] = cops.get(k, 0) + v
                    for k, v in best[4].items():
                        cbb[k] = cbb.get(k, 0) + v
                continue
            if opcode == "fusion":
                # FLOPs from inside; bytes only at the call boundary.
                layout_only = True
                for cn in called:
                    f2, _, c2, o2, bb2 = comp_cost(cn, False)
                    fl += f2
                    cb += c2
                    for k, v in o2.items():
                        cops[k] = cops.get(k, 0) + v
                    for k, v in bb2.items():
                        cbb[k] = cbb.get(k, 0) + v
                    inner = comps.get(cn)
                    if inner is not None:
                        for iop in inner.instrs:
                            if iop.opcode not in _LAYOUT_OPS:
                                layout_only = False
                                break
                if count_bytes:
                    # Pure layout/convert fusions (convert_bitcast, copy,
                    # transpose chains) are CPU-backend materializations a
                    # TPU build fuses away or expresses as layout choices:
                    # count one write, not operands+result.
                    by += result_bytes if layout_only \
                        else operand_bytes + result_bytes
                continue
            if opcode in ("call", "async-start", "custom-call"):
                for cn in called:
                    f2, b2, c2, o2, bb2 = comp_cost(cn, count_bytes)
                    fl += f2
                    by += b2
                    cb += c2
                    for k, v in o2.items():
                        cops[k] = cops.get(k, 0) + v
                    for k, v in bb2.items():
                        cbb[k] = cbb.get(k, 0) + v
                if count_bytes and opcode == "custom-call":
                    by += operand_bytes + result_bytes
                continue

            # --- plain instruction ------------------------------------------
            if base in _COLLECTIVES:
                nbytes = operand_bytes
                cb += nbytes
                cops[base] = cops.get(base, 0) + 1
                cbb[base] = cbb.get(base, 0) + nbytes
            if opcode == "dot":
                fl += _dot_flops(ins, comp)
            if count_bytes and opcode not in _SKIP_BYTES \
                    and not opcode.endswith("-done"):
                if opcode in _RESULT_ONLY_BYTES:
                    by += result_bytes
                elif opcode in ("dynamic-slice", "gather", "slice"):
                    # real traffic ~ the slice, not the full source buffer
                    by += 2 * result_bytes
                elif opcode in ("dynamic-update-slice", "scatter"):
                    # in-place update: the written window, not the buffer
                    upd = 0
                    names = _OPERAND_RE.findall(ins.rest.split("),", 1)[0])
                    if len(names) >= 2:
                        shp = comp.shapes.get(names[1])
                        if shp:
                            upd = _shape_bytes(shp)
                    by += 2 * (upd or result_bytes // 2)
                else:
                    by += operand_bytes + result_bytes

        out = (fl, by, cb, cops, cbb)
        memo[key] = out
        return out

    fl, by, cb, cops, cbb = comp_cost(entry, True)
    cost.flops = fl
    cost.bytes_accessed = by
    cost.collective_bytes = cb
    cost.collective_ops = {k: int(v) for k, v in cops.items()}
    cost.collective_bytes_by_op = cbb
    return cost
