"""Roofline-term extraction from compiled XLA artifacts (EXPERIMENTS.md §Roofline).

Sources:
  * ``compiled.cost_analysis()`` — HLO FLOPs and bytes accessed (per-device
    program under SPMD partitioning; verified by calibration in
    ``tests/test_dryrun_infra.py``),
  * ``compiled.as_text()`` — optimized HLO, parsed for the operand bytes of
    every ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` /
    ``all-to-all`` / ``collective-permute`` (+ their ``-start`` async forms).

Terms (seconds, per-device program == per-step wall-clock lower bound):
  compute    = flops_per_device / peak_flops
  memory     = bytes_per_device / hbm_bw
  collective = collective_bytes_per_device / ici_bw
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

__all__ = ["CollectiveStats", "parse_collectives", "RooflineReport",
           "roofline_from_compiled", "HW"]


class HW:
    """TPU v5e per-chip constants (targets; this container is CPU-only)."""

    PEAK_FLOPS_BF16 = 197e12
    HBM_BW = 819e9
    ICI_BW = 50e9          # per link; 1 link engaged per collective hop (cons.)
    HBM_BYTES = 16 * 2**30


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    ops: dict = field(default_factory=dict)        # op kind -> count
    operand_bytes: dict = field(default_factory=dict)  # op kind -> bytes

    @property
    def total_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_ops(self) -> int:
        return sum(self.ops.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in optimized HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)(?:-start)?\(", stripped)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        if op not in _COLLECTIVES:
            continue
        # operand shapes appear inside the call parens
        call = stripped[m.end():]
        shapes = _SHAPE_RE.findall(call)
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        stats.ops[op] = stats.ops.get(op, 0) + 1
        stats.operand_bytes[op] = stats.operand_bytes.get(op, 0) + nbytes
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_ops: dict
    collective_bytes_by_op: dict
    memory_per_device: dict            # from memory_analysis
    model_flops_global: float          # 6*N*D (train) or 2*N*D
    model_params: int
    compile_seconds: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / HW.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HW.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / HW.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_bound(self) -> float:
        """Roofline step time: the dominant term (optimistic overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak the *useful* model FLOPs achieve at
        the rooflined step time (the §Perf score)."""
        denom = self.step_time_bound * self.chips * HW.PEAK_FLOPS_BF16
        return self.model_flops_global / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_ops": self.collective_ops,
            "collective_bytes_by_op": self.collective_bytes_by_op,
            "memory_per_device": self.memory_per_device,
            "model_flops_global": self.model_flops_global,
            "model_params": self.model_params,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "compile_seconds": self.compile_seconds,
        }


def roofline_from_compiled(compiled, *, arch: str, shape: str, mesh_desc: str,
                           chips: int, model_flops_global: float,
                           model_params: int,
                           compile_seconds: float = 0.0) -> RooflineReport:
    from .hlo_analysis import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    # cost_analysis counts while-loop bodies once; the HLO-text analyzer
    # scales by known_trip_count (see hlo_analysis.py). Primary numbers come
    # from the analyzer; cost_analysis is kept as a lower-bound cross-check.
    hlo = analyze_hlo(compiled.as_text())
    flops = float(max(hlo.flops, float(cost.get("flops", 0.0))))
    nbytes = float(max(hlo.bytes_accessed, float(cost.get("bytes accessed", 0.0))))

    class _S:  # adapt HloCost to the CollectiveStats duck type
        total_bytes = hlo.collective_bytes
        ops = hlo.collective_ops
        operand_bytes = hlo.collective_bytes_by_op

    stats = _S()
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        }
    except Exception:
        mem = {}
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        flops_per_device=flops, bytes_per_device=nbytes,
        collective_bytes_per_device=float(stats.total_bytes),
        collective_ops=dict(stats.ops),
        collective_bytes_by_op=dict(stats.operand_bytes),
        memory_per_device=mem,
        model_flops_global=model_flops_global,
        model_params=model_params,
        compile_seconds=compile_seconds,
    )
