"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — required because only ``dryrun.py`` may set
``xla_force_host_platform_device_count``.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "TPUV5E"]


# Hardware constants used by the roofline analysis (TPU v5e targets).
class TPUV5E:
    PEAK_FLOPS_BF16 = 197e12        # per chip [FLOP/s]
    HBM_BW = 819e9                  # per chip [B/s]
    ICI_BW = 50e9                   # per link [B/s]
    HBM_BYTES = 16 * 2**30          # per chip


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
