"""Render the §Dry-run / §Roofline tables of EXPERIMENTS.md from the JSON
records written by ``repro.launch.dryrun``.

    PYTHONPATH=src python -m repro.launch.report results/dryrun_single.json
"""

from __future__ import annotations

import argparse
import json

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(records: list[dict]) -> str:
    recs = {(r["arch"], r["shape"], r["mesh"]): r for r in records}
    lines = [
        "| arch | shape | mesh | t_compute [s] | t_memory [s] | t_collective [s] "
        "| bottleneck | MODEL_FLOPS | useful ratio | roofline frac | "
        "mem/dev GiB (arg+tmp) | compile [s] |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    n_cells = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        app = applicable_shapes(cfg)
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if shape not in app:
                    if mesh == "16x16":
                        lines.append(
                            f"| {arch} | {shape} | — | — | — | — | *skipped:"
                            f" quadratic attention at 524k (DESIGN.md §4)* "
                            f"| — | — | — | — | — |")
                    continue
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING "
                                 f"| | | | | | | | |")
                    continue
                n_cells += 1
                mem = r.get("memory_per_device", {})
                memstr = (f"{fmt_bytes(mem.get('argument_bytes', 0))}+"
                          f"{fmt_bytes(mem.get('temp_bytes', 0))}")
                lines.append(
                    f"| {arch} | {shape} | {mesh} "
                    f"| {r['t_compute']:.4f} | {r['t_memory']:.4f} "
                    f"| {r['t_collective']:.4f} | {r['bottleneck']} "
                    f"| {r['model_flops_global']:.2e} "
                    f"| {r['useful_flops_ratio']:.3f} "
                    f"| {r['roofline_fraction']:.4f} "
                    f"| {memstr} | {r['compile_seconds']:.0f} |")
    lines.append(f"\n({n_cells} compiled cells rendered)")
    return "\n".join(lines)


def collectives_table(records: list[dict]) -> str:
    lines = ["| arch | shape | mesh | collective ops | collective GiB/dev |",
             "|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: -r["collective_bytes_per_device"]):
        ops = " ".join(f"{k}:{v}" for k, v in sorted(r["collective_ops"].items()))
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {ops} "
                     f"| {r['collective_bytes_per_device'] / 2**30:.2f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--collectives", action="store_true")
    args = ap.parse_args()
    records = []
    for f in args.files:
        with open(f) as fh:
            records.extend(json.load(fh))
    print(roofline_table(records))
    if args.collectives:
        print()
        print(collectives_table(records))


if __name__ == "__main__":
    main()
