"""Step functions (train / prefill / decode) and their abstract input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step selected by the shape kind — weak-type-correct, shardable,
and never allocated (the dry-run contract). ``abstract_state`` does the same
for parameters/optimizer/cache pytrees via ``jax.eval_shape``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models import (
    ModelConfig,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)
from repro.optim import OptimizerConfig, adamw_update, init_opt_state

__all__ = [
    "TrainState", "make_train_step", "make_prefill_step", "make_decode_step",
    "input_specs", "abstract_params", "abstract_train_state", "abstract_cache",
]


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt: OptimizerConfig | None = None,
                    remat: bool = True):
    opt = opt or OptimizerConfig()

    def train_step(state, batch):
        params = state["params"]

        def lf(p):
            loss, metrics = loss_fn(cfg, p, batch, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, remat: bool = True):
    """Full-sequence forward (the prefill cost driver); returns logits."""

    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch["tokens"],
                            embeds=batch.get("embeds"),
                            memory=batch.get("memory"), remat=remat)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        logits, cache = decode_step(cfg, params, cache, batch["tokens"],
                                    memory=batch.get("memory"))
        return logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct; nothing allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract model inputs for one (arch x shape) cell."""
    b = shape.global_batch
    tok = jnp.int32
    if shape.kind == "train":
        s = shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "labels": jax.ShapeDtypeStruct((b, s), tok),
        }
        if cfg.frontend == "vision":
            specs["embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
        if cfg.frontend == "audio":
            specs["memory"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
        return specs
    if shape.kind == "prefill":
        s = shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
        if cfg.frontend == "vision":
            specs["embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
        if cfg.frontend == "audio":
            specs["memory"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
        return specs
    # decode: one new token against a seq_len-deep cache
    specs = {"tokens": jax.ShapeDtypeStruct((b, 1), tok)}
    if cfg.frontend == "audio":
        specs["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), cfg.jdtype)
    return specs


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def abstract_train_state(cfg: ModelConfig):
    p = abstract_params(cfg)
    opt = jax.eval_shape(init_opt_state, p)
    return {"params": p, "opt": opt}


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len))
