import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware (the two lines above MUST precede every other import — JAX locks
the device count at first init).

For every (architecture x input shape) cell this lowers + compiles the
appropriate step (train / prefill / decode) on the production mesh
(16x16 single pod and 2x16x16 multi-pod) with fully-abstract inputs
(ShapeDtypeStruct; nothing allocated), prints ``memory_analysis()`` (fits?)
and ``cost_analysis()`` (FLOPs/bytes for §Roofline), and appends the
roofline record to a JSON results file.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_from_compiled
from repro.launch.steps import (
    abstract_cache,
    abstract_train_state,
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.parallel import ShardingConfig, batch_specs, cache_specs, param_specs
from jax.sharding import NamedSharding, PartitionSpec as P


def model_flops(cfg, shape) -> tuple[float, int]:
    """(MODEL_FLOPS_global, N_params[active]) — 6*N*D train, 2*N*D inference."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens, n_active
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens, n_active
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens, n_active


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               sharding_mode: str = "fsdp_tp", remat: bool = True,
               donate: bool = True):
    """Lower + compile one (arch x shape x mesh) cell; returns the report."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape)
    chips = int(np.prod(mesh.devices.shape))
    shcfg = ShardingConfig(mode=sharding_mode)

    specs = input_specs(cfg, shape)
    b_specs = batch_specs(mesh, specs)

    t0 = time.time()
    if shape.kind == "train":
        state = abstract_train_state(cfg)
        p_specs = param_specs(state["params"], cfg, mesh, shcfg)
        opt_specs = {
            "m": p_specs, "v": p_specs, "count": P(),
        }
        in_shardings = ({"params": p_specs, "opt": opt_specs}, b_specs)
        out_shardings = ({"params": p_specs, "opt": opt_specs}, None)
        step = make_train_step(cfg, remat=remat)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                          in_shardings,
                                          is_leaf=lambda x: isinstance(x, P)),
                out_shardings=(jax.tree.map(
                    lambda s: NamedSharding(mesh, s), out_shardings[0],
                    is_leaf=lambda x: isinstance(x, P)), None),
                donate_argnums=(0,) if donate else (),
            ).lower(state, specs)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        params = abstract_train_state(cfg)["params"]
        p_specs = param_specs(params, cfg, mesh, shcfg)
        step = make_prefill_step(cfg, remat=remat)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(jax.tree.map(
                    lambda s: NamedSharding(mesh, s), p_specs,
                    is_leaf=lambda x: isinstance(x, P)), jax.tree.map(
                    lambda s: NamedSharding(mesh, s), b_specs,
                    is_leaf=lambda x: isinstance(x, P))),
            ).lower(params, specs)
            compiled = lowered.compile()
    else:  # decode
        params = abstract_train_state(cfg)["params"]
        p_specs = param_specs(params, cfg, mesh, shcfg)
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        c_specs = cache_specs(cfg, mesh, cache, shcfg)
        step = make_decode_step(cfg)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(
                    jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                                 is_leaf=lambda x: isinstance(x, P)),
                    jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                                 is_leaf=lambda x: isinstance(x, P)),
                    jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                                 is_leaf=lambda x: isinstance(x, P)),
                ),
                donate_argnums=(1,) if donate else (),
            ).lower(params, cache, specs)
            compiled = lowered.compile()
    dt = time.time() - t0

    mf, n_active = model_flops(cfg, shape)
    report = roofline_from_compiled(
        compiled, arch=arch, shape=shape_name, mesh_desc=mesh_desc,
        chips=chips, model_flops_global=mf, model_params=n_active,
        compile_seconds=dt)
    return report, compiled


def run_cell(arch, shape_name, multi_pod, args):
    from repro.models.tuning import tuning_tag

    report, compiled = lower_cell(
        arch, shape_name, multi_pod=multi_pod,
        sharding_mode=args.sharding, remat=not args.no_remat,
        donate=not args.no_donate)
    d = report.to_dict()
    d["tuning"] = tuning_tag()
    mem = d["memory_per_device"]
    print(f"[dryrun] {arch} x {shape_name} mesh={d['mesh']} "
          f"compile={d['compile_seconds']:.1f}s")
    print(f"  memory/device: args={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
          f"out={mem.get('output_bytes', 0)/2**30:.2f}GiB "
          f"temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB "
          f"(HBM 16GiB)")
    print(f"  flops/device={d['flops_per_device']:.3e} "
          f"bytes/device={d['bytes_per_device']:.3e} "
          f"coll_bytes/device={d['collective_bytes_per_device']:.3e}")
    print(f"  roofline terms [s]: compute={d['t_compute']:.4f} "
          f"memory={d['t_memory']:.4f} collective={d['t_collective']:.4f} "
          f"-> bottleneck={d['bottleneck']}")
    print(f"  MODEL_FLOPS={d['model_flops_global']:.3e} "
          f"useful_ratio={d['useful_flops_ratio']:.3f} "
          f"roofline_fraction={d['roofline_fraction']:.3f}")
    print(f"  collectives: {d['collective_ops']}")
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sharding", default="fsdp_tp",
                    choices=["tp", "fsdp_tp", "dp"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tune", default=None,
                    help="comma list of tuning knobs, e.g. "
                         "'ce_chunk=8,attn_additive_mask=1'")
    args = ap.parse_args()

    if args.tune:
        from repro.models.tuning import set_tuning

        kw = {}
        for item in args.tune.split(","):
            k, v = item.split("=")
            kw[k] = int(v) if v.isdigit() else v.lower() in ("true", "1", "yes")
        set_tuning(**kw)

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape_name in applicable_shapes(get_config(arch)):
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    existing = {}
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for rec in json.load(f):
                existing[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    results = list(existing.values())

    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_desc = "2x16x16" if mp else "16x16"
            if args.skip_existing and (arch, shape_name, mesh_desc) in existing:
                print(f"[dryrun] skip cached {arch} x {shape_name} {mesh_desc}")
                continue
            try:
                d = run_cell(arch, shape_name, mp, args)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"])
                           != (arch, shape_name, mesh_desc)]
                results.append(d)
            except Exception as e:
                traceback.print_exc()
                failures.append((arch, shape_name, mesh_desc, repr(e)[:200]))
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    if failures:
        print("\nFAILED CELLS:")
        for f4 in failures:
            print(" ", f4)
        raise SystemExit(1)
    print(f"\nALL {len(cells) * len(meshes)} CELLS PASSED")


if __name__ == "__main__":
    main()
