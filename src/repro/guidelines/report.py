"""PGMPI-style verdict report formatting.

The verdict table mirrors the guideline-verification tables of
arXiv:1606.00215: one row per (guideline, message size) cell, the
measured averages of both sides, the violation p-value raw and
Holm-adjusted, and the verdict. ``holds(<)`` marks a guideline with
positive evidence (lhs significantly faster), ``holds(~)`` one that is
merely not refuted — the distinction PGMPI draws between a guideline the
data supports and one the data cannot decide.
"""

from __future__ import annotations

from repro.core.stats import significance_stars

from .engine import GuidelineReport

__all__ = ["format_report", "format_violations"]


def format_report(report: GuidelineReport, title: str = "") -> str:
    """The full verdict table, PGMPI style."""
    lines = []
    if title:
        lines.append(f"# {title}")
    lines.append(
        f"# backend={report.backend_name} statistic={report.statistic} "
        f"alpha={report.alpha} cells={len(report.verdicts)} "
        f"measured={report.n_measured} resumed={report.n_resumed}"
        + (f" fingerprint={report.fingerprint}" if report.fingerprint else ""))
    lines.append(
        f"{'guideline':<30} {'msize':>7} {'lhs[us]':>10} {'rhs[us]':>10} "
        f"{'ratio':>7} {'p(viol)':>9} {'p(holm)':>9} {'sig':>4} {'verdict':>9}")
    for v in report.verdicts:
        stars = significance_stars(v.p_holm) if v.violated else \
            (significance_stars(v.p_confirmed) if v.confirmed else "")
        lines.append(
            f"{v.guideline.name:<30} {v.msize:>7} {v.lhs_us:>10.2f} "
            f"{v.rhs_us:>10.2f} {v.ratio:>7.3f} {v.p_violated:>9.2e} "
            f"{v.p_holm:>9.2e} {stars:>4} {v.verdict:>9}")
    bad = report.violations()
    if bad:
        lines.append(f"# {len(bad)}/{len(report.verdicts)} cells VIOLATED "
                     f"(family-wise alpha={report.alpha})")
    else:
        lines.append(f"# all {len(report.verdicts)} cells hold "
                     f"(family-wise alpha={report.alpha})")
    return "\n".join(lines)


def format_violations(report: GuidelineReport) -> str:
    """Compact violation list for CI logs — empty string when all hold."""
    bad = report.violations()
    if not bad:
        return ""
    lines = ["guideline violations:"]
    for v in bad:
        lines.append(
            f"  {v.guideline.name} @ msize={v.msize}: "
            f"{v.guideline.lhs} = {v.lhs_us:.2f}us  >  "
            f"{v.guideline.rhs} = {v.rhs_us:.2f}us "
            f"(x{v.ratio:.2f}, p_holm={v.p_holm:.2e}) — "
            f"{v.guideline.description or 'guideline broken'}")
    return "\n".join(lines)
