"""The guideline verifier: compile → campaign → per-cell verdicts.

:func:`verify_guidelines` is the first end-to-end consumer of the
campaign subsystem: it compiles every guideline side into campaign test
cases (shared sides are measured once), runs them through
:class:`~repro.campaign.Campaign` against any
:class:`~repro.campaign.MeasurementBackend` — resumable through a
:class:`~repro.campaign.ResultStore`, adaptive-``nrep`` when the design
says so — and then answers, per (guideline, message size), the one-sided
Wilcoxon question "is the lhs slower than the rhs?" on the distribution
of per-epoch medians, with Holm's step-down correction across the whole
family so the false-violation rate of the *report* (not of each cell) is
bounded by ``alpha``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.campaign import Campaign, CampaignSpec, MeasurementBackend, ResultStore
from repro.core.compare import ComparisonRow, compare_cases
from repro.core.design import ExperimentDesign, TestCase
from repro.core.stats import holm_bonferroni

from .rules import Guideline

__all__ = ["GuidelineVerdict", "GuidelineReport", "compile_cases",
           "verdicts_from_table", "verify_guidelines", "DEFAULT_MSIZES"]

DEFAULT_MSIZES: tuple[int, ...] = (1024, 8192)


@dataclass(frozen=True)
class GuidelineVerdict:
    """One verified cell: a guideline at one message size."""

    guideline: Guideline
    msize: int
    lhs_case: TestCase
    rhs_case: TestCase
    lhs_us: float              # mean of per-epoch averages, lhs [us]
    rhs_us: float
    ratio: float               # lhs / rhs
    p_violated: float          # raw one-sided p for H_a: lhs > rhs
    p_holm: float              # Holm-adjusted p_violated over the family
    p_confirmed: float         # raw one-sided p for H_a: lhs < rhs
    n_epochs: int
    alpha: float

    @property
    def violated(self) -> bool:
        """lhs significantly slower than rhs after Holm correction — the
        guideline is broken and the report must say so."""
        return self.p_holm <= self.alpha

    @property
    def confirmed(self) -> bool:
        """lhs significantly *faster* (raw test) — the guideline holds with
        positive evidence, not merely absence of evidence."""
        return not self.violated and self.p_confirmed <= self.alpha

    @property
    def verdict(self) -> str:
        if self.violated:
            return "VIOLATED"
        return "holds(<)" if self.confirmed else "holds(~)"


@dataclass
class GuidelineReport:
    """Everything a CI job or a tuning loop needs from one verification."""

    verdicts: list[GuidelineVerdict]
    backend_name: str
    alpha: float
    statistic: str
    n_measured: int = 0
    n_resumed: int = 0
    fingerprint: str | None = None
    meta: dict = field(default_factory=dict)

    def violations(self) -> list[GuidelineVerdict]:
        return [v for v in self.verdicts if v.violated]

    @property
    def ok(self) -> bool:
        return not self.violations()


def _guideline_msizes(g: Guideline, msizes) -> tuple[int, ...]:
    return tuple(g.msizes) if g.msizes else tuple(msizes)


def compile_cases(guidelines, msizes=DEFAULT_MSIZES) -> list[TestCase]:
    """Every distinct campaign case the guideline family needs, in first-
    use order. Sides shared between guidelines (or appearing at the same
    effective message size, e.g. a monotonicity rhs that coincides with
    another guideline's lhs) are measured once."""
    out: list[TestCase] = []
    seen = set()
    for g in guidelines:
        for m in _guideline_msizes(g, msizes):
            for case in g.cases(m):
                if case.key() not in seen:
                    seen.add(case.key())
                    out.append(case)
    return out


def verdicts_from_table(
    guidelines,
    table,
    msizes=DEFAULT_MSIZES,
    alpha: float = 0.05,
    statistic: str = "median",
) -> list[GuidelineVerdict]:
    """The statistical half of verification, separated from measurement:
    per-(guideline, msize) one-sided Wilcoxon on an already-measured
    result table, Holm-corrected across the family.

    Splitting this out is what makes the verdict procedure itself
    *testable*: the soundness tier feeds it thousands of synthetic
    null-hypothesis tables and pins the empirical false-violation rate —
    the same code path a real campaign's verdicts take, not a re-derivation.
    """
    guidelines = list(guidelines)
    if not guidelines:
        raise ValueError("verdicts_from_table: empty guideline family")
    cells: list[tuple[Guideline, int, ComparisonRow]] = []
    for g in guidelines:
        for m in _guideline_msizes(g, msizes):
            lhs_case, rhs_case = g.cases(m)
            cells.append((g, m, compare_cases(table, lhs_case, rhs_case,
                                              statistic)))
    p_holm = holm_bonferroni([row.p_a_greater for _, _, row in cells])
    return [
        GuidelineVerdict(
            guideline=g, msize=m,
            lhs_case=row.case, rhs_case=g.cases(m)[1],
            lhs_us=row.avg_a * 1e6, rhs_us=row.avg_b * 1e6,
            ratio=row.ratio,
            p_violated=row.p_a_greater, p_holm=float(adj),
            p_confirmed=row.p_a_less,
            n_epochs=row.n_a, alpha=alpha,
        )
        for (g, m, row), adj in zip(cells, p_holm)
    ]


def verify_guidelines(
    guidelines,
    backend: MeasurementBackend,
    design: ExperimentDesign | None = None,
    msizes=DEFAULT_MSIZES,
    store: ResultStore | None = None,
    alpha: float = 0.05,
    statistic: str = "median",
    name: str = "guidelines",
) -> GuidelineReport:
    """Verify a guideline family against a measurement backend.

    One campaign measures the union of all guideline sides (dedup'd); with
    a ``store`` the campaign resumes — a killed verification re-measures
    only the missing cells, and re-running a finished one measures
    nothing. The default design uses adaptive ``nrep`` so quiet cells stop
    early and heavy-tailed ones get the sample they need.
    """
    guidelines = list(guidelines)
    if not guidelines:
        raise ValueError("verify_guidelines: empty guideline family")
    if design is None:
        design = ExperimentDesign(n_launch_epochs=10, nrep_min=20,
                                  nrep_max=150, rel_ci_target=0.05, seed=0)
    cases = compile_cases(guidelines, msizes)
    spec = CampaignSpec(cases=cases, design=design, name=name)
    res = Campaign(spec, backend, store).run()
    verdicts = verdicts_from_table(guidelines, res.table, msizes=msizes,
                                   alpha=alpha, statistic=statistic)
    return GuidelineReport(
        verdicts=verdicts, backend_name=backend.name, alpha=alpha,
        statistic=statistic, n_measured=res.n_measured,
        n_resumed=res.n_resumed, fingerprint=res.fingerprint,
        meta=dict(n_cases=len(cases), design_seed=design.seed,
                  n_launch_epochs=design.n_launch_epochs),
    )
