"""Performance guidelines as first-class objects (PGMPI, arXiv:1606.00215;
Hunold et al., "Tuning MPI Collectives by Verifying Performance
Guidelines", arXiv:1707.09965).

A *performance guideline* is a self-consistency requirement on a
collectives library: a specialized operation should never lose to a
general one that subsumes its communication pattern, nor to a *mock-up* of
itself built from other collectives run back to back. Each
:class:`Guideline` declares ``lhs ⪯ rhs`` where both sides are op
expressions (:mod:`repro.core.opexpr`) that compile to ordinary campaign
:class:`~repro.core.design.TestCase`\\ s — so a guideline is verified by
the paper's own measurement machinery, not by a separate ad-hoc harness.

Four guideline families are expressible:

  * **pattern containment**  — ``allgather ⪯ alltoall``: the alltoall
    exchange is a superset of allgather's, so a sane library's allgather
    cannot be slower;
  * **mock-up composition**  — ``bcast ⪯ scatter+allgather``,
    ``allreduce ⪯ reduce+bcast``: the library could implement the lhs via
    the rhs sequence, so the dedicated algorithm must not lose to it;
  * **monotonicity**         — ``op(m) ⪯ op(k·m)`` via ``rhs_msize_scale``:
    sending more data must not be faster (a violation is the classic
    protocol-switchover bug);
  * **split-robustness**     — ``allreduce ⪯ allreduce@half+allreduce@half``:
    running on the full communicator must not lose to running the two
    halves one after the other (``p -> p/2 + p/2``).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.design import TestCase
from repro.core.opexpr import parse_opexpr

__all__ = ["Guideline", "SIM_GUIDELINES", "KERNEL_GUIDELINES",
           "default_guidelines"]


@dataclass(frozen=True)
class Guideline:
    """``lhs ⪯ rhs``: the lhs expression must not be (statistically
    significantly) slower than the rhs expression.

    ``rhs_msize_scale`` evaluates the rhs at a scaled message size — the
    monotonicity family (``lhs == rhs``, scale > 1). ``msizes``, when
    non-empty, overrides the verifier's default message-size sweep for
    this guideline (kernel guidelines need block-aligned sequence
    lengths, for example).
    """

    name: str
    lhs: str
    rhs: str
    rhs_msize_scale: float = 1.0
    msizes: tuple = ()
    description: str = ""

    def __post_init__(self):
        # fail at declaration time, not in the middle of a campaign
        parse_opexpr(self.lhs)
        parse_opexpr(self.rhs)
        if self.rhs_msize_scale <= 0:
            raise ValueError(f"guideline {self.name!r}: rhs_msize_scale "
                             "must be positive")

    def cases(self, msize: int) -> tuple[TestCase, TestCase]:
        """The (lhs, rhs) campaign cases of this guideline at ``msize``."""
        rhs_m = max(1, int(round(self.rhs_msize_scale * msize)))
        return TestCase(self.lhs, msize), TestCase(self.rhs, rhs_m)


#: The PGMPI-style self-consistency set for the simulated MPI library —
#: one guideline per family. All four hold for the honest default cost
#: models in :func:`repro.core.mpi_ops.make_op`; a mis-tuned collective
#: (seeded via ``SimBackend(per_op_kw=...)``) is what verification exists
#: to flag.
SIM_GUIDELINES: tuple[Guideline, ...] = (
    Guideline(
        name="allgather_pat_alltoall",
        lhs="allgather", rhs="alltoall",
        description="pattern containment: allgather ⪯ alltoall",
    ),
    Guideline(
        name="bcast_mock_scatter_allgather",
        lhs="bcast", rhs="scatter+allgather",
        description="mock-up: bcast ⪯ scatter+allgather",
    ),
    Guideline(
        name="allreduce_mock_reduce_bcast",
        lhs="allreduce", rhs="reduce+bcast",
        description="mock-up: allreduce ⪯ reduce+bcast",
    ),
    Guideline(
        name="allreduce_mono_msize",
        lhs="allreduce", rhs="allreduce", rhs_msize_scale=4.0,
        description="monotonicity: allreduce(m) ⪯ allreduce(4m)",
    ),
    Guideline(
        name="allreduce_split_procs",
        lhs="allreduce", rhs="allreduce@half+allreduce@half",
        description="split-robustness: allreduce(p) ⪯ 2x allreduce(p/2)",
    ),
)

#: The kernel-layer analogue: a Pallas kernel must not lose to its own jnp
#: reference oracle (both sides measured in the same campaign through
#: ``#impl`` tags). Only meaningful on a real accelerator — in interpret
#: mode (CPU) the Pallas side is emulated and the guideline is expected to
#: fail, which is itself the point: the verdict names the factor.
KERNEL_GUIDELINES: tuple[Guideline, ...] = (
    Guideline(
        name="flash_attention_vs_ref",
        lhs="flash_attention#pallas", rhs="flash_attention#ref",
        msizes=(128,),
        description="kernel: pallas flash_attention ⪯ jnp reference",
    ),
    Guideline(
        name="ssd_scan_vs_ref",
        lhs="ssd_scan#pallas", rhs="ssd_scan#ref",
        msizes=(128,),
        description="kernel: pallas ssd_scan ⪯ jnp reference",
    ),
)


def default_guidelines(backend_name: str) -> tuple[Guideline, ...]:
    """The stock guideline set for a backend family."""
    sets = {"sim": SIM_GUIDELINES, "kernel": KERNEL_GUIDELINES}
    try:
        return sets[backend_name]
    except KeyError:
        raise ValueError(f"no default guideline set for backend "
                         f"{backend_name!r}; one of {sorted(sets)}") from None
