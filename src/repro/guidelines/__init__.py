"""repro.guidelines — PGMPI-style performance-guideline verification.

Declares performance guidelines (``lhs ⪯ rhs`` over collective/mock-up
expressions) as first-class objects and verifies them against any
:class:`~repro.campaign.MeasurementBackend` through the campaign layer:
resumable stores, adaptive ``nrep``, Wilcoxon verdicts with Holm
family-wise correction. This turns the repo from "measures collectives"
into "audits implementations". ::

    from repro.campaign import SimBackend, ResultStore
    from repro.guidelines import SIM_GUIDELINES, verify_guidelines, format_report

    report = verify_guidelines(SIM_GUIDELINES, SimBackend(p=8),
                               store=ResultStore("g.jsonl"))
    print(format_report(report))
    assert report.ok, "guideline violations found"
"""

from .engine import (DEFAULT_MSIZES, GuidelineReport, GuidelineVerdict,
                     compile_cases, verdicts_from_table, verify_guidelines)
from .report import format_report, format_violations
from .rules import (KERNEL_GUIDELINES, SIM_GUIDELINES, Guideline,
                    default_guidelines)

__all__ = [
    "Guideline",
    "SIM_GUIDELINES",
    "KERNEL_GUIDELINES",
    "default_guidelines",
    "GuidelineVerdict",
    "GuidelineReport",
    "compile_cases",
    "verdicts_from_table",
    "verify_guidelines",
    "DEFAULT_MSIZES",
    "format_report",
    "format_violations",
]
