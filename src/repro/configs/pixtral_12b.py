"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, head_dim=128 — Pixtral-ViT frontend (STUB: ``input_specs``
provides precomputed patch embeddings) on a Mistral-NeMo-style decoder
[hf:mistralai/Pixtral-12B-2409].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    act="swiglu",
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=256,      # patch embeddings per image (stub)
)

SMOKE = ModelConfig(
    name="pixtral-smoke",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    act="swiglu",
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=8,
    dtype="float32",
)
