"""The paper's own experimental configuration (§3, Appendix C).

Not an LM architecture: this is the benchmark-suite config used by the
paper's experiments — the operations, message sizes, process counts and
method parameters of Table 4 / Appendix C, exposed so `benchmarks/` and the
examples share one source of truth.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PaperSuite:
    # §3.6 machines: TUWien 16..512 processes; we simulate the same range.
    process_counts: tuple = (8, 16, 32, 64, 128, 256, 512)
    # Table 1 / §6 message sizes: 1 B .. 32 KiB powers of two.
    message_sizes: tuple = tuple(2 ** i for i in range(0, 16))
    # §2/§5 collective operations studied.
    operations: tuple = ("bcast", "allreduce", "alltoall", "scan", "barrier")
    # §6 experimental design defaults (30 mpiruns x 1000 measurements).
    n_launch_epochs: int = 30
    nrep: int = 1000
    # §4 synchronization parameters (N_FITPTS, N_EXCHANGES) grid of Fig. 10.
    sync_params: tuple = ((10, 10), (60, 20), (100, 30), (200, 40),
                          (500, 100), (1000, 100))
    window_sizes_us: tuple = (30, 100, 150, 300, 500, 1000, 10_000)
    significance_level: float = 0.05


CONFIG = PaperSuite()

# Reduced suite for CI-speed runs (same structure, smaller counts).
SMOKE = PaperSuite(
    process_counts=(8, 16),
    message_sizes=(16, 256, 4096),
    n_launch_epochs=6,
    nrep=60,
    sync_params=((60, 20), (200, 40)),
    window_sizes_us=(100, 400),
)
