"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) expert d_ff=16384
vocab=32768 — 8 experts, top-2 routing, sliding-window attention
[arXiv:2401.04088; hf].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    moe_top_k=2,
    window=4096,
    global_every=-1,         # SWA on every layer
    act="swiglu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    n_experts=4,
    moe_top_k=2,
    window=8,
    global_every=-1,
    act="swiglu",
    tie_embeddings=False,
    dtype="float32",
)
