"""zamba2-7b [hybrid]: Mamba-2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242]. The shared transformer block (attention + FFN with a
single set of weights) is applied every 6th layer; its KV cache is allocated
per application (14 applications), not per layer.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    act="geglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    attn_every=2,
    act="geglu",
    dtype="float32",
)
