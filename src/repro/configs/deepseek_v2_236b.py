"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(routed)=1536
vocab=102400 — MLA with kv_lora_rank=512 / q_lora_rank=1536 /
rope_head_dim=64; 2 shared + 160 routed experts, top-6; first layer dense
(d_ff=12288) [arXiv:2405.04434; hf].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,               # dense first layer
    moe_d_ff=1536,            # routed/shared expert hidden
    vocab_size=102400,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    first_dense_layers=1,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    act="swiglu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=8,
    head_dim=8,
    d_ff=160,
    moe_d_ff=32,
    vocab_size=512,
    n_experts=8,
    n_shared_experts=2,
    moe_top_k=2,
    first_dense_layers=1,
    kv_lora_rank=16,
    q_lora_rank=24,
    rope_head_dim=8,
    act="swiglu",
    tie_embeddings=False,
    dtype="float32",
)
