"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000
— GeGLU, head_dim=256, embeddings scaled by sqrt(d) [arXiv:2403.08295; hf].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    act="geglu",
    embed_scale=True,
    dtype="float32",
)
