"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000
— alternating local(4096-window)/global attention, attention- and
final-logit soft-capping, head_dim=256 [arXiv:2408.00118; hf].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    window=4096,
    global_every=2,          # local/global alternating
    attn_softcap=50.0,
    final_softcap=30.0,
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window=8,
    global_every=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="geglu",
    embed_scale=True,
    dtype="float32",
)
