"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280
ssm_state=128 — SSD (state-space duality), expand=2, head_dim=64
[arXiv:2405.21060].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=32,              # unused (attention-free); kept for input_specs
    n_kv_heads=32,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    dtype="float32",
)
