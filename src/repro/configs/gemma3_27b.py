"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global interleaving, 1024-token sliding window on
local layers, head_dim=128, GeGLU [hf:google/gemma-3-*; arXiv:2503.19786].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    window=1024,
    global_every=6,          # 5 local : 1 global
    act="geglu",
    embed_scale=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window=8,
    global_every=6,
    act="geglu",
    embed_scale=True,
    dtype="float32",
)
