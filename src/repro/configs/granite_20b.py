"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-style code model, head_dim=128 [arXiv:2405.04324; hf].
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",
    glu=False,            # gpt_bigcode-style plain MLP
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=1,
    d_ff=192,
    vocab_size=512,
    act="gelu",
    glu=False,
    dtype="float32",
)
