"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596; hf].

The speech frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, frames, d_model) consumed by the encoder.
"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    n_encoder_layers=12,
    cross_attention=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    act="geglu",
    tie_embeddings=True,
    frontend="audio",
    frontend_tokens=1024,     # speech frames per utterance (stub)
)

SMOKE = ModelConfig(
    name="seamless-smoke",
    family="audio",
    n_layers=2,
    n_encoder_layers=2,
    cross_attention=True,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    act="geglu",
    frontend="audio",
    frontend_tokens=16,
    dtype="float32",
)
