"""Assigned input shapes (same four for every LM-family architecture).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``. ``long_500k`` only applies to
sub-quadratic architectures (SSM / hybrid) — see DESIGN.md §4.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["ShapeSpec", "SHAPES", "applicable_shapes"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Families allowed to run the long-context decode shape.
_SUBQUADRATIC = ("ssm", "hybrid")


def applicable_shapes(cfg) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in _SUBQUADRATIC:
        out.append("long_500k")
    return out
