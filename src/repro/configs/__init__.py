"""Architecture registry: the 10 assigned configs + the paper's own suite.

``get_config(arch)`` / ``get_smoke(arch)`` resolve by id; ``ARCHS`` lists
all ids. Shapes live in :mod:`repro.configs.shapes`.
"""

from __future__ import annotations

import importlib

from .shapes import SHAPES, ShapeSpec, applicable_shapes

_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "gemma3-27b": "gemma3_27b",
    "gemma-2b": "gemma_2b",
    "gemma2-2b": "gemma2_2b",
    "granite-20b": "granite_20b",
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "pixtral-12b": "pixtral_12b",
    "mamba2-1.3b": "mamba2_1_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _mod(arch).CONFIG


def get_smoke(arch: str):
    return _mod(arch).SMOKE


__all__ = ["ARCHS", "get_config", "get_smoke", "SHAPES", "ShapeSpec",
           "applicable_shapes"]
