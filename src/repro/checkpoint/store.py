"""Mesh-agnostic checkpointing with async save (fault tolerance substrate).

Checkpoints store every leaf as a full logical array (``.npz`` + a JSON
tree manifest), so a restart may use a *different* mesh shape — the elastic
path: save on 2x16x16, restore on 16x16 (or on the CPU test mesh). Saves
run on a background thread off the training loop (async checkpointing);
``save`` is atomic via tmpdir rename. Retention keeps the newest K steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["CheckpointConfig", "CheckpointStore"]


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "checkpoints"
    keep: int = 3
    async_save: bool = True


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointStore:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, leaves, treedef_repr: str):
        final = os.path.join(self.cfg.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        arrays = {}
        dtypes = {}
        for i, l in enumerate(leaves):
            a = np.asarray(l)
            dtypes[f"leaf_{i}"] = str(a.dtype)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = a.view(np.uint16)  # npz cannot store ml_dtypes natively
            arrays[f"leaf_{i}"] = a
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(leaves),
                       "treedef": treedef_repr, "dtypes": dtypes}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._retain()

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(os.path.join(self.cfg.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, state) -> None:
        """Snapshot state (device->host copy happens synchronously; the
        file write happens on a background thread when async_save)."""
        self.wait()
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        if self.cfg.async_save:
            t = threading.Thread(
                target=self._write, args=(step, host_leaves, str(treedef)))
            t.start()
            self._pending = t
        else:
            self._write(step, host_leaves, str(treedef))

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.cfg.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: int | None = None, shardings=None):
        """Restore into the structure of ``like_tree``; optionally place
        shards per ``shardings`` (a matching tree of NamedSharding) —
        the elastic re-mesh path."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.cfg.directory, f"step_{step:08d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        dtypes = manifest.get("dtypes", {})
        import ml_dtypes

        def _undo(name):
            a = data[name]
            want = dtypes.get(name)
            if want and str(a.dtype) != want:
                a = a.view(ml_dtypes.bfloat16) if want == "bfloat16" \
                    else a.astype(want)
            return a

        leaves, treedef = _flatten(like_tree)
        restored = [_undo(f"leaf_{i}") for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))
            restored = [jax.device_put(a, s)
                        for a, s in zip(restored, sh_leaves)]
        else:
            restored = [
                np.asarray(a).astype(l.dtype) if hasattr(l, "dtype") else a
                for a, l in zip(restored, leaves)
            ]
        return jax.tree_util.tree_unflatten(treedef, restored), step
