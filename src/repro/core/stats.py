"""Statistical machinery for sound MPI/collective benchmarking (§3.5, §5-6).

Self-contained (numpy-only) implementations of everything the paper's
method needs, so the framework has no SciPy dependency on cluster hosts:

  * Tukey's outlier filter (§3.5),
  * Wilcoxon rank-sum / Mann-Whitney test with tie correction and one- or
    two-sided alternatives (§6.2) — the paper's test of choice because MPI
    run-times are *not* normally distributed (§5.1),
  * confidence intervals for the mean (normal and small-sample t),
  * normality diagnostics (Jarque-Bera; the paper uses Kolmogorov-Smirnov /
    Shapiro-Wilk — JB plays the same gatekeeper role for the t-test),
  * autocorrelation function with significance bounds (§5.3, Fig. 18),
  * significance stars for p-values as printed in Figs. 28/30,
  * TOST equivalence testing and percentile-bootstrap CIs — the primitives
    that let a *re-run* be positively certified as reproducing an archived
    reference, not merely "not significantly different".
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = [
    "tukey_filter",
    "tukey_fences",
    "normal_ppf",
    "t_ppf",
    "mean_confidence_interval",
    "relative_ci_width",
    "RankSumResult",
    "wilcoxon_rank_sum",
    "TostResult",
    "tost_wilcoxon",
    "bootstrap_ci",
    "holm_bonferroni",
    "chi2_sf",
    "kruskal_wallis",
    "cliffs_delta",
    "significance_stars",
    "jarque_bera",
    "autocorrelation",
    "autocorr_significant_lags",
    "coefficient_of_variation",
]


# ---------------------------------------------------------------------------
# Outlier handling (§3.5)
# ---------------------------------------------------------------------------

def tukey_fences(x: np.ndarray, k: float = 1.5) -> tuple[float, float]:
    """``(Q1 - k*IQR, Q3 + k*IQR)`` fences of Tukey's filter."""
    x = np.asarray(x, dtype=np.float64)
    q1, q3 = np.percentile(x, [25.0, 75.0])
    iqr = q3 - q1
    return float(q1 - k * iqr), float(q3 + k * iqr)


def tukey_filter(x: np.ndarray, k: float = 1.5) -> np.ndarray:
    """Remove observations outside the Tukey fences (paper §3.5).

    Robust against OS-noise spikes and unknown warm-up length without the
    implicit bias of min-taking benchmarks (Table 2 discussion).
    """
    x = np.asarray(x, dtype=np.float64)
    if x.size < 4:
        return x
    lo, hi = tukey_fences(x, k)
    return x[(x >= lo) & (x <= hi)]


# ---------------------------------------------------------------------------
# Quantiles (numpy-only inverse normal / t)
# ---------------------------------------------------------------------------

def normal_ppf(q: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |rel err| < 1.15e-9)."""
    if not 0.0 < q < 1.0:
        raise ValueError(f"q must be in (0,1), got {q}")
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if q < plow:
        u = math.sqrt(-2 * math.log(q))
        return (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4]) * u + c[5]) / \
               ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1)
    if q > phigh:
        u = math.sqrt(-2 * math.log(1 - q))
        return -(((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4]) * u + c[5]) / \
               ((((d[0] * u + d[1]) * u + d[2]) * u + d[3]) * u + 1)
    u = q - 0.5
    r = u * u
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * u / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def t_ppf(q: float, df: int) -> float:
    """Student-t quantile via the Cornish-Fisher expansion in the normal
    quantile (Hill 1970 style); adequate for CI construction (df >= 3)."""
    if df <= 0:
        raise ValueError("df must be positive")
    z = normal_ppf(q)
    g1 = (z**3 + z) / 4.0
    g2 = (5 * z**5 + 16 * z**3 + 3 * z) / 96.0
    g3 = (3 * z**7 + 19 * z**5 + 17 * z**3 - 15 * z) / 384.0
    g4 = (79 * z**9 + 776 * z**7 + 1482 * z**5 - 1920 * z**3 - 945 * z) / 92160.0
    return z + g1 / df + g2 / df**2 + g3 / df**3 + g4 / df**4


def mean_confidence_interval(x: np.ndarray, level: float = 0.95) -> tuple[float, float, float]:
    """``(mean, lo, hi)`` CI of the sample mean.

    Valid when the sample mean is ~normal — per §5.1 (Fig. 15), this needs
    a sample size of >= ~30 for MPI run-time distributions.
    """
    x = np.asarray(x, dtype=np.float64)
    n = x.size
    m = float(np.mean(x))
    if n < 2:
        return m, m, m
    se = float(np.std(x, ddof=1) / math.sqrt(n))
    q = 0.5 + level / 2.0
    crit = t_ppf(q, n - 1) if n <= 60 else normal_ppf(q)
    return m, m - crit * se, m + crit * se


def relative_ci_width(x: np.ndarray, level: float = 0.95) -> float:
    """Relative half-width of the CI of the mean: ``(hi - lo) / (2 |mean|)``.

    The precision measure behind sequential (adaptive-``nrep``) stopping:
    SKaMPI-style benchmarks repeat a measurement until this drops below a
    target fraction (§3.4's "repeat until the result is stable"). Returns
    ``inf`` when the sample is too small (n < 2) or the mean is zero, so a
    caller's ``rel <= target`` check naturally keeps sampling.
    """
    x = np.asarray(x, dtype=np.float64)
    if x.size < 2:
        return float("inf")
    m, lo, hi = mean_confidence_interval(x, level)
    if m == 0.0:
        return float("inf")
    return float((hi - lo) / (2.0 * abs(m)))


# ---------------------------------------------------------------------------
# Wilcoxon rank-sum (Mann-Whitney) test (§6.2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RankSumResult:
    statistic: float       # Mann-Whitney U of sample A
    z: float               # normal-approximation z score
    p_value: float
    alternative: str
    n_a: int
    n_b: int

    @property
    def significant(self) -> bool:
        return self.p_value <= 0.05

    @property
    def stars(self) -> str:
        return significance_stars(self.p_value)


def _rank_with_ties(values: np.ndarray) -> tuple[np.ndarray, float]:
    """Midranks plus the tie-correction term ``sum(t^3 - t)``."""
    order = np.argsort(values, kind="mergesort")
    ranks = np.empty(values.size, dtype=np.float64)
    sorted_vals = values[order]
    tie_term = 0.0
    i = 0
    while i < values.size:
        j = i
        while j + 1 < values.size and sorted_vals[j + 1] == sorted_vals[i]:
            j += 1
        avg_rank = 0.5 * (i + j) + 1.0
        ranks[order[i:j + 1]] = avg_rank
        t = j - i + 1
        if t > 1:
            tie_term += t**3 - t
        i = j + 1
    return ranks, tie_term


def wilcoxon_rank_sum(a: np.ndarray, b: np.ndarray,
                      alternative: str = "two-sided") -> RankSumResult:
    """WILCOXON TEST of the paper (§6.2): nonparametric comparison of two
    independent samples (e.g. the 30 per-mpirun medians of two MPI
    libraries, Fig. 28).

    ``alternative='less'`` tests H_a: A < B (the "is library X faster?"
    question of Fig. 30); ``'greater'`` the reverse. Normal approximation
    with tie correction and continuity correction — appropriate for the
    paper's regime (n >= ~10 per side).
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    n1, n2 = a.size, b.size
    if n1 == 0 or n2 == 0:
        raise ValueError("empty sample")
    if alternative not in ("two-sided", "less", "greater"):
        raise ValueError(f"unknown alternative {alternative!r}")
    combined = np.concatenate([a, b])
    ranks, tie_term = _rank_with_ties(combined)
    r1 = float(np.sum(ranks[:n1]))
    u1 = r1 - n1 * (n1 + 1) / 2.0   # Mann-Whitney U of sample A
    mu = n1 * n2 / 2.0
    n = n1 + n2
    sigma2 = n1 * n2 / 12.0 * ((n + 1) - tie_term / (n * (n - 1)))
    if sigma2 <= 0.0:
        # every observation tied: under the permutation null every
        # assignment yields this same U, so the exact p is 1 for every
        # alternative — crucially NOT 0, which the continuity-corrected
        # normal approximation would produce from the floored sigma (and
        # which would let two bit-identical constant runs test "different")
        return RankSumResult(statistic=u1, z=0.0, p_value=1.0,
                             alternative=alternative, n_a=n1, n_b=n2)
    sigma = math.sqrt(sigma2)

    def z_of(u: float, shift: float) -> float:
        return (u - mu + shift) / sigma

    if alternative == "two-sided":
        z = z_of(u1, -0.5 * math.copysign(1.0, u1 - mu))
        p = 2.0 * 0.5 * math.erfc(abs(z) / math.sqrt(2.0))
        p = min(1.0, p)
    elif alternative == "less":
        # small U1 (A ranked low -> A smaller) is evidence for A < B
        z = z_of(u1, +0.5)
        p = 0.5 * math.erfc(-z / math.sqrt(2.0))  # P(Z <= z)
    elif alternative == "greater":
        z = z_of(u1, -0.5)
        p = 0.5 * math.erfc(z / math.sqrt(2.0))   # P(Z >= z)
    else:
        raise ValueError(f"unknown alternative {alternative!r}")
    return RankSumResult(statistic=u1, z=z, p_value=float(p),
                         alternative=alternative, n_a=n1, n_b=n2)


@dataclass(frozen=True)
class TostResult:
    """Outcome of a two-one-sided-tests (TOST) equivalence test."""

    p_value: float         # max of the two one-sided p-values
    p_lower: float         # H_a: a > (1 - margin) * b  (not too far below)
    p_upper: float         # H_a: a < (1 + margin) * b  (not too far above)
    margin: float
    n_a: int
    n_b: int

    def equivalent(self, alpha: float = 0.05) -> bool:
        """Equivalence demonstrated at ``alpha`` — deliberately a method,
        not a 5%-hardcoded property: certifying at the wrong level is the
        dangerous direction, and family-wise users must pass their
        *corrected* threshold."""
        return self.p_value <= alpha


def tost_wilcoxon(a: np.ndarray, b: np.ndarray,
                  margin: float = 0.10) -> TostResult:
    """Nonparametric TOST equivalence test with a *relative* margin.

    Difference tests (the Wilcoxon above) can only ever *fail to refute*
    sameness — "no significant difference" is weak evidence that gets
    weaker as the sample shrinks. Certifying reproducibility needs the
    burden of proof reversed: the null hypothesis here is *non*-equivalence
    (``a`` below ``(1-margin)·b`` or above ``(1+margin)·b``), and only data
    can overturn it. Both one-sided nulls are tested by the Wilcoxon
    rank-sum against the margin-scaled ``b`` sample; rejecting both (the
    reported ``p_value`` is the max, the standard intersection-union
    argument, no multiplicity correction needed between the pair) concludes
    that ``a`` lies within ``±margin`` of ``b`` on the ratio scale.

    Run-times are strictly positive, which is what makes the relative
    margin (and the scaling of ``b``) meaningful; both samples are
    required to be > 0.

    Each one-sided p is floored at ``1 / C(n_a+n_b, n_a)`` — the exact
    probability of complete separation under H0, the smallest p the exact
    rank-sum test can produce. The normal approximation dips *below* that
    at tiny n, and for an equivalence test anti-conservatism is the
    dangerous direction: it would let two or three noisy epochs "certify"
    a reproduction.
    """
    if not 0.0 < margin < 1.0:
        raise ValueError(f"margin must be in (0, 1), got {margin}")
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    if a.size == 0 or b.size == 0:
        raise ValueError("empty sample")
    if np.any(a <= 0) or np.any(b <= 0):
        raise ValueError("tost_wilcoxon: a relative margin needs strictly "
                         "positive samples (run-times)")
    p_min = 1.0 / math.comb(a.size + b.size, a.size)
    p_lower = max(p_min,
                  wilcoxon_rank_sum(a, (1.0 - margin) * b, "greater").p_value)
    p_upper = max(p_min,
                  wilcoxon_rank_sum(a, (1.0 + margin) * b, "less").p_value)
    return TostResult(p_value=float(max(p_lower, p_upper)),
                      p_lower=float(p_lower), p_upper=float(p_upper),
                      margin=float(margin), n_a=a.size, n_b=b.size)


def bootstrap_ci(statistic, samples, n_boot: int = 1000,
                 level: float = 0.95, seed: int = 0) -> tuple[float, float]:
    """Percentile-bootstrap confidence interval of ``statistic(*samples)``.

    Each sample is resampled independently with replacement (they come
    from independent runs/epochs), the statistic is recomputed per
    replicate, and the ``(1-level)/2`` tails of the replicate distribution
    are the interval. Distribution-free — the right companion for a
    statistic like the ratio of medians, whose sampling distribution has
    no usable closed form in the paper's non-normal regime.
    """
    if not 0.0 < level < 1.0:
        raise ValueError(f"level must be in (0, 1), got {level}")
    if n_boot < 1:
        raise ValueError(f"n_boot must be >= 1, got {n_boot}")
    arrays = [np.asarray(s, dtype=np.float64) for s in samples]
    if not arrays or any(s.size == 0 for s in arrays):
        raise ValueError("empty sample")
    rng = np.random.default_rng(seed)
    reps = np.empty(n_boot, dtype=np.float64)
    for i in range(n_boot):
        reps[i] = statistic(*(s[rng.integers(0, s.size, s.size)]
                              for s in arrays))
    tail = 100.0 * (1.0 - level) / 2.0
    lo, hi = np.percentile(reps, [tail, 100.0 - tail])
    return float(lo), float(hi)


def holm_bonferroni(pvals) -> np.ndarray:
    """Holm's step-down adjusted p-values (family-wise error control).

    Verifying a whole family of performance guidelines means one Wilcoxon
    test per (guideline, message size) cell; declaring a violation whenever
    any raw p <= alpha would inflate the family-wise false-violation rate
    far past alpha. Holm's procedure — ``adj_(i) = max_{j<=i} (m-j+1) *
    p_(j)`` over the ascending order, clipped at 1 — is uniformly more
    powerful than plain Bonferroni and needs no independence assumption,
    which matters because guideline tests share measurement cells.
    """
    p = np.asarray(pvals, dtype=np.float64)
    if p.ndim != 1:
        raise ValueError("holm_bonferroni expects a 1-D array of p-values")
    m = p.size
    if m == 0:
        return p.copy()
    if np.any((p < 0) | (p > 1) | ~np.isfinite(p)):
        raise ValueError("p-values must be finite and in [0, 1]")
    order = np.argsort(p, kind="mergesort")
    stepped = (m - np.arange(m)) * p[order]
    adj_sorted = np.minimum(np.maximum.accumulate(stepped), 1.0)
    adj = np.empty(m)
    adj[order] = adj_sorted
    return adj


def chi2_sf(x: float, df: int) -> float:
    """Chi-square survival function ``P(X > x)`` for integer ``df``.

    Closed forms via the regularized upper incomplete gamma at integer and
    half-integer shape (no SciPy): for even ``df`` a finite Poisson sum,
    for odd ``df`` the erfc term plus a finite sum with half-integer
    gamma weights. Exact (up to float rounding) for every integer df —
    the null distribution of the Kruskal-Wallis H statistic below.
    """
    if df < 1:
        raise ValueError(f"df must be a positive integer, got {df}")
    if x <= 0.0:
        return 1.0
    h = x / 2.0
    if df % 2 == 0:
        # Q(h, m) = exp(-h) * sum_{k<m} h^k / k!,  m = df/2
        term, total = 1.0, 1.0
        for k in range(1, df // 2):
            term *= h / k
            total += term
        return float(min(1.0, math.exp(-h) * total))
    # odd df = 2m+1: Q = erfc(sqrt(h)) + exp(-h) * sum_{k=1..m} h^(k-1/2)/G(k+1/2)
    m = (df - 1) // 2
    total = math.erfc(math.sqrt(h))
    if m > 0:
        # h^(k-1/2) / Gamma(k+1/2), built iteratively to avoid overflow
        term = math.sqrt(h) / math.gamma(1.5)          # k = 1
        acc = term
        for k in range(2, m + 1):
            term *= h / (k - 0.5)
            acc += term
        total += math.exp(-h) * acc
    return float(min(1.0, total))


def kruskal_wallis(samples) -> tuple[float, float]:
    """Kruskal-Wallis H test across ``k`` independent samples ->
    ``(H, p_value)``.

    The k-level generalization of the Wilcoxon rank-sum test — the
    paper-consistent (nonparametric, §5.1) omnibus test for "does this
    experimental factor have *any* effect across its levels?". Tie-
    corrected; the null distribution is chi-square with ``k - 1`` degrees
    of freedom (adequate for the sweep regime, every group >= ~5).
    """
    groups = [np.asarray(s, dtype=np.float64) for s in samples]
    if len(groups) < 2:
        raise ValueError("kruskal_wallis needs at least 2 samples")
    if any(g.size == 0 for g in groups):
        raise ValueError("kruskal_wallis: empty sample")
    n = np.array([g.size for g in groups])
    total = int(n.sum())
    ranks, tie_term = _rank_with_ties(np.concatenate(groups))
    h = 0.0
    pos = 0
    for size in n:
        r = float(np.sum(ranks[pos:pos + size]))
        h += r * r / size
        pos += size
    h = 12.0 / (total * (total + 1)) * h - 3.0 * (total + 1)
    correction = 1.0 - tie_term / (total**3 - total)
    if correction <= 0.0:      # every observation tied: no information
        return 0.0, 1.0
    h /= correction
    return float(h), chi2_sf(float(h), len(groups) - 1)


def cliffs_delta(a: np.ndarray, b: np.ndarray) -> float:
    """Cliff's delta effect size ``P(a > b) - P(a < b)`` in ``[-1, 1]``.

    The ordinal companion to the rank tests: +1 means every ``a``
    observation exceeds every ``b`` (sample A strictly slower when the
    samples are run-times), 0 means complete overlap. Unlike a p-value it
    does not grow with sample size, so it is the sound *ranking* key for
    "which factors matter most" (|delta|), with the Wilcoxon/KW p-values
    gating significance.
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    if a.size == 0 or b.size == 0:
        raise ValueError("empty sample")
    bs = np.sort(b)
    n_less = np.searchsorted(bs, a, side="left").sum()     # b < a_i pairs
    n_greater = (b.size - np.searchsorted(bs, a, side="right")).sum()
    return float((int(n_less) - int(n_greater)) / (a.size * b.size))


def significance_stars(p: float) -> str:
    """The paper's asterisk notation: *** p<=0.001, ** p<=0.01, * p<=0.05."""
    if p <= 0.001:
        return "***"
    if p <= 0.01:
        return "**"
    if p <= 0.05:
        return "*"
    return ""


# ---------------------------------------------------------------------------
# Normality & independence diagnostics (§5.1, §5.3)
# ---------------------------------------------------------------------------

def jarque_bera(x: np.ndarray) -> tuple[float, float]:
    """Jarque-Bera normality test -> ``(statistic, p_value)``.

    Plays the role of the paper's KS/Shapiro-Wilk gate before a t-test
    (§6.2): the JB statistic is asymptotically chi-square(2), whose survival
    function is ``exp(-x/2)`` — no special functions needed.
    """
    x = np.asarray(x, dtype=np.float64)
    n = x.size
    if n < 8:
        return 0.0, 1.0
    m = x.mean()
    d = x - m
    s2 = float(np.mean(d**2))
    if s2 <= 0:
        return 0.0, 1.0
    skew = float(np.mean(d**3)) / s2**1.5
    kurt = float(np.mean(d**4)) / s2**2
    jb = n / 6.0 * (skew**2 + 0.25 * (kurt - 3.0) ** 2)
    return jb, float(math.exp(-jb / 2.0))


def autocorrelation(x: np.ndarray, max_lag: int = 50) -> np.ndarray:
    """ACF coefficients ``C_h / C_0`` for lags 0..max_lag (§5.3)."""
    x = np.asarray(x, dtype=np.float64)
    n = x.size
    max_lag = min(max_lag, n - 1)
    d = x - x.mean()
    c0 = float(np.dot(d, d)) / n
    if c0 <= 0:
        return np.zeros(max_lag + 1)
    acf = np.empty(max_lag + 1)
    for h in range(max_lag + 1):
        acf[h] = float(np.dot(d[: n - h], d[h:])) / n / c0
    return acf


def autocorr_significant_lags(x: np.ndarray, max_lag: int = 50) -> np.ndarray:
    """Lags (>=1) whose ACF exceeds the 95% significance bound 1.96/sqrt(n).

    Empty result => measurements can be treated as independent; otherwise
    the paper suggests sub-sampling (§5.3, Fig. 18b).
    """
    x = np.asarray(x, dtype=np.float64)
    acf = autocorrelation(x, max_lag)
    bound = 1.96 / math.sqrt(max(1, x.size))
    lags = np.arange(1, acf.size)
    return lags[np.abs(acf[1:]) > bound]


def coefficient_of_variation(x: np.ndarray) -> float:
    x = np.asarray(x, dtype=np.float64)
    m = float(np.mean(x))
    return float(np.std(x, ddof=1) / m) if x.size > 1 and m != 0 else 0.0
