"""Fair comparison of two implementations via hypothesis testing (§6.2).

Given two :class:`~repro.core.design.ResultTable`\\ s (library/config A vs B),
apply the WILCOXON TEST per test case on the distributions of per-epoch
averages, reporting two-sided significance (Fig. 28) and the one-sided
"is A faster than B?" question (Fig. 30). Comparing on single means —
common practice the paper argues against — is available as
``naive_comparison`` for the benchmarks that demonstrate its instability.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .design import ResultTable, TestCase
from .stats import significance_stars, wilcoxon_rank_sum

__all__ = ["ComparisonRow", "compare_tables", "compare_cases",
           "naive_comparison", "format_comparison"]


@dataclass
class ComparisonRow:
    case: TestCase
    avg_a: float
    avg_b: float
    ratio: float              # avg_a / avg_b
    p_two_sided: float
    p_a_less: float           # H_a: A < B   ("A is faster")
    p_a_greater: float        # H_a: A > B
    n_a: int
    n_b: int

    @property
    def stars(self) -> str:
        return significance_stars(self.p_two_sided)

    @property
    def verdict(self) -> str:
        """Human-readable conclusion at the 5% level."""
        if self.p_a_less <= 0.05:
            return "A<B"
        if self.p_a_greater <= 0.05:
            return "A>B"
        return "indistinguishable"


def compare_tables(
    table_a: ResultTable,
    table_b: ResultTable,
    statistic: str = "median",
) -> list[ComparisonRow]:
    """Per-case Wilcoxon comparison of the per-epoch ``median`` (default,
    as in Fig. 28) or ``mean`` distributions.

    Accepts anything exposing ``cases()``/``medians()``/``means()`` or a
    ``to_table()`` adapter — in particular a
    :class:`~repro.campaign.ResultStore`, so persisted campaigns compare
    across stores and across runs without manual reloading.

    Raises :class:`ValueError` when the two tables share no ``(op, msize)``
    cell with data on both sides — an empty comparison almost always means
    the wrong stores (or the wrong fingerprints) were paired, and silently
    returning an empty table turns that mistake into a vacuous "no
    significant difference" downstream.
    """
    if hasattr(table_a, "to_table"):
        table_a = table_a.to_table()
    if hasattr(table_b, "to_table"):
        table_b = table_b.to_table()
    get = (lambda t, c: t.medians(c)) if statistic == "median" else (lambda t, c: t.means(c))
    keys_b = {c.key() for c in table_b.cases()}
    rows: list[ComparisonRow] = []
    for case in table_a.cases():
        if case.key() not in keys_b:
            continue
        a = get(table_a, case)
        b = get(table_b, case)
        if a.size == 0 or b.size == 0:
            continue
        rows.append(_compare_row(case, a, b))
    if not rows:
        ka = sorted(c.key() for c in table_a.cases())
        kb = sorted(c.key() for c in table_b.cases())
        raise ValueError(
            "compare_tables: no common (op, msize) cells with data on both "
            f"sides — A has {ka or 'no cases'}, B has {kb or 'no cases'}. "
            "Check that the right stores/fingerprints were paired.")
    return rows


def _compare_row(case: TestCase, a: np.ndarray, b: np.ndarray) -> ComparisonRow:
    return ComparisonRow(
        case=case,
        avg_a=float(np.mean(a)),
        avg_b=float(np.mean(b)),
        ratio=float(np.mean(a) / np.mean(b)) if np.mean(b) else float("nan"),
        p_two_sided=wilcoxon_rank_sum(a, b, "two-sided").p_value,
        p_a_less=wilcoxon_rank_sum(a, b, "less").p_value,
        p_a_greater=wilcoxon_rank_sum(a, b, "greater").p_value,
        n_a=int(a.size),
        n_b=int(b.size),
    )


def compare_cases(
    table: ResultTable,
    case_a: TestCase,
    case_b: TestCase,
    statistic: str = "median",
) -> ComparisonRow:
    """Wilcoxon comparison of two *cases inside one table* — the primitive
    of guideline verification (PGMPI): both sides of ``lhs <= rhs`` are
    measured in the same campaign (same launch epochs, same factor set),
    and their per-epoch ``median`` (default) or ``mean`` distributions are
    compared. The returned row's ``case`` is ``case_a`` (the lhs).
    """
    if hasattr(table, "to_table"):
        table = table.to_table()
    get = (lambda c: table.medians(c)) if statistic == "median" \
        else (lambda c: table.means(c))
    a, b = get(case_a), get(case_b)
    if a.size == 0 or b.size == 0:
        missing = [c.key() for c, x in ((case_a, a), (case_b, b))
                   if x.size == 0]
        raise ValueError(f"compare_cases: no data for {missing}; table has "
                         f"{sorted(c.key() for c in table.cases())}")
    return _compare_row(case_a, a, b)


def naive_comparison(table_a: ResultTable, table_b: ResultTable,
                     epoch: int = 0) -> list[tuple[TestCase, float, float]]:
    """The practice the paper warns about (Fig. 27): compare single-epoch
    means and call the smaller one the winner, no dispersion, no test."""
    out = []
    keys_b = {c.key() for c in table_b.cases()}
    for case in table_a.cases():
        if case.key() not in keys_b:
            continue
        a = [s.mean for s in table_a.summaries if s.case.key() == case.key() and s.epoch == epoch]
        b = [s.mean for s in table_b.summaries if s.case.key() == case.key() and s.epoch == epoch]
        if a and b:
            out.append((case, a[0], b[0]))
    return out


def format_comparison(rows: list[ComparisonRow], name_a: str = "A",
                      name_b: str = "B") -> str:
    lines = [
        f"{'op':<12} {'msize':>8} {name_a + ' [us]':>12} {name_b + ' [us]':>12} "
        f"{'ratio':>7} {'p(2s)':>9} {'sig':>4} {'verdict':>18}"
    ]
    for r in rows:
        lines.append(
            f"{r.case.op:<12} {r.case.msize:>8} {r.avg_a * 1e6:>12.2f} "
            f"{r.avg_b * 1e6:>12.2f} {r.ratio:>7.3f} {r.p_two_sided:>9.2e} "
            f"{r.stars:>4} {r.verdict:>18}"
        )
    return "\n".join(lines)
