"""Exponential backoff with full jitter — the fleet's only sleep policy.

A measurement fleet retries constantly: a crashed worker's cell goes back
on the queue, a stalled lease is re-claimed, a transient exception is
re-attempted. Every one of those retries must (a) back off exponentially
so a sick host does not hammer the scheduler, (b) jitter the delay so a
fleet of workers whose leases expired together does not retry in
lock-step (the "thundering herd" the AWS architecture blog's *full
jitter* policy exists to break), and (c) be *deterministic under a seed*
so the tier-1 tests can assert the exact retry schedule instead of
trusting it.

:class:`RetryPolicy` is a frozen dataclass computing per-attempt delays;
:func:`retry_call` is the loop. There is deliberately no ad-hoc
``time.sleep`` anywhere in :mod:`repro.fleet` — every wait is a policy
delay, every policy is seedable.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Iterable

import numpy as np

__all__ = ["RetryPolicy", "RetryBudgetExceeded", "retry_call"]


class RetryBudgetExceeded(Exception):
    """Raised by :func:`retry_call` when every attempt failed; carries the
    last underlying exception as ``__cause__`` and the attempt count."""

    def __init__(self, attempts: int, last: BaseException):
        super().__init__(f"all {attempts} attempts failed "
                         f"(last: {type(last).__name__}: {last})")
        self.attempts = attempts
        self.last = last


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with *full* jitter and a deadline cap.

    ``delay(attempt)`` for attempt ``k`` (0-based: the delay *before*
    retry ``k+1``) is drawn uniformly from ``[0, min(max_delay,
    base * factor**k)]`` — full jitter, not equal jitter: the whole
    interval is randomized, which de-correlates retries best. With a
    ``seed`` the draw is a pure function of ``(seed, key, attempt)``, so
    a test (or a resumed scheduler) replays the identical schedule;
    ``key`` lets many independent schedules (one per sweep cell) share
    one policy without sharing their jitter streams.

    ``deadline`` caps the *cumulative* delay: :func:`retry_call` and the
    fleet's lease queue stop retrying once the total backoff spent would
    exceed it, whatever ``attempts`` says.
    """

    base: float = 0.05            # first backoff ceiling [s]
    factor: float = 2.0           # exponential growth per attempt
    max_delay: float = 2.0        # per-attempt ceiling [s]
    attempts: int = 4             # total tries (1 initial + attempts-1 retries)
    deadline: float | None = None  # cumulative backoff cap [s]
    seed: int | None = None       # None = nondeterministic jitter

    def __post_init__(self):
        if self.base < 0 or self.max_delay < 0:
            raise ValueError("RetryPolicy: delays must be >= 0")
        if self.factor < 1.0:
            raise ValueError("RetryPolicy: factor must be >= 1 (backoff "
                             "must not shrink)")
        if self.attempts < 1:
            raise ValueError("RetryPolicy: attempts must be >= 1")

    def ceiling(self, attempt: int) -> float:
        """The un-jittered backoff ceiling for 0-based ``attempt``."""
        return float(min(self.max_delay, self.base * self.factor ** attempt))

    def delay(self, attempt: int, key: int = 0) -> float:
        """The jittered delay before retry ``attempt + 1``."""
        hi = self.ceiling(attempt)
        if hi == 0.0:
            return 0.0
        if self.seed is None:
            rng = np.random.default_rng()
        else:
            # stateless: a pure function of (seed, key, attempt), so the
            # schedule survives process restarts and replays under test
            rng = np.random.default_rng((self.seed, key, attempt))
        return float(rng.uniform(0.0, hi))

    def delays(self, key: int = 0) -> Iterable[float]:
        """The full (deadline-capped) delay schedule, one entry per retry."""
        spent = 0.0
        for k in range(self.attempts - 1):
            d = self.delay(k, key)
            if self.deadline is not None and spent + d > self.deadline:
                return
            spent += d
            yield d


def retry_call(
    fn: Callable[[], Any],
    policy: RetryPolicy,
    retry_on: tuple[type[BaseException], ...] = (Exception,),
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Callable[[int, BaseException, float], None] | None = None,
    key: int = 0,
) -> Any:
    """Call ``fn()`` under ``policy``: up to ``policy.attempts`` tries,
    sleeping the policy's jittered delay between them.

    Only exceptions matching ``retry_on`` are retried; anything else
    propagates immediately (a programming error must not be retried into
    silence). ``on_retry(attempt, exc, delay)`` fires before each sleep —
    the logging hook. Raises :class:`RetryBudgetExceeded` (chaining the
    last exception) when the budget — attempts or cumulative deadline —
    is exhausted.
    """
    last: BaseException | None = None
    spent = 0.0
    for attempt in range(policy.attempts):
        try:
            return fn()
        except retry_on as e:
            last = e
        d = policy.delay(attempt, key)
        if attempt == policy.attempts - 1 or (
                policy.deadline is not None and spent + d > policy.deadline):
            break
        if on_retry is not None:
            on_retry(attempt, last, d)
        sleep(d)
        spent += d
    raise RetryBudgetExceeded(attempt + 1, last) from last
