"""Discrete-event simulation of a cluster's host control plane (§3, §4).

The paper's clock-synchronization algorithms run over MPI point-to-point
messages between *hosts*. On a TPU pod the same algorithms run over the host
control plane (gRPC/ICI-host network); on this CPU-only CI they run against
this simulator, which models:

  * per-host hardware clocks (offset + skew + optional random walk),
    see :mod:`repro.core.clocks`,
  * a host network with lognormal one-way latency noise and occasional
    OS-noise spikes (the heavy right tail seen in Fig. 32 of the paper),
  * per-host "program counter" timelines so hierarchical rounds of pairwise
    exchanges execute concurrently, like real MPI ranks (this is what makes
    the sync-duration Pareto analysis of Fig. 10 meaningful).

All quantities are in seconds of *true* simulated time. Hosts never see true
time: every algorithm only reads local clocks via :meth:`SimNet.local_time`.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .clocks import SimClock

__all__ = ["NetParams", "ClockParams", "SimNet", "PingPongSample"]


@dataclass
class NetParams:
    """Host-network latency model.

    Defaults are calibrated to the paper's InfiniBand clusters (RTT of a
    small message ~10-40 us, Fig. 32-33) which is also representative of a
    TPU-pod host fabric.
    """

    one_way: float = 8e-6           # base one-way latency [s]
    jitter_sigma: float = 0.25      # lognormal sigma on one-way latency
    spike_prob: float = 2e-3        # probability of an OS-noise spike
    spike_scale: float = 25.0       # spike multiplies the one-way latency
    proc_overhead: float = 3e-7     # local per-message processing [s]


@dataclass
class ClockParams:
    """Distribution of per-host clock imperfections.

    ``skew_sigma=5e-6`` reproduces the magnitude of Fig. 3: two hosts drift
    apart by several hundred microseconds over 50 s.
    """

    offset_spread: float = 5e-3     # initial offsets ~ U(-spread, +spread) [s]
    skew_sigma: float = 5e-6        # relative frequency error ~ N(0, sigma)
    rw_sigma: float = 0.0           # oscillator random walk [s / sqrt(s)]
    freq_est_sigma: float = 0.0     # frequency-estimation error (§4.2.1); set
                                    # ~4.3e-6 to model Netgauge's HRT_CALIBRATE


@dataclass
class PingPongSample:
    """Timestamps of one ping-pong exchange (client -> server -> client)."""

    t_send_client: float   # client local clock when the ping was sent
    t_server: float        # server local clock when it stamped the reply
    t_recv_client: float   # client local clock when the reply arrived


class SimNet:
    """A simulated cluster of ``p`` hosts with clocks and a lossless network."""

    def __init__(
        self,
        p: int,
        net: NetParams | None = None,
        clocks: ClockParams | None = None,
        seed: int = 0,
    ) -> None:
        self.p = int(p)
        self.net = net or NetParams()
        self.clock_params = clocks or ClockParams()
        self.rng = np.random.default_rng(seed)
        cp = self.clock_params
        self.clocks = [
            SimClock(
                offset=float(self.rng.uniform(-cp.offset_spread, cp.offset_spread)),
                skew=float(self.rng.normal(0.0, cp.skew_sigma)),
                rw_sigma=cp.rw_sigma,
                scale_error=float(self.rng.normal(0.0, cp.freq_est_sigma)) if cp.freq_est_sigma else 0.0,
                seed=int(self.rng.integers(0, 2**31 - 1)),
            )
            for _ in range(self.p)
        ]
        # Per-host true-time program counters.
        self.t = np.zeros(self.p, dtype=np.float64)
        self.msg_count = 0

    # ------------------------------------------------------------------ time
    def local_time(self, r: int) -> float:
        """Read host ``r``'s hardware clock (what GET_TIME returns)."""
        return self.clocks[r].read(self.t[r])

    def true_time(self, r: int) -> float:
        """Simulator-only ground truth; never exposed to algorithms."""
        return float(self.t[r])

    def true_time_at_local(self, r: int, local: float) -> float:
        """Invert host ``r``'s clock (simulator bookkeeping for waits).

        Exact for affine clocks and for random-walk clocks in drift-path
        mode; for a lazy walk the inversion freezes the walk at its last
        sampled value (see :meth:`SimClock.true_at_local`).
        """
        return self.clocks[r].true_at_local(local)

    def freeze_drift_paths(self, dt: float, ranks: list[int] | None = None):
        """Switch the given clocks' random walks to pre-sampled drift-path
        mode (node spacing ``dt``); idempotent. The batched random-walk
        window engine does this implicitly — tests freeze both nets up
        front so scalar and batch runs traverse identical walks."""
        ranks = range(self.p) if ranks is None else ranks
        return [self.clocks[r].drift_path(dt) for r in ranks]

    def advance(self, r: int, dt: float) -> None:
        """Host ``r`` computes locally for ``dt`` true seconds."""
        self.t[r] += max(0.0, dt)

    def wait_until_local(self, r: int, local_deadline: float) -> bool:
        """Busy-wait host ``r`` until its local clock shows ``local_deadline``.

        Returns ``False`` if the deadline already passed (the window-based
        scheme's START_LATE error).
        """
        target = self.true_time_at_local(r, local_deadline)
        if target <= self.t[r]:
            return False
        self.t[r] = target
        return True

    def sleep_all(self, dt: float) -> None:
        """All hosts idle for ``dt`` true seconds (used between probes)."""
        self.t += dt

    # --------------------------------------------------------------- network
    def _latency(self) -> float:
        lat = self.net.one_way * float(self.rng.lognormal(0.0, self.net.jitter_sigma))
        if self.rng.random() < self.net.spike_prob:
            lat *= self.net.spike_scale
        return lat

    def transfer(self, src: int, dst: int) -> None:
        """One-way message; advances both hosts' timelines appropriately.

        The receiver is assumed to be blocked in a receive: delivery happens
        at ``max(t_dst, t_src + latency)``.
        """
        self.msg_count += 1
        send_done = self.t[src] + self.net.proc_overhead
        self.t[src] = send_done
        arrival = max(self.t[dst], send_done + self._latency())
        self.t[dst] = arrival + self.net.proc_overhead

    def pingpong(self, client: int, server: int) -> PingPongSample:
        """One client->server->client exchange with local timestamps.

        This is the primitive underlying SKAMPI_PINGPONG (Alg. 7),
        COMPUTE_OFFSET (Alg. 12), COMPUTE_RTT (Alg. 17) and the fitpoint
        collection of JK / LEARN_MODEL_HCA (Algs. 15 / 4).
        """
        t_send_client = self.local_time(client)
        self.transfer(client, server)
        t_server = self.local_time(server)
        self.transfer(server, client)
        t_recv_client = self.local_time(client)
        return PingPongSample(t_send_client, t_server, t_recv_client)

    def _latencies(self, n: int) -> np.ndarray:
        lat = self.net.one_way * self.rng.lognormal(0.0, self.net.jitter_sigma, size=n)
        spikes = self.rng.random(n) < self.net.spike_prob
        lat[spikes] *= self.net.spike_scale
        return lat

    def pingpong_batch(self, client: int, server: int, n: int):
        """Vectorized sequence of ``n`` ping-pong exchanges.

        Semantically identical to ``n`` calls of :meth:`pingpong` (the server
        sits in a receive loop after the first delivery), but samples all
        latencies at once so the large fitpoint sweeps of JK/HCA (up to
        ``N_FITPTS x N_EXCHANGES`` exchanges per pair) stay tractable in the
        discrete-event simulation.

        Returns local-clock arrays ``(t_send_client, t_server, t_recv_client)``.
        """
        if n <= 0:
            return (np.empty(0), np.empty(0), np.empty(0))
        oh = self.net.proc_overhead
        lat1 = self._latencies(n)
        lat2 = self._latencies(n)
        # True-time recurrence: send_i = recv_{i-1} + oh ; srv_i = send_i +
        # lat1_i + oh ; recv_i = srv_i + lat2_i + oh. Only the first delivery
        # needs the max() against the server's availability.
        send = np.empty(n)
        srv = np.empty(n)
        recv = np.empty(n)
        send[0] = self.t[client] + oh
        srv[0] = max(self.t[server], send[0] + lat1[0]) + oh
        recv[0] = srv[0] + lat2[0] + oh
        if n > 1:
            # Per-exchange duration after the pipeline is primed.
            d = 3 * oh + lat1[1:] + lat2[1:]
            recv[1:] = recv[0] + np.cumsum(d)
            send[1:] = recv[:-1] + oh
            srv[1:] = send[1:] + lat1[1:] + oh
        self.t[client] = recv[-1]
        self.t[server] = srv[-1]
        self.msg_count += 2 * n
        c = self.clocks[client]
        s = self.clocks[server]
        return (c.read_affine(send), s.read_affine(srv), c.read_affine(recv))

    # -------------------------------------------------------------- barriers
    def dissemination_barrier(self, ranks: list[int] | None = None) -> np.ndarray:
        """Framework-owned dissemination barrier (cf. §4.6 / Taubenfeld [20]).

        ``ceil(log2 p)`` rounds; in round ``k`` rank ``i`` signals rank
        ``(i + 2^k) mod p`` and proceeds once it heard from
        ``(i - 2^k) mod p``. Returns the per-rank *true* exit times
        (simulator-side; experiments read clocks separately).

        Each round is evaluated as one latency-vector update (``np.roll``
        of the pre-round send times) instead of a per-rank Python loop;
        the per-round arrival rule is unchanged.
        """
        ranks = list(range(self.p)) if ranks is None else ranks
        n = len(ranks)
        oh = self.net.proc_overhead
        t = self.t[ranks]
        k = 1
        while k < n:
            send_time = t + oh
            # rotate right by k: receiver i hears from (i - k) mod n
            rotated = np.concatenate((send_time[n - k:], send_time[:n - k]))
            arrival = rotated + self._latencies(n)
            t = np.maximum(t + oh, arrival)
            self.msg_count += n
            k *= 2
        self.t[ranks] = t
        return t.copy()

    def _dissemination_barrier_scalar(self, ranks: list[int] | None = None) -> np.ndarray:
        """Per-rank scalar reference of :meth:`dissemination_barrier`,
        kept for the scalar<->vectorized equivalence tests."""
        ranks = list(range(self.p)) if ranks is None else ranks
        n = len(ranks)
        idx = {r: i for i, r in enumerate(ranks)}
        k = 1
        while k < n:
            send_time = {r: self.t[r] + self.net.proc_overhead for r in ranks}
            for r in ranks:
                src = ranks[(idx[r] - k) % n]
                arrival = send_time[src] + self._latency()
                self.t[r] = max(self.t[r] + self.net.proc_overhead, arrival)
                self.msg_count += 1
            k *= 2
        return self.t[ranks].copy()

    def library_barrier(self, exit_skew: float = 0.0, ranks: list[int] | None = None) -> np.ndarray:
        """An opaque library barrier with configurable *exit skew* (§4.6).

        Models implementations like the MVAPICH 2.0a barrier of Fig. 12 where
        ranks leave the barrier up to ~40 us apart, linearly in rank. With
        ``exit_skew=0`` it behaves like the dissemination barrier.
        """
        ranks = list(range(self.p)) if ranks is None else ranks
        out = self.dissemination_barrier(ranks)
        if exit_skew > 0.0:
            n = len(ranks)
            bias = exit_skew * np.arange(n) / max(1, n - 1)
            bias = bias + self.rng.normal(0.0, 0.05 * exit_skew, size=n)
            self.t[ranks] += np.maximum(0.0, bias)
        return self.t[ranks].copy()

    # ------------------------------------------------------------- utilities
    def elapsed_snapshot(self) -> np.ndarray:
        return self.t.copy()

    def max_elapsed_since(self, snap: np.ndarray) -> float:
        """Wall-clock duration of a phase = max over hosts (Fig. 10 x-axis)."""
        return float(np.max(self.t - snap))

    def align(self, ranks: list[int] | None = None) -> None:
        """Bring hosts to a common true time (models a blocking sync point)."""
        ranks = list(range(self.p)) if ranks is None else ranks
        tmax = float(np.max(self.t[ranks]))
        for r in ranks:
            self.t[r] = tmax

    def true_offset(self, r: int, ref: int = 0) -> float:
        """Ground-truth clock offset of ``r`` vs ``ref`` at the current moment."""
        t = max(self.t[r], self.t[ref])
        return self.clocks[r].read(t) - self.clocks[ref].read(t)
