"""MPI-style timing procedures (§3.2, Algorithm 1) and barrier probes (§4.6).

Two ways to compute the completion time of a distributed operation:

  * **Local times** (§3.2.1, used with barrier sync):
    ``t[i] = max_r (end_local_r[i] - start_local_r[i])`` — no global clock
    needed, but silently *includes barrier exit skew* in the measurement.
  * **Global times** (§3.2.2, used with window sync or drift-corrected
    clocks): ``t[i] = max_r g(end_r[i]) - min_r g(start_r[i])`` — the true
    completion time of the operation, requires synchronized clocks.

Figure 11's surprising gap between the two is reproduced by
:func:`run_barrier_timed` returning *both* quantities, and Fig. 12's barrier
exit-skew probe by :func:`probe_barrier_skew`.

:func:`run_barrier_timed` pre-samples all operation durations through
:meth:`~repro.core.mpi_ops.SimCollective.sample_durations` and defers every
clock read to vectorized affine conversions after the barrier loop, falling
back to per-observation scalar reads only for random-walk clocks (whose
reads are stateful and order-dependent).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .mpi_ops import SimCollective
from .simnet import SimNet
from .sync.base import SyncResult

__all__ = ["BarrierRun", "run_barrier_timed", "probe_barrier_skew"]


@dataclass
class BarrierRun:
    """Measurements of ``nrep`` operation calls under barrier sync."""

    times_local: np.ndarray   # max_r (end_r - start_r), scheme of §3.2.1
    times_global: np.ndarray  # max_r g(end_r) - min_r g(start_r), §3.2.2
    barrier_exit_true: np.ndarray  # (nrep, p) true exit times (skew study)
    start_true: np.ndarray
    end_true: np.ndarray


def run_barrier_timed(
    net: SimNet,
    op: SimCollective,
    msize: int,
    nrep: int,
    sync: SyncResult | None = None,
    barrier_exit_skew: float = 0.0,
    use_library_barrier: bool = True,
    ranks: list[int] | None = None,
) -> BarrierRun:
    """Algorithm 1 with SYNC_PROCESSES = MPI_Barrier.

    ``sync`` (optional) provides globally-synchronized clocks so the *same*
    run can report both the local-max and the global completion time — the
    §4.6 experiment design. ``barrier_exit_skew`` models implementations
    whose barrier releases ranks far apart (Fig. 12: >40 us for MVAPICH).
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    p = len(ranks)
    if any(net.clocks[r].rw_sigma > 0.0 for r in ranks):
        return _run_barrier_timed_scalar(
            net, op, msize, nrep, sync, barrier_exit_skew,
            use_library_barrier, ranks)

    bx = np.empty((nrep, p))
    st = np.empty((nrep, p))
    et = np.empty((nrep, p))

    # All op noise is pre-sampled; the per-observation loop only runs the
    # (stochastic, entry-time-dependent) barrier and the entry/finish
    # arithmetic of a synchronizing collective.
    dur = op.sample_durations(net, p, msize, nrep)
    imb = net.rng.normal(0.0, op.rank_imbalance, size=(nrep, p))
    span = dur[:, None] * np.maximum(0.25, 1.0 + imb)
    for obs in range(nrep):
        if use_library_barrier:
            exit_true = net.library_barrier(exit_skew=barrier_exit_skew, ranks=ranks)
        else:
            exit_true = net.dissemination_barrier(ranks=ranks)
        bx[obs] = exit_true
        st[obs] = exit_true
        et[obs] = np.max(exit_true) + span[obs]
        net.t[ranks] = et[obs]

    # Deferred clock reads: local stamps of all (obs, rank) pairs at once.
    start_local = np.empty((nrep, p))
    end_local = np.empty((nrep, p))
    for i, r in enumerate(ranks):
        clk = net.clocks[r]
        start_local[:, i] = clk.read(st[:, i])
        end_local[:, i] = clk.read(et[:, i])
    tl = np.max(end_local - start_local, axis=1)
    tg = np.full(nrep, np.nan)
    if sync is not None:
        g_start = np.empty((nrep, p))
        g_end = np.empty((nrep, p))
        for i, r in enumerate(ranks):
            model, init = sync.models[r], sync.initial_times[r]
            g_start[:, i] = model.normalize(start_local[:, i] - init)
            g_end[:, i] = model.normalize(end_local[:, i] - init)
        tg = np.max(g_end, axis=1) - np.min(g_start, axis=1)

    return BarrierRun(
        times_local=tl, times_global=tg,
        barrier_exit_true=bx, start_true=st, end_true=et,
    )


def _run_barrier_timed_scalar(
    net: SimNet,
    op: SimCollective,
    msize: int,
    nrep: int,
    sync: SyncResult | None,
    barrier_exit_skew: float,
    use_library_barrier: bool,
    ranks: list[int],
) -> BarrierRun:
    """Per-observation scalar reference (and the random-walk-clock path)."""
    p = len(ranks)
    tl = np.empty(nrep)
    tg = np.full(nrep, np.nan)
    bx = np.empty((nrep, p))
    st = np.empty((nrep, p))
    et = np.empty((nrep, p))

    for obs in range(nrep):
        if use_library_barrier:
            exit_true = net.library_barrier(exit_skew=barrier_exit_skew, ranks=ranks)
        else:
            exit_true = net.dissemination_barrier(ranks=ranks)
        bx[obs] = exit_true
        start_local = np.array([net.local_time(r) for r in ranks])
        start_true = net.t[ranks].copy()
        ex = op.execute(net, msize, ranks)
        end_local = np.array([net.local_time(r) for r in ranks])
        st[obs] = start_true
        et[obs] = ex.end_true
        tl[obs] = float(np.max(end_local - start_local))
        if sync is not None:
            g_start = [
                sync.global_time(net, r, net.clocks[r].read(start_true[i]))
                for i, r in enumerate(ranks)
            ]
            g_end = [
                sync.global_time(net, r, net.clocks[r].read(ex.end_true[i]))
                for i, r in enumerate(ranks)
            ]
            tg[obs] = float(np.max(g_end) - np.min(g_start))

    return BarrierRun(
        times_local=tl, times_global=tg,
        barrier_exit_true=bx, start_true=st, end_true=et,
    )


def probe_barrier_skew(
    net: SimNet,
    nrep: int = 1000,
    barrier_exit_skew: float = 0.0,
    use_library_barrier: bool = True,
    ranks: list[int] | None = None,
) -> np.ndarray:
    """Fig. 12 experiment: per-rank barrier exit times relative to the first
    rank that leaves, averaged over ``nrep`` barrier calls.

    Returns shape ``(nrep, p)`` relative exit times in seconds; column means
    reproduce the per-rank skew profile.
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    p = len(ranks)
    out = np.empty((nrep, p))
    for obs in range(nrep):
        if use_library_barrier:
            exit_true = net.library_barrier(exit_skew=barrier_exit_skew, ranks=ranks)
        else:
            exit_true = net.dissemination_barrier(ranks=ranks)
        out[obs] = exit_true - np.min(exit_true)
        # small idle gap between probes so barriers do not overlap
        net.sleep_all(5e-6)
    return out
