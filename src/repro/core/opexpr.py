"""Composite operation expressions — the mock-up language of performance
guidelines (PGMPI, arXiv:1606.00215).

A performance guideline compares a collective against a *mock-up*: an
alternative implementation of (an upper bound on) the same communication
pattern, built from other collectives run back to back — ``bcast <=
scatter + allgather``, ``allreduce <= reduce + bcast``, split-robustness
``allreduce(p) <= allreduce(p/2) + allreduce(p/2)``. Both sides of a
guideline must flow through the *same* measurement pipeline, so mock-ups
are encoded as ordinary :class:`~repro.core.design.TestCase` op names and
every :class:`~repro.campaign.MeasurementBackend` learns to execute them.

Grammar (whitespace-insensitive)::

    expr     :=  term ("+" term)*
    term     :=  NAME modifier*
    modifier :=  "*" FLOAT      message-size scale of this term
              |  "@half"        run this term on half the processes
              |  "#" NAME       implementation tag (backend-specific,
                                e.g. KernelBackend's pallas | ref)

``"+"`` sequences the constituent operations inside one timed region: one
observation of ``"scatter+allgather"`` is a scatter immediately followed
by an allgather, timed end to end — exactly the mock-up semantics of the
guideline literature. A plain name (``"allreduce"``) parses to a single
unmodified term, so every existing op name is a valid expression.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

__all__ = ["OpTerm", "parse_opexpr", "is_composite", "format_opexpr"]

_TERM_RE = re.compile(
    r"^(?P<op>[A-Za-z_][A-Za-z0-9_]*)"
    r"(?P<mods>(?:\*[0-9.]+|@half|#[A-Za-z_][A-Za-z0-9_]*)*)$"
)
_MOD_RE = re.compile(r"\*[0-9.]+|@half|#[A-Za-z_][A-Za-z0-9_]*")


@dataclass(frozen=True)
class OpTerm:
    """One constituent operation of a (possibly composite) expression."""

    op: str
    msize_scale: float = 1.0   # term message size = round(scale * case msize)
    procs: str = "all"         # "all" | "half" (split-robustness mock-ups)
    impl: str | None = None    # backend-specific implementation tag

    def msize(self, case_msize: int) -> int:
        return max(0, int(round(self.msize_scale * case_msize)))


def parse_opexpr(expr: str) -> tuple[OpTerm, ...]:
    """Parse an op expression into its terms (a plain name -> one term)."""
    terms: list[OpTerm] = []
    for raw in expr.split("+"):
        raw = raw.strip()
        m = _TERM_RE.match(raw)
        if not m:
            raise ValueError(
                f"opexpr: cannot parse term {raw!r} of {expr!r} "
                "(grammar: NAME, NAME*SCALE, NAME@half, NAME#impl, "
                "terms joined by '+')")
        scale, procs, impl = 1.0, "all", None
        for mod in _MOD_RE.findall(m.group("mods")):
            if mod.startswith("*"):
                scale = float(mod[1:])
                if scale <= 0:
                    raise ValueError(f"opexpr: non-positive msize scale "
                                     f"in {raw!r}")
            elif mod == "@half":
                procs = "half"
            else:
                impl = mod[1:]
        terms.append(OpTerm(op=m.group("op"), msize_scale=scale,
                            procs=procs, impl=impl))
    if not terms:
        raise ValueError(f"opexpr: empty expression {expr!r}")
    return tuple(terms)


def is_composite(expr: str) -> bool:
    """True when ``expr`` needs the composite execution path (more than one
    term, or any modifier on a single term)."""
    terms = parse_opexpr(expr)
    if len(terms) > 1:
        return True
    t = terms[0]
    return t.msize_scale != 1.0 or t.procs != "all" or t.impl is not None


def format_opexpr(terms: tuple[OpTerm, ...] | list[OpTerm]) -> str:
    """Inverse of :func:`parse_opexpr` (canonical spelling)."""
    parts = []
    for t in terms:
        s = t.op
        if t.msize_scale != 1.0:
            s += f"*{t.msize_scale:g}"
        if t.procs == "half":
            s += "@half"
        if t.impl is not None:
            s += f"#{t.impl}"
        parts.append(s)
    return "+".join(parts)
