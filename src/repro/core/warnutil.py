"""Warnings that point at the *user's* code, not the library's.

``warnings.warn(..., stacklevel=N)`` attributes a warning to the frame N
levels above the ``warn`` call — but a hardcoded N is only right for one
call depth. The engine-fallback warning, for example, fires from
``SimBackend._warn_fallback`` which is reached through ``make_epoch`` →
``_SimEpoch.__init__`` at depths that differ between a direct
``backend.make_epoch(0)`` and a ``Campaign(...).run()``; any fixed
``stacklevel`` points *inside* ``repro`` for at least one of them, and a
``filterwarnings`` keyed on the caller's module can never match.

:func:`warn_external` computes the stacklevel at call time by walking the
stack past every frame that lives inside the ``repro`` package (plus any
explicitly skipped files), so the warning lands on the first external
caller — what Python 3.12's ``skip_file_prefixes`` does, implemented here
because the supported floor is 3.10.
"""

from __future__ import annotations

import os
import sys
import warnings

__all__ = ["warn_external"]

#: Absolute directory of the ``repro`` package (``src/repro``): frames
#: whose code lives under it are library internals a warning should skip.
_PKG_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _is_internal(filename: str, skip_files: tuple) -> bool:
    path = os.path.abspath(filename)
    if path.startswith(_PKG_DIR + os.sep):
        return True
    return any(path == os.path.abspath(s) for s in skip_files)


def warn_external(message: str, category: type = UserWarning,
                  skip_files: tuple = ()) -> None:
    """``warnings.warn`` attributed to the first caller frame outside
    ``repro`` (and outside ``skip_files`` — pass a module's ``__file__``
    to skip a shim's own frames as well)."""
    level = 1                    # stacklevel=1 == this function's frame
    frame = sys._getframe(0)
    while frame is not None and _is_internal(frame.f_code.co_filename,
                                             skip_files):
        frame = frame.f_back
        level += 1
    warnings.warn(message, category, stacklevel=level)
