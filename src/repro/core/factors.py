"""Experimental-factor registry (§5.9, Table 4).

"Knowing all factors is a tedious, but necessary task" (Le Boudec, quoted in
§5). The paper's Table 4 lists the factors every MPI benchmark result must
carry; this module defines the TPU/JAX analogue and attaches it to every
result record. Two results are only *comparable* when their factor sets
differ solely in the declared factor under test — enforced by
:func:`assert_comparable`.

| paper factor          | TPU/JAX analogue captured here                  |
|-----------------------|-------------------------------------------------|
| MPI implementation    | jax / jaxlib version, backend, library config   |
| network               | device kind, mesh shape & axis names            |
| synchronization method| sync algorithm + window size                    |
| mpirun                | launch-epoch count and epoch isolation mode     |
| compiler / flags      | XLA_FLAGS, jit options (donate, remat policy)   |
| DVFS level            | device clock class (fixed on TPU; recorded)     |
| cache                 | buffer reuse policy (warm/cold; donation)       |
| pinning               | host process binding / device->host mapping     |
"""

from __future__ import annotations

import hashlib
import json
import os
import platform
from dataclasses import asdict, dataclass, field

__all__ = ["FactorSet", "capture_factors", "assert_comparable"]


@dataclass(frozen=True)
class FactorSet:
    backend: str = "cpu"
    device_kind: str = "cpu"
    jax_version: str = ""
    mesh_shape: tuple = ()
    mesh_axes: tuple = ()
    sync_method: str = "barrier"
    window_size_us: float = 0.0
    n_launch_epochs: int = 1
    nrep: int = 0
    # adaptive-nrep stopping contract (0/0 = fixed nrep): the stopping rule
    # changes the sample-size distribution, so it is itself a factor.
    nrep_min: int = 0
    nrep_max: int = 0
    rel_ci_target: float = 0.0
    # design identity: two campaigns with different seeds or randomization
    # are different experiments and must not share a store fingerprint.
    design_seed: int = 0
    shuffle: bool = True
    measurement_backend: str = ""      # sim | jax | kernel | "" (ad hoc)
    epoch_isolation: str = "process"   # process | clear_caches | none
    xla_flags: str = ""
    matmul_precision: str = "default"
    donate_buffers: bool = False
    remat_policy: str = "none"
    buffer_policy: str = "warm"        # warm | cold (cache factor, §5.8)
    dtype: str = "float32"
    host: str = field(default_factory=platform.node)
    extra: tuple = ()

    def to_dict(self) -> dict:
        return asdict(self)

    def fingerprint(self, exclude: tuple[str, ...] = ()) -> str:
        d = {k: v for k, v in self.to_dict().items() if k not in exclude and k != "host"}
        blob = json.dumps(d, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def capture_factors(**overrides) -> FactorSet:
    """Capture the ambient environment into a :class:`FactorSet`."""
    try:
        import jax

        backend = jax.default_backend()
        device_kind = jax.devices()[0].device_kind
        jax_version = jax.__version__
    except Exception:  # pragma: no cover - jax always present in this repo
        backend, device_kind, jax_version = "unknown", "unknown", "unknown"
    base = dict(
        backend=backend,
        device_kind=device_kind,
        jax_version=jax_version,
        xla_flags=os.environ.get("XLA_FLAGS", ""),
    )
    base.update(overrides)
    return FactorSet(**base)


def assert_comparable(a: FactorSet, b: FactorSet, factor_under_test: tuple[str, ...]) -> None:
    """Refuse to statistically compare results whose factor sets differ in
    anything but the declared factor(s) under test (§5.9's conclusion)."""
    fa = a.fingerprint(exclude=factor_under_test)
    fb = b.fingerprint(exclude=factor_under_test)
    if fa != fb:
        da, db = a.to_dict(), b.to_dict()
        diffs = {
            k: (da[k], db[k])
            for k in da
            if k not in factor_under_test and k != "host" and da[k] != db[k]
        }
        raise ValueError(
            "factor sets differ beyond the factor under test "
            f"{factor_under_test}: {diffs} — results are not comparable"
        )
