"""Experimental-factor registry (§5.9, Table 4).

"Knowing all factors is a tedious, but necessary task" (Le Boudec, quoted in
§5). The paper's Table 4 lists the factors every MPI benchmark result must
carry; this module defines the TPU/JAX analogue and attaches it to every
result record. Two results are only *comparable* when their factor sets
differ solely in the declared factor under test — enforced by
:func:`assert_comparable`.

| paper factor          | TPU/JAX analogue captured here                  |
|-----------------------|-------------------------------------------------|
| MPI implementation    | jax / jaxlib version, backend, library config   |
| network               | device kind, mesh shape & axis names            |
| synchronization method| sync algorithm + window size                    |
| mpirun                | launch-epoch count and epoch isolation mode     |
| compiler / flags      | XLA_FLAGS, jit options (donate, remat policy)   |
| DVFS level            | device clock class (fixed on TPU; recorded)     |
| cache                 | buffer reuse policy (warm/cold; donation)       |
| pinning               | host process binding / device->host mapping     |
"""

from __future__ import annotations

import hashlib
import json
import os
import platform
from dataclasses import asdict, dataclass, field, replace

import numpy as np

__all__ = [
    "FactorSet",
    "capture_factors",
    "assert_comparable",
    "FactorAxis",
    "GridCell",
    "FactorGrid",
]


@dataclass(frozen=True)
class FactorSet:
    backend: str = "cpu"
    device_kind: str = "cpu"
    jax_version: str = ""
    mesh_shape: tuple = ()
    mesh_axes: tuple = ()
    sync_method: str = "barrier"
    window_size_us: float = 0.0
    n_launch_epochs: int = 1
    nrep: int = 0
    # adaptive-nrep stopping contract (0/0 = fixed nrep): the stopping rule
    # changes the sample-size distribution, so it is itself a factor.
    nrep_min: int = 0
    nrep_max: int = 0
    rel_ci_target: float = 0.0
    # design identity: two campaigns with different seeds or randomization
    # are different experiments and must not share a store fingerprint.
    design_seed: int = 0
    shuffle: bool = True
    measurement_backend: str = ""      # sim | jax | kernel | "" (ad hoc)
    epoch_isolation: str = "process"   # process | clear_caches | none
    xla_flags: str = ""
    matmul_precision: str = "default"
    donate_buffers: bool = False
    remat_policy: str = "none"
    buffer_policy: str = "warm"        # warm | cold (cache factor, §5.8)
    dtype: str = "float32"
    host: str = field(default_factory=platform.node)
    extra: tuple = ()

    def to_dict(self) -> dict:
        return asdict(self)

    def fingerprint(self, exclude: tuple[str, ...] = ()) -> str:
        d = {k: v for k, v in self.to_dict().items() if k not in exclude and k != "host"}
        blob = json.dumps(d, sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def capture_factors(**overrides) -> FactorSet:
    """Capture the ambient environment into a :class:`FactorSet`.

    A failed capture (no usable jax runtime) degrades to ``"unknown"``
    values, but never *silently*: the failure reason is recorded in
    ``extra`` so a degraded capture shows up in fingerprint diffs instead
    of masquerading as a comparable environment.
    """
    failure: tuple = ()
    try:
        import jax

        backend = jax.default_backend()
        device_kind = jax.devices()[0].device_kind
        jax_version = jax.__version__
    except Exception as e:
        backend, device_kind, jax_version = "unknown", "unknown", "unknown"
        failure = (("capture_failure", f"{type(e).__name__}: {e}"),)
    base = dict(
        backend=backend,
        device_kind=device_kind,
        jax_version=jax_version,
        xla_flags=os.environ.get("XLA_FLAGS", ""),
    )
    base.update(overrides)
    if failure:
        base["extra"] = tuple(base.get("extra", ())) + failure
    return FactorSet(**base)


def assert_comparable(a: FactorSet, b: FactorSet, factor_under_test: tuple[str, ...]) -> None:
    """Refuse to statistically compare results whose factor sets differ in
    anything but the declared factor(s) under test (§5.9's conclusion)."""
    fa = a.fingerprint(exclude=factor_under_test)
    fb = b.fingerprint(exclude=factor_under_test)
    if fa != fb:
        da, db = a.to_dict(), b.to_dict()
        diffs = {
            k: (da[k], db[k])
            for k in da
            if k not in factor_under_test and k != "host" and da[k] != db[k]
        }
        raise ValueError(
            "factor sets differ beyond the factor under test "
            f"{factor_under_test}: {diffs} — results are not comparable"
        )


# ---------------------------------------------------------------------------
# Enumerable factor axes (the executable Table 4)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FactorAxis:
    """One experimental factor as an *enumerable axis*: a name and the
    levels it is swept over.

    Recording a factor (:class:`FactorSet`) says what was held fixed;
    an axis says how to *vary* it. Each level is a concrete value for one
    constructor field of the measurement backend (``target="backend"``) or
    of the :class:`~repro.core.design.ExperimentDesign`
    (``target="design"``) — so a grid cell materializes into runnable
    objects by plain dataclass replacement, and the resulting
    :class:`FactorSet` carries the level through the backend's own
    ``factors()`` plumbing (nothing bypasses the fingerprint).

    ``key`` is the constructor field the levels are assigned to (default:
    the axis name). ``labels`` are the display names used in sweep
    manifests and factor-impact reports; they default to ``str(level)``,
    and must be given explicitly when levels are unwieldy values (a
    ``per_op_kw`` dict, a window size in seconds).
    """

    name: str
    levels: tuple
    target: str = "backend"          # backend | design
    key: str | None = None
    labels: tuple = ()

    def __post_init__(self):
        if self.target not in ("backend", "design"):
            raise ValueError(f"axis {self.name!r}: target must be 'backend' "
                             f"or 'design', got {self.target!r}")
        if len(self.levels) < 2:
            raise ValueError(f"axis {self.name!r}: a factor axis needs at "
                             f"least 2 levels, got {len(self.levels)}")
        if self.labels and len(self.labels) != len(self.levels):
            raise ValueError(f"axis {self.name!r}: {len(self.labels)} labels "
                             f"for {len(self.levels)} levels")
        labels = self.labels or tuple(str(v) for v in self.levels)
        if len(set(labels)) != len(labels):
            raise ValueError(f"axis {self.name!r}: level labels must be "
                             f"distinct, got {labels}")

    def label(self, i: int) -> str:
        return self.labels[i] if self.labels else str(self.levels[i])

    def kwarg(self) -> str:
        return self.key or self.name


@dataclass(frozen=True)
class GridCell:
    """One point of a factor grid: a concrete level choice per axis.

    ``index`` is the cell's position in the *full* cross-product (row-major
    over the axes), stable under fractional sampling — it is the resume key
    of a sharded sweep. ``materialize`` turns the cell into a runnable
    ``(backend, design)`` pair; the cell's :class:`FactorSet` then comes
    from ``backend.factors(design)``, never from the grid itself, so a
    level that the backend fails to surface in its factors is caught as a
    fingerprint collision rather than silently merged.
    """

    index: int
    axes: tuple[FactorAxis, ...]
    coords: tuple[int, ...]          # level index per axis

    def levels(self) -> dict[str, str]:
        """Axis name -> level *label* (the report/manifest view)."""
        return {ax.name: ax.label(i) for ax, i in zip(self.axes, self.coords)}

    def overrides(self, target: str) -> dict:
        return {ax.kwarg(): ax.levels[i]
                for ax, i in zip(self.axes, self.coords) if ax.target == target}

    def materialize(self, base_backend, base_design):
        """``(backend, design)`` with this cell's levels applied via
        dataclass replacement."""
        backend_kw = self.overrides("backend")
        design_kw = self.overrides("design")
        try:
            backend = replace(base_backend, **backend_kw) if backend_kw \
                else base_backend
        except TypeError as e:
            raise TypeError(
                f"grid cell {self.levels()}: backend "
                f"{type(base_backend).__name__} does not accept "
                f"{sorted(backend_kw)} — check the axis 'key' fields"
            ) from e
        try:
            design = replace(base_design, **design_kw) if design_kw \
                else base_design
        except TypeError as e:
            raise TypeError(
                f"grid cell {self.levels()}: ExperimentDesign does not "
                f"accept {sorted(design_kw)} — check the axis 'key' fields"
            ) from e
        return backend, design

    def factors(self, base_backend, base_design) -> FactorSet:
        backend, design = self.materialize(base_backend, base_design)
        return backend.factors(design)


@dataclass(frozen=True)
class FactorGrid:
    """An executable experiment space: the cross-product of factor axes.

    ``fraction < 1`` selects a deterministic random subset of the full
    cross-product (seeded by ``design_seed``) — the fractional-design
    escape hatch for factor spaces too large to run exhaustively. Cell
    indices always refer to the full product, so growing ``fraction``
    later only *adds* cells and a persisted sweep keeps resuming.
    """

    axes: tuple[FactorAxis, ...]
    design_seed: int = 0
    fraction: float = 1.0

    def __post_init__(self):
        if not self.axes:
            raise ValueError("FactorGrid needs at least one axis")
        names = [ax.name for ax in self.axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate axis names: {names}")
        keys = [(ax.target, ax.kwarg()) for ax in self.axes]
        if len(set(keys)) != len(keys):
            raise ValueError(f"two axes drive the same constructor field: "
                             f"{keys}")
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got "
                             f"{self.fraction}")
        object.__setattr__(self, "axes", tuple(self.axes))

    def n_full(self) -> int:
        n = 1
        for ax in self.axes:
            n *= len(ax.levels)
        return n

    def __len__(self) -> int:
        return len(self.cell_indices())

    def cell_indices(self) -> list[int]:
        """Indices (into the full cross-product) of the cells this grid
        actually runs — all of them, or the seeded fractional sample.

        The sample is a prefix of one seed-keyed permutation, so samples
        *nest*: every cell of ``fraction=f1`` is also a cell of any
        ``fraction=f2 >= f1`` at the same ``design_seed`` — which is what
        lets a persisted fractional sweep keep resuming after the
        fraction is raised."""
        n = self.n_full()
        if self.fraction >= 1.0:
            return list(range(n))
        n_pick = max(1, int(round(self.fraction * n)))
        rng = np.random.default_rng(self.design_seed)
        return sorted(int(i) for i in rng.permutation(n)[:n_pick])

    def cell(self, index: int) -> GridCell:
        """The cell at a full-cross-product index (row-major over axes)."""
        sizes = [len(ax.levels) for ax in self.axes]
        if not 0 <= index < self.n_full():
            raise IndexError(f"cell index {index} out of range "
                             f"[0, {self.n_full()})")
        coords, rem = [], index
        for size in reversed(sizes):
            coords.append(rem % size)
            rem //= size
        return GridCell(index=index, axes=self.axes,
                        coords=tuple(reversed(coords)))

    def cells(self) -> list[GridCell]:
        return [self.cell(i) for i in self.cell_indices()]

    def manifest(self) -> dict:
        """The JSON-able identity of this grid (sweep-store manifests)."""
        return dict(
            axes=[dict(name=ax.name, target=ax.target, key=ax.kwarg(),
                       labels=[ax.label(i) for i in range(len(ax.levels))])
                  for ax in self.axes],
            design_seed=self.design_seed,
            fraction=self.fraction,
            n_full=self.n_full(),
        )
