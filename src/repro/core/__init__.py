"""repro.core — the paper's contribution as a composable library.

Hunold & Carpen-Amarie, *MPI Benchmarking Revisited: Experimental Design and
Reproducibility* (2015): drift-corrected clock synchronization (HCA),
window-based process synchronization, experimental-factor control, and a
statistically sound, reproducible benchmarking method for distributed
collective operations — adapted here to JAX/TPU collectives and step
functions (see DESIGN.md §2 for the hardware-adaptation map).
"""

from .clocks import IDENTITY_MODEL, AdjustedClock, Clock, LinearModel, PerfClock, SimClock, linear_fit
from .compare import ComparisonRow, compare_cases, compare_tables, format_comparison, naive_comparison
from .design import (
    EpochSummary,
    ExperimentDesign,
    MeasurementRecord,
    ResultTable,
    TestCase,
    analyze_records,
    case_orders,
    map_parallel,
    measure_adaptive,
    measure_case,
    run_design,
)
from .factors import (
    FactorAxis,
    FactorGrid,
    FactorSet,
    GridCell,
    assert_comparable,
    capture_factors,
)
from .mpi_ops import (
    OP_LIBRARY,
    BatchExecution,
    CollectiveExecution,
    SimCollective,
    SimCompositeOp,
    make_composite_op,
    make_op,
)
from .opexpr import OpTerm, format_opexpr, is_composite, parse_opexpr
from .retry import RetryBudgetExceeded, RetryPolicy, retry_call
from .simnet import ClockParams, NetParams, SimNet
from .stats import (
    autocorr_significant_lags,
    autocorrelation,
    bootstrap_ci,
    chi2_sf,
    cliffs_delta,
    coefficient_of_variation,
    holm_bonferroni,
    jarque_bera,
    kruskal_wallis,
    mean_confidence_interval,
    normal_ppf,
    relative_ci_width,
    significance_stars,
    t_ppf,
    TostResult,
    tost_wilcoxon,
    tukey_filter,
    wilcoxon_rank_sum,
)
from .sync import (
    ALGORITHMS,
    HCASync,
    JKSync,
    NetgaugeSync,
    SkampiSync,
    SyncResult,
    make_sync,
    probe_offsets,
    true_offsets,
)
from .timing import BarrierRun, probe_barrier_skew, run_barrier_timed
from .window import WindowRun, run_windowed, run_windowed_scalar

__all__ = [
    # clocks
    "Clock", "PerfClock", "SimClock", "AdjustedClock", "LinearModel",
    "IDENTITY_MODEL", "linear_fit",
    # simulation
    "SimNet", "NetParams", "ClockParams", "SimCollective", "SimCompositeOp",
    "CollectiveExecution", "BatchExecution", "make_op", "make_composite_op",
    "OP_LIBRARY",
    # op expressions (guideline mock-ups)
    "OpTerm", "parse_opexpr", "is_composite", "format_opexpr",
    # sync
    "ALGORITHMS", "make_sync", "SkampiSync", "NetgaugeSync", "JKSync",
    "HCASync", "SyncResult", "probe_offsets", "true_offsets",
    # measurement
    "run_windowed", "run_windowed_scalar", "WindowRun", "run_barrier_timed",
    "BarrierRun", "probe_barrier_skew",
    # statistics
    "tukey_filter", "wilcoxon_rank_sum", "holm_bonferroni",
    "significance_stars", "chi2_sf", "kruskal_wallis", "cliffs_delta",
    "mean_confidence_interval", "jarque_bera", "autocorrelation",
    "autocorr_significant_lags", "coefficient_of_variation", "normal_ppf",
    "t_ppf", "relative_ci_width", "TostResult", "tost_wilcoxon",
    "bootstrap_ci",
    # design & comparison
    "ExperimentDesign", "TestCase", "run_design", "analyze_records",
    "ResultTable", "EpochSummary", "MeasurementRecord", "case_orders",
    "measure_case", "measure_adaptive", "map_parallel",
    "compare_tables", "compare_cases", "ComparisonRow", "naive_comparison",
    "format_comparison",
    # factors
    "FactorSet", "capture_factors", "assert_comparable",
    "FactorAxis", "FactorGrid", "GridCell",
    # retry / backoff
    "RetryPolicy", "RetryBudgetExceeded", "retry_call",
]
