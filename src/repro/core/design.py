"""Reproducible experimental design and analysis (§6.1, Algorithms 5-6).

The paper's central methodological result: *the launcher invocation is an
experimental factor* (§5.2). A sound benchmark therefore

  1. runs ``n`` independent **launch epochs** (mpirun calls / process
     restarts / fresh jit compilations) — replication over the blocking
     factor,
  2. measures ``nrep`` observations per (function, message size) inside
     each epoch,
  3. **randomizes** the order of test cases within an epoch (Montgomery's
     randomization principle; Alg. 5 line 9 ``shuffle``),
  4. removes outliers per group with Tukey's filter (Alg. 6 line 5),
  5. summarizes each epoch by its mean *and* median, producing a
     *distribution of averages* over epochs for the hypothesis test.

The design is engine-agnostic: an *epoch factory* builds a fresh context
(a new :class:`~repro.core.simnet.SimNet`, or a fresh jit cache on a real
pod) and a *measure* callable produces the raw sample.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

import numpy as np

from .stats import tukey_filter

__all__ = [
    "TestCase",
    "ExperimentDesign",
    "MeasurementRecord",
    "EpochSummary",
    "ResultTable",
    "run_design",
    "analyze_records",
]


@dataclass(frozen=True)
class TestCase:
    """One benchmark cell: an operation at a message size (Alg. 5's
    ``(func, msize)``; the process count is fixed per campaign)."""

    op: str
    msize: int

    def key(self) -> tuple[str, int]:
        return (self.op, self.msize)


@dataclass
class ExperimentDesign:
    n_launch_epochs: int = 30     # paper default: 30 mpiruns (§6)
    nrep: int = 100               # measurements per case per epoch
    shuffle: bool = True          # randomization (Alg. 5 line 9)
    outlier_filter: bool = True   # Tukey per group (Alg. 6 line 5)
    seed: int = 0


@dataclass
class MeasurementRecord:
    case: TestCase
    epoch: int
    times: np.ndarray             # raw run-times [s]
    invalid_fraction: float = 0.0
    meta: dict = field(default_factory=dict)


@dataclass
class EpochSummary:
    """Per-epoch averages after outlier removal (one row of Alg. 6's v)."""

    case: TestCase
    epoch: int
    mean: float
    median: float
    n_kept: int
    n_raw: int


@dataclass
class ResultTable:
    """Distribution of per-epoch averages for every test case."""

    summaries: list[EpochSummary]

    def cases(self) -> list[TestCase]:
        seen: dict[tuple, TestCase] = {}
        for s in self.summaries:
            seen.setdefault(s.case.key(), s.case)
        return [seen[k] for k in sorted(seen)]

    def medians(self, case: TestCase) -> np.ndarray:
        return np.array([s.median for s in self.summaries if s.case.key() == case.key()])

    def means(self, case: TestCase) -> np.ndarray:
        return np.array([s.mean for s in self.summaries if s.case.key() == case.key()])

    def to_rows(self) -> list[dict]:
        return [
            dict(op=s.case.op, msize=s.case.msize, epoch=s.epoch,
                 mean=s.mean, median=s.median, n_kept=s.n_kept, n_raw=s.n_raw)
            for s in self.summaries
        ]


def run_design(
    design: ExperimentDesign,
    epoch_factory: Callable[[int], Any],
    measure: Callable[[Any, TestCase, int], np.ndarray],
    cases: Iterable[TestCase],
) -> list[MeasurementRecord]:
    """Algorithm 5: ``n`` launch epochs, each measuring all cases in a
    freshly shuffled order."""
    cases = list(cases)
    rng = np.random.default_rng(design.seed)
    records: list[MeasurementRecord] = []
    for epoch in range(design.n_launch_epochs):
        ctx = epoch_factory(epoch)
        order = list(cases)
        if design.shuffle:
            perm = rng.permutation(len(order))
            order = [order[i] for i in perm]
        for case in order:
            times = np.asarray(measure(ctx, case, design.nrep), dtype=np.float64)
            records.append(MeasurementRecord(case=case, epoch=epoch, times=times))
    return records


def analyze_records(
    records: Iterable[MeasurementRecord],
    outlier_filter: bool = True,
) -> ResultTable:
    """Algorithm 6: per (case, epoch) Tukey-filter then mean & median."""
    summaries: list[EpochSummary] = []
    for rec in records:
        raw = rec.times
        kept = tukey_filter(raw) if outlier_filter else raw
        if kept.size == 0:
            kept = raw
        summaries.append(
            EpochSummary(
                case=rec.case,
                epoch=rec.epoch,
                mean=float(np.mean(kept)),
                median=float(np.median(kept)),
                n_kept=int(kept.size),
                n_raw=int(raw.size),
            )
        )
    return ResultTable(summaries=summaries)
