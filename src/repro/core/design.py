"""Reproducible experimental design and analysis (§6.1, Algorithms 5-6).

The paper's central methodological result: *the launcher invocation is an
experimental factor* (§5.2). A sound benchmark therefore

  1. runs ``n`` independent **launch epochs** (mpirun calls / process
     restarts / fresh jit compilations) — replication over the blocking
     factor,
  2. measures ``nrep`` observations per (function, message size) inside
     each epoch,
  3. **randomizes** the order of test cases within an epoch (Montgomery's
     randomization principle; Alg. 5 line 9 ``shuffle``),
  4. removes outliers per group with Tukey's filter (Alg. 6 line 5),
  5. summarizes each epoch by its mean *and* median, producing a
     *distribution of averages* over epochs for the hypothesis test.

The design is engine-agnostic: an *epoch factory* builds a fresh context
(a new :class:`~repro.core.simnet.SimNet`, or a fresh jit cache on a real
pod) and a *measure* callable produces the raw sample.

Launch epochs are independent by construction (§5.2: each is its own
process instantiation), so :func:`run_design` can execute them across a
``ProcessPoolExecutor`` (``n_workers > 1``). Per-epoch case orders are
drawn up front from the design seed in the exact serial order, so the
parallel run reproduces the serial records bit-for-bit as long as the
factory/measure pair derives all randomness from the epoch index (which
the simulation backends do).
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

import numpy as np

from .stats import relative_ci_width, tukey_filter

__all__ = [
    "TestCase",
    "ExperimentDesign",
    "MeasurementRecord",
    "EpochSummary",
    "ResultTable",
    "case_orders",
    "measure_case",
    "measure_adaptive",
    "run_design",
    "map_parallel",
    "analyze_records",
    "NREP_SPENT",
]


class _NrepCounter:
    """Process-global measurement-cost meter: every repetition measured
    through :func:`measure_case` is counted, whatever layer asked for it.
    Wall-clock seconds depend on the machine; *repetitions spent* is the
    machine-independent cost a budgeted sweep actually saves — the
    benchmark harness snapshots this around each bench to report
    ``nrep_total`` next to seconds."""

    __slots__ = ("total",)

    def __init__(self) -> None:
        self.total = 0

    def add(self, n: int) -> None:
        self.total += int(n)

    def read(self) -> int:
        return self.total


#: The process-wide repetition counter (see :class:`_NrepCounter`).
NREP_SPENT = _NrepCounter()


@dataclass(frozen=True)
class TestCase:
    """One benchmark cell: an operation at a message size (Alg. 5's
    ``(func, msize)``; the process count is fixed per campaign)."""

    __test__ = False  # tell pytest this is not a test class

    op: str
    msize: int

    def key(self) -> tuple[str, int]:
        return (self.op, self.msize)


@dataclass
class ExperimentDesign:
    """Parameters of Algorithm 5, plus the adaptive-``nrep`` stopping rule.

    ``nrep`` is the *fixed* per-case sample size. Setting ``nrep_max``
    switches the design to sequential stopping (§3.4: "repeat until the
    result is stable"): each case starts with ``nrep_min`` observations and
    grows its sample until the relative CI half-width of the Tukey-filtered
    mean falls to ``rel_ci_target``, or ``nrep_max`` observations have been
    taken — whichever comes first. The rule is backend-agnostic: it only
    calls ``measure`` again for another chunk, so the simulator, real jitted
    JAX collectives and Pallas kernels all share it.
    """

    n_launch_epochs: int = 30     # paper default: 30 mpiruns (§6)
    nrep: int = 100               # measurements per case per epoch (fixed mode)
    shuffle: bool = True          # randomization (Alg. 5 line 9)
    outlier_filter: bool = True   # Tukey per group (Alg. 6 line 5)
    seed: int = 0
    # --- adaptive stopping (active iff nrep_max is not None) ---
    nrep_min: int = 10            # initial chunk / smallest defensible sample
    nrep_max: int | None = None   # hard cap; None = fixed-nrep mode
    rel_ci_target: float = 0.05   # stop when rel. CI half-width <= this
    ci_level: float = 0.95

    @property
    def adaptive(self) -> bool:
        return self.nrep_max is not None

    def replace(self, **overrides) -> "ExperimentDesign":
        """A copy with the given fields overridden — how a
        :class:`~repro.core.factors.FactorGrid` cell derives its per-cell
        design from a campaign's base design instead of every call site
        hard-wiring its own."""
        return dataclasses.replace(self, **overrides)


@dataclass
class MeasurementRecord:
    case: TestCase
    epoch: int
    times: np.ndarray             # raw run-times [s]
    invalid_fraction: float = 0.0
    meta: dict = field(default_factory=dict)


@dataclass
class EpochSummary:
    """Per-epoch averages after outlier removal (one row of Alg. 6's v).

    ``host`` is where the epoch was *measured* (carried through record
    meta) — not a factor, but the audit trail a merged multi-host store
    needs to stay attributable."""

    case: TestCase
    epoch: int
    mean: float
    median: float
    n_kept: int
    n_raw: int
    host: str = ""


@dataclass
class ResultTable:
    """Distribution of per-epoch averages for every test case.

    Lookups by case go through a grouped index built once per table state
    (and rebuilt only if ``summaries`` grows), so repeated
    :meth:`means`/:meth:`medians` calls stay O(group) instead of rescanning
    every summary — this matters once campaigns reach hundreds of cells.
    """

    summaries: list[EpochSummary]
    _index: dict = field(default=None, init=False, repr=False, compare=False)
    _indexed_len: int = field(default=-1, init=False, repr=False, compare=False)

    def _grouped(self) -> dict:
        if self._index is None or self._indexed_len != len(self.summaries):
            groups: dict[tuple, list[EpochSummary]] = {}
            for s in self.summaries:
                groups.setdefault(s.case.key(), []).append(s)
            self._index = {
                k: (v[0].case,
                    np.array([s.mean for s in v]),
                    np.array([s.median for s in v]))
                for k, v in groups.items()
            }
            self._indexed_len = len(self.summaries)
        return self._index

    def cases(self) -> list[TestCase]:
        idx = self._grouped()
        return [idx[k][0] for k in sorted(idx)]

    def medians(self, case: TestCase) -> np.ndarray:
        entry = self._grouped().get(case.key())
        return entry[2].copy() if entry else np.empty(0)

    def means(self, case: TestCase) -> np.ndarray:
        entry = self._grouped().get(case.key())
        return entry[1].copy() if entry else np.empty(0)

    def to_rows(self) -> list[dict]:
        return [
            dict(op=s.case.op, msize=s.case.msize, epoch=s.epoch,
                 mean=s.mean, median=s.median, n_kept=s.n_kept,
                 n_raw=s.n_raw, host=s.host)
            for s in self.summaries
        ]


def measure_adaptive(
    measure: Callable[[Any, TestCase, int], np.ndarray],
    ctx: Any,
    case: TestCase,
    design: ExperimentDesign,
    initial: np.ndarray | None = None,
) -> tuple[np.ndarray, dict]:
    """Sequential stopping for one case: sample in growing chunks until the
    relative CI half-width of the (Tukey-filtered) mean reaches
    ``design.rel_ci_target``, bounded by ``nrep_min``/``nrep_max``.

    ``initial`` injects an already-measured first chunk (a fused backend's
    batched ``nrep_min`` dispatch) so only the top-up chunks go through
    ``measure``; the stopping rule is unchanged.

    Returns ``(times, meta)`` where ``meta`` records ``nrep_used``,
    ``converged`` and the final ``rel_ci`` — the provenance every stored
    result needs to interpret its own sample size.
    """
    if initial is not None:
        times = np.asarray(initial, dtype=np.float64)
    else:
        times = np.asarray(measure(ctx, case, design.nrep_min),
                           dtype=np.float64)
    while True:
        kept = tukey_filter(times) if design.outlier_filter else times
        rel = relative_ci_width(kept if kept.size else times, design.ci_level)
        if rel <= design.rel_ci_target:
            return times, dict(nrep_used=int(times.size), converged=True,
                               rel_ci=float(rel))
        remaining = design.nrep_max - times.size
        if remaining <= 0:
            return times, dict(nrep_used=int(times.size), converged=False,
                               rel_ci=float(rel))
        # grow geometrically (~1.5x) so convergence checks stay O(log n)
        chunk = int(min(remaining, max(design.nrep_min, times.size // 2)))
        more = np.asarray(measure(ctx, case, chunk), dtype=np.float64)
        if more.size == 0:
            return times, dict(nrep_used=int(times.size), converged=False,
                               rel_ci=float(rel))
        times = np.concatenate([times, more])


def measure_case(
    measure: Callable[[Any, TestCase, int], np.ndarray],
    ctx: Any,
    case: TestCase,
    design: ExperimentDesign,
) -> tuple[np.ndarray, dict]:
    """Measure one case under the design's nrep policy (fixed or adaptive)."""
    if design.adaptive:
        times, meta = measure_adaptive(measure, ctx, case, design)
    else:
        times = np.asarray(measure(ctx, case, design.nrep), dtype=np.float64)
        meta = dict(nrep_used=int(times.size), converged=True)
    NREP_SPENT.add(times.size)
    return times, meta


def _measure_epoch(
    epoch_factory: Callable[[int], Any],
    measure: Callable[[Any, TestCase, int], np.ndarray],
    epoch: int,
    order: list[TestCase],
    design: ExperimentDesign,
) -> list[tuple[TestCase, np.ndarray, dict]]:
    """One launch epoch: build a fresh context and measure every case in
    the given (already shuffled) order. Module-level so it can cross a
    process boundary."""
    ctx = epoch_factory(epoch)
    return [
        (case, *measure_case(measure, ctx, case, design))
        for case in order
    ]


def case_orders(design: ExperimentDesign,
                cases: Iterable[TestCase]) -> list[list[TestCase]]:
    """Per-epoch case orders, drawn up front from the design seed (Alg. 5
    line 9). Shared by :func:`run_design` and the campaign orchestrator so
    a resumed campaign replays the exact order of the original run."""
    cases = list(cases)
    rng = np.random.default_rng(design.seed)
    orders: list[list[TestCase]] = []
    for _ in range(design.n_launch_epochs):
        order = list(cases)
        if design.shuffle:
            perm = rng.permutation(len(order))
            order = [order[i] for i in perm]
        orders.append(order)
    return orders


def _as_backend_pair(backend_or_factory, measure):
    """Accept either a :class:`~repro.campaign.MeasurementBackend` (has
    ``make_epoch`` + ``measure``) or the **deprecated** legacy
    ``(epoch_factory, measure)`` pair; return the pair.

    The backend protocol is the single entry point: it carries factor
    capture, default cases and provenance that the bare pair cannot, so
    results measured through a pair are second-class citizens in every
    layer above (stores, sweeps, audits). Wrap a pair in
    :class:`~repro.campaign.FunctionBackend` instead.
    """
    if measure is None:
        if not (hasattr(backend_or_factory, "make_epoch")
                and hasattr(backend_or_factory, "measure")):
            raise TypeError(
                "run_design: pass a MeasurementBackend, or an epoch_factory "
                "together with a measure callable")
        return backend_or_factory.make_epoch, backend_or_factory.measure
    warnings.warn(
        "run_design(epoch_factory, measure) is deprecated; wrap the pair "
        "in repro.campaign.FunctionBackend (the MeasurementBackend "
        "protocol is the single entry point)",
        DeprecationWarning, stacklevel=3)
    return backend_or_factory, measure


def run_design(
    design: ExperimentDesign,
    backend: Any,
    measure: Callable[[Any, TestCase, int], np.ndarray] | None = None,
    cases: Iterable[TestCase] | None = None,
    n_workers: int = 1,
) -> list[MeasurementRecord]:
    """Algorithm 5: ``n`` launch epochs, each measuring all cases in a
    freshly shuffled order.

    ``backend`` is either a :class:`~repro.campaign.MeasurementBackend`
    (``measure`` omitted; ``cases`` defaults to ``backend.default_cases()``)
    or, legacy form, an ``epoch_factory`` callable paired with an explicit
    ``measure``.

    With ``n_workers > 1`` the epochs — independent by the paper's own
    design — run across a ``ProcessPoolExecutor``. Records come back in
    the serial order (epoch-major, then shuffled case order) and are
    bit-identical to a serial run whenever the factory/measure pair is
    deterministic per epoch index. Falls back to the serial loop when the
    callables cannot be pickled or no pool can be spawned.
    """
    if cases is None:
        if hasattr(backend, "default_cases"):
            cases = backend.default_cases()
        else:
            raise TypeError("run_design: cases is required unless the "
                            "backend provides default_cases()")
    epoch_factory, measure = _as_backend_pair(backend, measure)
    cases = list(cases)
    orders = case_orders(design, cases)

    per_epoch: list[list[tuple[TestCase, np.ndarray, dict]]] | None = None
    if n_workers and n_workers > 1 and design.n_launch_epochs > 1:
        per_epoch = _run_epochs_parallel(
            design, epoch_factory, measure, orders, n_workers)
    if per_epoch is None:
        per_epoch = [
            _measure_epoch(epoch_factory, measure, epoch, orders[epoch],
                           design)
            for epoch in range(design.n_launch_epochs)
        ]

    records: list[MeasurementRecord] = []
    for epoch, results in enumerate(per_epoch):
        for case, times, meta in results:
            records.append(MeasurementRecord(case=case, epoch=epoch,
                                             times=times, meta=meta))
    return records


def map_parallel(
    fn: Callable,
    argtuples: list[tuple],
    n_workers: int,
    what: str = "tasks",
    on_result: Callable[[int, Any], None] | None = None,
    timeout: float | None = None,
    max_restarts: int = 1,
    retry: Any | None = None,
) -> list | None:
    """Run ``fn(*args)`` for every argtuple across a ``ProcessPoolExecutor``.

    The shared fan-out machinery of :func:`run_design` (launch epochs) and
    the sweep scheduler (grid cells). Results come back in submission
    order; ``on_result(index, result)`` fires in the *parent* as each task
    completes (completion order), which is how a sharded sweep persists
    finished cells while later cells are still running.

    Failure semantics distinguish *setup* from *execution*:

    * **Setup failure** — unpicklable callables/args, or the first pool
      refusing to spawn — returns ``None`` so the caller falls back to its
      serial loop: nothing has run yet, serial is a faithful substitute.
    * **Worker crash mid-run** (``BrokenProcessPool``) restarts the pool
      and resubmits only the unfinished tasks, backing off between
      restarts (``retry``, a :class:`~repro.core.retry.RetryPolicy`;
      default two quick jittered restarts). The warning names exactly
      which task indices were in flight. After ``max_restarts`` the
      exception is **re-raised** — a pool that keeps dying is a fault the
      caller must see, not silently absorb into a serial run whose
      completion would misattribute the crash to nothing.
    * **Stall** — no task completing within ``timeout`` seconds — raises
      ``TimeoutError`` naming the in-flight tasks after terminating the
      pool's workers: a hung worker must not wedge the campaign forever.
      ``None`` (default) waits indefinitely, the pre-existing behavior.
    """
    import concurrent.futures as cf
    import multiprocessing as mp
    import pickle

    from .retry import RetryPolicy

    if not argtuples:
        return []
    try:
        pickle.dumps((fn, argtuples))
    except Exception:
        warnings.warn(
            f"map_parallel: {what} not picklable; running serially",
            RuntimeWarning, stacklevel=3)
        return None
    mp_ctx = None
    if "fork" in mp.get_all_start_methods():
        mp_ctx = mp.get_context("fork")
    if retry is None:
        retry = RetryPolicy(base=0.1, max_delay=1.0,
                            attempts=max_restarts + 1, seed=0)

    out: list = [None] * len(argtuples)
    done_idx: set[int] = set()
    restarts = 0
    while True:
        pending_idx = [i for i in range(len(argtuples)) if i not in done_idx]
        try:
            pool = cf.ProcessPoolExecutor(
                max_workers=min(n_workers, len(pending_idx)),
                mp_context=mp_ctx)
        except OSError as e:
            if restarts:        # a pool ran and died, and now none spawns:
                raise           # that is a fault, not a setup condition
            warnings.warn(
                f"map_parallel: no process pool available ({e!r}); running "
                f"{what} serially", RuntimeWarning, stacklevel=3)
            return None
        try:
            with pool:
                futures = {pool.submit(fn, *argtuples[i]): i
                           for i in pending_idx}
                not_done = set(futures)
                while not_done:
                    done, not_done = cf.wait(
                        not_done, timeout=timeout,
                        return_when=cf.FIRST_COMPLETED)
                    if not done:
                        in_flight = sorted(futures[f] for f in not_done)
                        # a hung worker would block pool.__exit__ forever;
                        # kill the workers so the TimeoutError actually
                        # returns control to the caller
                        for p in getattr(pool, "_processes", {}).values():
                            p.terminate()
                        pool.shutdown(wait=False, cancel_futures=True)
                        raise TimeoutError(
                            f"map_parallel: no {what} completed within "
                            f"{timeout}s; in flight: {in_flight}")
                    for fut in done:
                        i = futures[fut]
                        out[i] = fut.result()
                        done_idx.add(i)
                        if on_result is not None:
                            on_result(i, out[i])
            return out
        except cf.process.BrokenProcessPool as e:
            in_flight = sorted(i for i in pending_idx if i not in done_idx)
            if restarts >= max_restarts:
                raise cf.process.BrokenProcessPool(
                    f"map_parallel: pool died {restarts + 1}x running {what}; "
                    f"giving up with {len(in_flight)} tasks unfinished: "
                    f"{in_flight}") from e
            delay = retry.delay(restarts)
            warnings.warn(
                f"map_parallel: a worker process died ({e!r}); "
                f"{len(in_flight)}/{len(argtuples)} {what} in flight: "
                f"{in_flight}; restarting pool in {delay:.2f}s "
                f"({restarts + 1}/{max_restarts} restarts)",
                RuntimeWarning, stacklevel=3)
            import time as _time

            _time.sleep(delay)
            restarts += 1


def _run_epochs_parallel(design, epoch_factory, measure, orders, n_workers):
    """Fan the launch epochs out over processes; ``None`` on any setup
    failure so :func:`run_design` runs serially instead."""
    return map_parallel(
        _measure_epoch,
        [(epoch_factory, measure, epoch, orders[epoch], design)
         for epoch in range(design.n_launch_epochs)],
        n_workers, what="epoch_factory/measure")


def analyze_records(
    records: Iterable[MeasurementRecord],
    outlier_filter: bool = True,
) -> ResultTable:
    """Algorithm 6: per (case, epoch) Tukey-filter then mean & median."""
    summaries: list[EpochSummary] = []
    for rec in records:
        raw = rec.times
        kept = tukey_filter(raw) if outlier_filter else raw
        if kept.size == 0:
            kept = raw
        summaries.append(
            EpochSummary(
                case=rec.case,
                epoch=rec.epoch,
                mean=float(np.mean(kept)),
                median=float(np.median(kept)),
                n_kept=int(kept.size),
                n_raw=int(raw.size),
                host=str(rec.meta.get("host", "")),
            )
        )
    return ResultTable(summaries=summaries)
