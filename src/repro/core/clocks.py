"""Clock models and linear drift models (paper §3.1, §4.3-4.4).

Terminology follows Kshemkalyani & Singhal as used in the paper:
  * clock *offset*: difference between the times reported by two clocks,
  * clock *skew*:   difference in the clocks' frequencies,
  * clock *drift*:  difference between two clocks over a period of time.

A hardware clock is modeled as an affine distortion of true time ``t``::

    local(t) = offset + (1 + skew) * t            (+ optional random walk)

which is exactly the linearity assumption of Jones & Koenig [19] that the
paper adopts (§4.3) and that Fig. 3 verifies empirically (drift is linear
over the tens-of-seconds horizon of a benchmark run).

``LinearModel`` is the paper's (slope, intercept) drift model: a process
``r`` learns ``d_r(t_r) = t_r - t_ref ~= slope * t_r + intercept`` from
ping-pong exchanges, and normalizes local to global (reference) time with
Algorithm 16::

    global(t_r) = t_r - (slope * t_r + intercept)

``LinearModel.merge`` is MERGE_LMS of Algorithm 4 (the exact transitive
composition of child-time-parameterized drift models; see the note below
about Eq. (1) in the paper).
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "Clock",
    "PerfClock",
    "SimClock",
    "AdjustedClock",
    "DriftPath",
    "LinearModel",
    "IDENTITY_MODEL",
    "derive_stream",
    "linear_fit",
]


def derive_stream(parent, *keys) -> np.random.Generator:
    """Derive an independent child RNG stream from ``parent``.

    ``parent`` is either an integer seed or a live
    :class:`numpy.random.Generator` — in the latter case exactly one draw
    is consumed from it, preserving the stream position of the historic
    inline derivations (``default_rng(rng.integers(2**31))``). ``keys``
    namespace sibling streams deterministically; strings are hashed with
    CRC-32 rather than ``hash()`` (which is salted per process), so every
    engine port — scalar, batch, JAX — derives the *same* stream for the
    same logical purpose.
    """
    if isinstance(parent, np.random.Generator):
        root = int(parent.integers(2**31))
    else:
        root = int(parent)
    if not keys:
        return np.random.default_rng(root)
    material = [root & 0xFFFFFFFFFFFFFFFF]
    for k in keys:
        if isinstance(k, str):
            material.append(zlib.crc32(k.encode("utf-8")) & 0xFFFFFFFF)
        else:
            material.append(int(k) & 0xFFFFFFFFFFFFFFFF)
    return np.random.default_rng(np.random.SeedSequence(material))


class Clock:
    """Abstract local clock. ``read(t_true)`` maps true time -> local time.

    Real clocks ignore ``t_true`` and read the host monotonic clock. The
    simulation passes the discrete-event true time explicitly.
    """

    def read(self, t_true: float) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class PerfClock(Clock):
    """Monotonic host clock (the TPU-host analogue of fixed-frequency RDTSCP).

    The paper (§3.4) pins the frequency and uses RDTSCP; on a TPU host we
    use CLOCK_MONOTONIC via ``time.perf_counter_ns`` which is likewise
    unaffected by NTP slewing of the wall clock on Linux.
    """

    def read(self, t_true: float = 0.0) -> float:
        return time.perf_counter_ns() * 1e-9


@dataclass
class DriftPath:
    """Pre-sampled cumulative random-walk drift on a fixed true-time grid.

    The lazy walk of :class:`SimClock` samples an increment at every clock
    read, which forces a per-observation scalar loop. A ``DriftPath``
    instead materializes the walk on nodes ``t_k = anchor + k * dt`` and
    linearly interpolates between them — the same Gaussian process at the
    nodes, a vectorizable piecewise-affine function everywhere else. That
    piecewise affinity is what makes batched local↔global deadline
    inversion possible (``SimClock.true_at_local``): locate the bracketing
    segment by binary search, then solve the in-segment affine map.

    Node values depend only on the derived stream and the node count, not
    on query order, so scalar and batched engines reading the same path see
    bit-identical walks.
    """

    sigma: float
    dt: float
    rng: np.random.Generator = field(repr=False)
    t: np.ndarray = field(repr=False)    # node true times, fixed spacing dt
    x: np.ndarray = field(repr=False)    # node walk values [s]

    @classmethod
    def start(cls, sigma: float, dt: float, anchor_t: float, anchor_x: float,
              rng: np.random.Generator) -> "DriftPath":
        return cls(sigma=float(sigma), dt=float(dt), rng=rng,
                   t=np.array([anchor_t], dtype=np.float64),
                   x=np.array([anchor_x], dtype=np.float64))

    @property
    def version(self) -> int:
        """Grows monotonically with the path; cheap cache-invalidation key."""
        return self.t.size

    def ensure(self, t_max: float) -> None:
        """Extend the path so its last node is at or past ``t_max``."""
        need = int(np.ceil((float(t_max) - float(self.t[-1])) / self.dt))
        if need <= 0:
            return
        n = max(need, 256)
        if self.sigma > 0.0:
            steps = self.rng.normal(0.0, self.sigma * np.sqrt(self.dt), size=n)
            # Keep per-segment local time strictly increasing even if a step
            # outruns the clock's own rate (needs sigma ~ sqrt(dt)/2 — never
            # at physical rw_sigma ~ 1e-7, but the inversion must not hang).
            np.clip(steps, -0.45 * self.dt, 0.45 * self.dt, out=steps)
        else:
            steps = np.zeros(n)
        t_new = self.t[-1] + self.dt * np.arange(1, n + 1)
        self.t = np.concatenate((self.t, t_new))
        self.x = np.concatenate((self.x, self.x[-1] + np.cumsum(steps)))

    def value(self, t_true):
        """Walk value at ``t_true`` (scalar or array), extending on demand."""
        arr = np.asarray(t_true, dtype=np.float64)
        if arr.size:
            self.ensure(float(np.max(arr)))
        out = np.interp(arr, self.t, self.x)
        return out if arr.ndim else float(out)


@dataclass
class SimClock(Clock):
    """Simulated hardware clock with offset, skew and optional noise.

    ``local(t) = offset + (1 + skew) * t + rw(t)`` where ``rw`` is an
    optional random-walk component (std ``rw_sigma`` per second) modelling
    oscillator wander.  ``scale_error`` models the *frequency estimation*
    error of §4.2.1 (Netgauge's HRT_CALIBRATE): reading the clock through a
    mis-estimated frequency multiplies elapsed local time by
    ``(1 + scale_error)``; the paper measures ~4.3e-6 relative error, i.e.
    an extra microsecond of drift per second.

    The walk has two sampling modes. *Lazy* (the default): an increment is
    drawn at every forward read — inherently scalar. *Path*: after
    :meth:`drift_path` activates a :class:`DriftPath`, reads interpolate
    the pre-sampled walk and accept arrays, and :meth:`true_at_local`
    inverts the clock exactly — what the batched window engine
    (``engine="batch_rw"``) is built on.
    """

    offset: float = 0.0
    skew: float = 0.0
    rw_sigma: float = 0.0
    scale_error: float = 0.0
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    _rw_t: float = field(default=0.0, init=False, repr=False)
    _rw_x: float = field(default=0.0, init=False, repr=False)
    _path: "DriftPath | None" = field(default=None, init=False, repr=False)
    _raw_nodes_cache: tuple = field(default=None, init=False, repr=False)

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def _random_walk(self, t_true: float) -> float:
        if self.rw_sigma <= 0.0:
            return 0.0
        dt = t_true - self._rw_t
        if dt > 0:
            self._rw_x += float(self._rng.normal(0.0, self.rw_sigma * np.sqrt(dt)))
            self._rw_t = t_true
        return self._rw_x

    def drift_path(self, dt: float) -> DriftPath:
        """Switch the walk to path mode (idempotent; returns the path).

        The path anchors at the walk's current state and samples forward on
        a ``dt`` grid from a stream derived from the clock seed — so two
        identically-seeded clocks frozen at the same state grow identical
        paths regardless of which engine queries them first.
        """
        if self._path is None:
            self._path = DriftPath.start(
                self.rw_sigma, max(float(dt), 1e-9), self._rw_t, self._rw_x,
                derive_stream(self.seed, "drift-path"))
        return self._path

    def read(self, t_true):
        """Local clock at true time ``t_true``.

        Scalar in lazy mode; accepts arrays once a drift path is active.
        """
        if self._path is not None:
            rw = self._path.value(t_true)
        else:
            rw = self._random_walk(t_true)
        raw = self.offset + (1.0 + self.skew) * t_true + rw
        out = raw * (1.0 + self.scale_error)
        return out if np.ndim(out) else float(out)

    def read_affine(self, t_true):
        """Affine part of :meth:`read` (no random-walk term); accepts
        arrays. This is the map the vectorized network paths
        (``pingpong_batch``, the fitpoint sweep) apply to whole true-time
        batches — identical to :meth:`read` whenever ``rw_sigma == 0``.
        """
        return (self.offset + (1.0 + self.skew) * t_true) * (1.0 + self.scale_error)

    def _raw_nodes(self) -> np.ndarray:
        """Node-wise raw local readings ``offset + (1+skew) t_k + x_k``
        of the drift path, cached until the path grows."""
        path = self._path
        cache = self._raw_nodes_cache
        if cache is None or cache[0] != path.version:
            f = self.offset + (1.0 + self.skew) * path.t + path.x
            self._raw_nodes_cache = (path.version, f)
        return self._raw_nodes_cache[1]

    def true_at_local(self, local):
        """Invert :meth:`read`: local reading → true time (scalar or array).

        In path mode the inversion is exact: raw local readings are
        strictly increasing node-to-node (``DriftPath.ensure`` clips steps
        below the clock rate), so bracket the target by binary search over
        the node readings and solve the in-segment affine map. In lazy mode
        the walk is frozen at its last sampled value — the future cannot be
        anticipated — matching the scalar engine's historical busy-wait
        semantics.
        """
        scalar = np.ndim(local) == 0
        raw = np.asarray(local, dtype=np.float64) / (1.0 + self.scale_error)
        if self._path is None:
            out = (raw - self.offset - self._rw_x) / (1.0 + self.skew)
            return float(out) if scalar else out
        path = self._path
        rate = 1.0 + self.skew
        raw_max = float(np.max(raw)) if raw.size else -np.inf
        path.ensure((raw_max - self.offset) / rate + 2.0 * path.dt)
        f = self._raw_nodes()
        while f[-1] < raw_max:      # drift pushed the root past the horizon
            path.ensure(path.t[-1] + 16.0 * path.dt)
            f = self._raw_nodes()
        idx = np.clip(np.searchsorted(f, raw, side="right") - 1,
                      0, f.size - 2)
        seg_slope = rate + (path.x[idx + 1] - path.x[idx]) / path.dt
        out = path.t[idx] + (raw - f[idx]) / seg_slope
        return float(out) if scalar else out

    def true_offset_to(self, other: "SimClock", t_true: float) -> float:
        """Ground-truth offset ``self - other`` at true time ``t_true``."""
        return self.read(t_true) - other.read(t_true)


@dataclass
class AdjustedClock(Clock):
    """Logical local clock starting at zero (Alg. 3 line 1 / GET_ADJUSTED_TIME).

    The paper subtracts the initially-read timestamp so that the intercept of
    the drift model represents the offset at (local) time zero instead of at
    an arbitrary hardware epoch.
    """

    base: Clock
    initial_time: float = 0.0

    def read(self, t_true: float) -> float:
        return self.base.read(t_true) - self.initial_time


@dataclass(frozen=True)
class LinearModel:
    """Linear model of the clock drift of one process relative to a reference.

    ``d(t_local) = slope * t_local + intercept ~= t_local - t_ref``.
    """

    slope: float = 0.0
    intercept: float = 0.0

    def normalize(self, local_time: float) -> float:
        """Algorithm 16: local time -> reference (global) time."""
        return local_time - (local_time * self.slope + self.intercept)

    def denormalize(self, global_time: float) -> float:
        """Inverse of :meth:`normalize` (exact)."""
        return (global_time + self.intercept) / (1.0 - self.slope)

    def with_intercept_from_offset(self, diff: float, diff_timestamp: float) -> "LinearModel":
        """COMPUTE_AND_SET_INTERCEPT (Alg. 4 lines 22-28).

        Re-anchor the intercept from a directly measured clock offset
        ``diff`` (this process minus reference) observed at adjusted local
        time ``diff_timestamp``: solve ``slope*t + i = diff`` at
        ``t = diff_timestamp``.
        """
        return LinearModel(self.slope, self.slope * (-diff_timestamp) + diff)

    @staticmethod
    def merge(lm_mid: "LinearModel", lm_child: "LinearModel") -> "LinearModel":
        """MERGE_LMS (Alg. 4 lines 29-31).

        ``lm_mid`` is the model of process M relative to reference R (a
        function of M's local time); ``lm_child`` is the model of process C
        relative to M (a function of C's local time). Returns C's model
        relative to R. This is the *exact* composition::

            d_CR(t_C) = d_CM(t_C) + s_MR*(t_C - d_CM(t_C)) + i_MR

        giving ``slope = s1 + s2 - s1*s2`` and ``intercept = i1 + i2 - s1*i2``
        (with 1 = mid, 2 = child), matching the pseudocode of MERGE_LMS.
        (The prose derivation in Eq. (1) of the paper parameterizes by the
        reference's time instead; the two agree to first order in the slopes,
        and the pseudocode form used here is exact for the learned model
        orientation — verified by ``tests/test_clock_sync.py``.)
        """
        s1, i1 = lm_mid.slope, lm_mid.intercept
        s2, i2 = lm_child.slope, lm_child.intercept
        return LinearModel(s1 + s2 - s1 * s2, i1 + i2 - s1 * i2)


IDENTITY_MODEL = LinearModel(0.0, 0.0)


def linear_fit(x: np.ndarray, y: np.ndarray) -> LinearModel:
    """Least-squares LINEAR_FIT used by JK and HCA (Alg. 4 line 20).

    Centered formulation for numerical stability with large time values.
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if x.size < 2:
        return LinearModel(0.0, float(y.mean()) if y.size else 0.0)
    xm = x.mean()
    ym = y.mean()
    dx = x - xm
    denom = float(np.dot(dx, dx))
    if denom == 0.0:
        return LinearModel(0.0, float(ym))
    slope = float(np.dot(dx, y - ym) / denom)
    intercept = float(ym - slope * xm)
    return LinearModel(slope, intercept)
