"""Clock models and linear drift models (paper §3.1, §4.3-4.4).

Terminology follows Kshemkalyani & Singhal as used in the paper:
  * clock *offset*: difference between the times reported by two clocks,
  * clock *skew*:   difference in the clocks' frequencies,
  * clock *drift*:  difference between two clocks over a period of time.

A hardware clock is modeled as an affine distortion of true time ``t``::

    local(t) = offset + (1 + skew) * t            (+ optional random walk)

which is exactly the linearity assumption of Jones & Koenig [19] that the
paper adopts (§4.3) and that Fig. 3 verifies empirically (drift is linear
over the tens-of-seconds horizon of a benchmark run).

``LinearModel`` is the paper's (slope, intercept) drift model: a process
``r`` learns ``d_r(t_r) = t_r - t_ref ~= slope * t_r + intercept`` from
ping-pong exchanges, and normalizes local to global (reference) time with
Algorithm 16::

    global(t_r) = t_r - (slope * t_r + intercept)

``LinearModel.merge`` is MERGE_LMS of Algorithm 4 (the exact transitive
composition of child-time-parameterized drift models; see the note below
about Eq. (1) in the paper).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "Clock",
    "PerfClock",
    "SimClock",
    "AdjustedClock",
    "LinearModel",
    "IDENTITY_MODEL",
    "linear_fit",
]


class Clock:
    """Abstract local clock. ``read(t_true)`` maps true time -> local time.

    Real clocks ignore ``t_true`` and read the host monotonic clock. The
    simulation passes the discrete-event true time explicitly.
    """

    def read(self, t_true: float) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class PerfClock(Clock):
    """Monotonic host clock (the TPU-host analogue of fixed-frequency RDTSCP).

    The paper (§3.4) pins the frequency and uses RDTSCP; on a TPU host we
    use CLOCK_MONOTONIC via ``time.perf_counter_ns`` which is likewise
    unaffected by NTP slewing of the wall clock on Linux.
    """

    def read(self, t_true: float = 0.0) -> float:
        return time.perf_counter_ns() * 1e-9


@dataclass
class SimClock(Clock):
    """Simulated hardware clock with offset, skew and optional noise.

    ``local(t) = offset + (1 + skew) * t + rw(t)`` where ``rw`` is an
    optional random-walk component (std ``rw_sigma`` per second) modelling
    oscillator wander.  ``scale_error`` models the *frequency estimation*
    error of §4.2.1 (Netgauge's HRT_CALIBRATE): reading the clock through a
    mis-estimated frequency multiplies elapsed local time by
    ``(1 + scale_error)``; the paper measures ~4.3e-6 relative error, i.e.
    an extra microsecond of drift per second.
    """

    offset: float = 0.0
    skew: float = 0.0
    rw_sigma: float = 0.0
    scale_error: float = 0.0
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    _rw_t: float = field(default=0.0, init=False, repr=False)
    _rw_x: float = field(default=0.0, init=False, repr=False)

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def _random_walk(self, t_true: float) -> float:
        if self.rw_sigma <= 0.0:
            return 0.0
        dt = t_true - self._rw_t
        if dt > 0:
            self._rw_x += float(self._rng.normal(0.0, self.rw_sigma * np.sqrt(dt)))
            self._rw_t = t_true
        return self._rw_x

    def read(self, t_true: float) -> float:
        raw = self.offset + (1.0 + self.skew) * t_true + self._random_walk(t_true)
        return raw * (1.0 + self.scale_error)

    def read_affine(self, t_true):
        """Affine part of :meth:`read` (no random-walk term); accepts
        arrays. This is the map the vectorized network paths
        (``pingpong_batch``, the fitpoint sweep) apply to whole true-time
        batches — identical to :meth:`read` whenever ``rw_sigma == 0``.
        """
        return (self.offset + (1.0 + self.skew) * t_true) * (1.0 + self.scale_error)

    def true_offset_to(self, other: "SimClock", t_true: float) -> float:
        """Ground-truth offset ``self - other`` at true time ``t_true``."""
        return self.read(t_true) - other.read(t_true)


@dataclass
class AdjustedClock(Clock):
    """Logical local clock starting at zero (Alg. 3 line 1 / GET_ADJUSTED_TIME).

    The paper subtracts the initially-read timestamp so that the intercept of
    the drift model represents the offset at (local) time zero instead of at
    an arbitrary hardware epoch.
    """

    base: Clock
    initial_time: float = 0.0

    def read(self, t_true: float) -> float:
        return self.base.read(t_true) - self.initial_time


@dataclass(frozen=True)
class LinearModel:
    """Linear model of the clock drift of one process relative to a reference.

    ``d(t_local) = slope * t_local + intercept ~= t_local - t_ref``.
    """

    slope: float = 0.0
    intercept: float = 0.0

    def normalize(self, local_time: float) -> float:
        """Algorithm 16: local time -> reference (global) time."""
        return local_time - (local_time * self.slope + self.intercept)

    def denormalize(self, global_time: float) -> float:
        """Inverse of :meth:`normalize` (exact)."""
        return (global_time + self.intercept) / (1.0 - self.slope)

    def with_intercept_from_offset(self, diff: float, diff_timestamp: float) -> "LinearModel":
        """COMPUTE_AND_SET_INTERCEPT (Alg. 4 lines 22-28).

        Re-anchor the intercept from a directly measured clock offset
        ``diff`` (this process minus reference) observed at adjusted local
        time ``diff_timestamp``: solve ``slope*t + i = diff`` at
        ``t = diff_timestamp``.
        """
        return LinearModel(self.slope, self.slope * (-diff_timestamp) + diff)

    @staticmethod
    def merge(lm_mid: "LinearModel", lm_child: "LinearModel") -> "LinearModel":
        """MERGE_LMS (Alg. 4 lines 29-31).

        ``lm_mid`` is the model of process M relative to reference R (a
        function of M's local time); ``lm_child`` is the model of process C
        relative to M (a function of C's local time). Returns C's model
        relative to R. This is the *exact* composition::

            d_CR(t_C) = d_CM(t_C) + s_MR*(t_C - d_CM(t_C)) + i_MR

        giving ``slope = s1 + s2 - s1*s2`` and ``intercept = i1 + i2 - s1*i2``
        (with 1 = mid, 2 = child), matching the pseudocode of MERGE_LMS.
        (The prose derivation in Eq. (1) of the paper parameterizes by the
        reference's time instead; the two agree to first order in the slopes,
        and the pseudocode form used here is exact for the learned model
        orientation — verified by ``tests/test_clock_sync.py``.)
        """
        s1, i1 = lm_mid.slope, lm_mid.intercept
        s2, i2 = lm_child.slope, lm_child.intercept
        return LinearModel(s1 + s2 - s1 * s2, i1 + i2 - s1 * i2)


IDENTITY_MODEL = LinearModel(0.0, 0.0)


def linear_fit(x: np.ndarray, y: np.ndarray) -> LinearModel:
    """Least-squares LINEAR_FIT used by JK and HCA (Alg. 4 line 20).

    Centered formulation for numerical stability with large time values.
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if x.size < 2:
        return LinearModel(0.0, float(y.mean()) if y.size else 0.0)
    xm = x.mean()
    ym = y.mean()
    dx = x - xm
    denom = float(np.dot(dx, dx))
    if denom == 0.0:
        return LinearModel(0.0, float(ym))
    slope = float(np.dot(dx, y - ym) / denom)
    intercept = float(ym - slope * xm)
    return LinearModel(slope, intercept)
