"""Simulated collective-operation cost models (§3.2, §5.1).

The measurement experiments need a distributed operation to time. On a real
pod the object under test is a jitted JAX collective or step function (see
:mod:`repro.core.runtime_meter`); in the simulation it is a cost model with
the statistical structure the paper reports:

  * non-normal, right-skewed run-time distributions with a *second smaller
    peak* on the right (bimodal, Fig. 14),
  * occasional OS-noise spikes (long tail),
  * per-rank finish imbalance (what makes ``max end - min start`` differ
    from ``max local``),
  * lag-1 autocorrelation between consecutive measurements (Fig. 18),
  * a per-launch-epoch bias: distinct mpiruns/launch epochs have different
    means (§5.2, Figs. 16-17) — modeled as a small multiplicative factor
    drawn once per :class:`~repro.core.simnet.SimNet` instance.

The default constants give a few tens of microseconds for small messages at
p = 16, matching Table 1 / Fig. 14 magnitudes.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .simnet import SimNet

__all__ = ["SimCollective", "CollectiveExecution", "OP_LIBRARY", "make_op"]


@dataclass
class CollectiveExecution:
    """Per-rank true start/finish times of one simulated collective call."""

    start_true: np.ndarray
    end_true: np.ndarray


@dataclass
class SimCollective:
    """Cost model ``T(p, m) = alpha * ceil(log2 p) + beta * m + gamma``.

    ``epoch_bias`` models the launch-epoch factor (§5.2): a per-process-
    instantiation multiplicative offset, sampled once per (net, op) pair.
    """

    name: str = "allreduce"
    alpha: float = 3.0e-6        # per tree level [s]
    beta: float = 2.5e-10        # per byte [s] (~4 GB/s effective)
    gamma: float = 2.0e-6        # fixed overhead [s]
    msize_factor: float = 1.0    # e.g. 2x for allreduce (reduce+bcast phases)
    noise_sigma: float = 0.04    # lognormal sigma on the common duration
    tail_prob: float = 0.08      # bimodal right peak probability (Fig. 14)
    tail_shift: float = 0.35     # right peak at ~(1+shift) * mean
    spike_prob: float = 0.003    # OS-noise spike
    spike_scale: float = 8.0
    rank_imbalance: float = 0.06 # per-rank finish spread (fraction of T)
    autocorr: float = 0.35       # AR(1) coefficient between consecutive calls
    epoch_bias_sigma: float = 0.02  # per-launch-epoch mean shift (§5.2)
    warm_cache_discount: float = 0.12  # §5.8: warm buffers run faster
    _ar_state: float = field(default=0.0, init=False, repr=False)
    _epoch_bias: dict = field(default_factory=dict, init=False, repr=False)

    def base_time(self, p: int, msize: int) -> float:
        levels = max(1, int(np.ceil(np.log2(max(2, p)))))
        return self.alpha * levels + self.beta * self.msize_factor * msize + self.gamma

    def _bias_for(self, net: SimNet) -> float:
        key = id(net)
        if key not in self._epoch_bias:
            rng = np.random.default_rng(net.rng.integers(2**31))
            self._epoch_bias[key] = float(
                np.exp(rng.normal(0.0, self.epoch_bias_sigma))
            )
        return self._epoch_bias[key]

    def sample_duration(self, net: SimNet, p: int, msize: int,
                        warm: bool = True) -> float:
        """Common (synchronized-start) duration of one call."""
        t0 = self.base_time(p, msize) * self._bias_for(net)
        if not warm:
            t0 *= 1.0 + self.warm_cache_discount
        rng = net.rng
        # AR(1) lognormal noise (Fig. 18's autocorrelation).
        eps = float(rng.normal(0.0, self.noise_sigma))
        self._ar_state = self.autocorr * self._ar_state + eps
        t = t0 * float(np.exp(self._ar_state))
        if rng.random() < self.tail_prob:
            t *= 1.0 + self.tail_shift * float(rng.uniform(0.7, 1.3))
        if rng.random() < self.spike_prob:
            t *= self.spike_scale
        return t

    def execute(self, net: SimNet, msize: int, ranks: list[int] | None = None,
                warm: bool = True) -> CollectiveExecution:
        """Run one collective call on the simulated cluster.

        Semantics of a synchronizing collective: no rank can finish before
        every rank has entered the call, so skewed entries inflate early
        entrants' *local* durations (§4.6 / Fig. 11's mechanism).
        """
        ranks = list(range(net.p)) if ranks is None else ranks
        p = len(ranks)
        start = net.t[ranks].copy()
        t_all_in = float(np.max(start))
        dur = self.sample_duration(net, p, msize, warm)
        imb = net.rng.normal(0.0, self.rank_imbalance, size=p)
        # one randomly-chosen "late" rank pattern per call
        end = t_all_in + dur * np.maximum(0.25, 1.0 + imb)
        for i, r in enumerate(ranks):
            net.t[r] = end[i]
        return CollectiveExecution(start_true=start, end_true=end)


def make_op(name: str, **overrides) -> SimCollective:
    """Factory for the collectives studied in the paper."""
    presets = {
        # msize_factor approximates the algorithmic volume multiplier.
        "bcast":     dict(msize_factor=1.0, alpha=2.5e-6),
        "allreduce": dict(msize_factor=2.0, alpha=3.0e-6),
        "alltoall":  dict(msize_factor=4.0, alpha=4.0e-6, rank_imbalance=0.10),
        "scan":      dict(msize_factor=2.0, alpha=3.5e-6, tail_prob=0.12),
        "reduce":    dict(msize_factor=1.0, alpha=2.5e-6),
        "barrier":   dict(msize_factor=0.0, alpha=2.0e-6, gamma=1.0e-6),
    }
    kw = dict(presets.get(name, {}))
    kw.update(overrides)
    return SimCollective(name=name, **kw)


OP_LIBRARY = tuple(sorted(["bcast", "allreduce", "alltoall", "scan", "reduce", "barrier"]))
