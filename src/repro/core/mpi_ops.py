"""Simulated collective-operation cost models (§3.2, §5.1).

The measurement experiments need a distributed operation to time. On a real
pod the object under test is a jitted JAX collective or step function (see
:mod:`repro.core.runtime_meter`); in the simulation it is a cost model with
the statistical structure the paper reports:

  * non-normal, right-skewed run-time distributions with a *second smaller
    peak* on the right (bimodal, Fig. 14),
  * occasional OS-noise spikes (long tail),
  * per-rank finish imbalance (what makes ``max end - min start`` differ
    from ``max local``),
  * lag-1 autocorrelation between consecutive measurements (Fig. 18),
  * a per-launch-epoch bias: distinct mpiruns/launch epochs have different
    means (§5.2, Figs. 16-17) — modeled as a small multiplicative factor
    drawn once per :class:`~repro.core.simnet.SimNet` instance.

The default constants give a few tens of microseconds for small messages at
p = 16, matching Table 1 / Fig. 14 magnitudes.

Two execution paths share the same cost model:

  * :meth:`SimCollective.execute` — the scalar semantic reference, one
    simulated call per invocation;
  * :meth:`SimCollective.execute_batch` — the vectorized engine: samples
    all ``nrep`` durations at once (:meth:`SimCollective.sample_durations`)
    and rolls the per-rank start/end recurrence forward in closed form.
    RNG draws are batched per quantity instead of interleaved per call, so
    a batch is statistically — not bit-wise — identical to ``nrep`` scalar
    calls with the same seed (``tests/test_batch_equivalence.py``).
"""

from __future__ import annotations

import weakref
from dataclasses import dataclass, field

import numpy as np

from .clocks import derive_stream
from .simnet import SimNet

__all__ = [
    "SimCollective",
    "SimCompositeOp",
    "CollectiveExecution",
    "BatchExecution",
    "OP_LIBRARY",
    "make_op",
    "make_composite_op",
]


@dataclass
class CollectiveExecution:
    """Per-rank true start/finish times of one simulated collective call."""

    start_true: np.ndarray
    end_true: np.ndarray


@dataclass
class BatchExecution:
    """Per-rank true start/finish times of ``nrep`` consecutive calls.

    ``start_true``/``end_true`` have shape ``(nrep, p)``; ``durations`` is
    the common (synchronized-start) duration of each call, shape ``(nrep,)``.
    """

    start_true: np.ndarray
    end_true: np.ndarray
    durations: np.ndarray


def _ar1_filter(eps: np.ndarray, coeff: float, state: float) -> np.ndarray:
    """Vectorized AR(1) recurrence ``s_i = coeff * s_{i-1} + eps_i``.

    Uses the exponential-decay closed form ``s_j = a^{j+1} s_{-1} +
    sum_k a^{j-k} eps_k`` evaluated in chunks small enough that the
    ``a^{-k}`` rescaling cannot overflow, so it is numerically equivalent
    to the scalar loop for any ``|coeff| < 1``.
    """
    n = eps.size
    if n == 0:
        return eps.copy()
    a = float(coeff)
    if a == 0.0:
        return eps.copy()
    if abs(a) >= 1.0:  # non-stationary config: fall back to the plain loop
        out = np.empty(n)
        s = state
        for i in range(n):
            s = a * s + eps[i]
            out[i] = s
        return out
    # chunk so that |a|^-(chunk-1) stays far below float64 overflow
    chunk = max(1, min(n, int(500.0 / max(1e-12, -np.log(abs(a))))))
    out = np.empty(n)
    carry = state
    for lo in range(0, n, chunk):
        e = eps[lo:lo + chunk]
        m = e.size
        decay = a ** np.arange(m)                     # a^j
        s = decay * np.cumsum(e / decay) + carry * a * decay
        out[lo:lo + m] = s
        carry = s[-1]
    return out


@dataclass
class SimCollective:
    """Cost model ``T(p, m) = alpha * ceil(log2 p) + beta * m + gamma``.

    ``epoch_bias`` models the launch-epoch factor (§5.2): a per-process-
    instantiation multiplicative offset, sampled once per (net, op) pair.
    The cache is keyed by the :class:`SimNet` object itself (weakly), so a
    garbage-collected epoch can never alias a new one through ``id`` reuse.
    """

    name: str = "allreduce"
    alpha: float = 3.0e-6        # per tree level [s]
    beta: float = 2.5e-10        # per byte [s] (~4 GB/s effective)
    gamma: float = 2.0e-6        # fixed overhead [s]
    msize_factor: float = 1.0    # e.g. 2x for allreduce (reduce+bcast phases)
    noise_sigma: float = 0.04    # lognormal sigma on the common duration
    tail_prob: float = 0.08      # bimodal right peak probability (Fig. 14)
    tail_shift: float = 0.35     # right peak at ~(1+shift) * mean
    spike_prob: float = 0.003    # OS-noise spike
    spike_scale: float = 8.0
    rank_imbalance: float = 0.06 # per-rank finish spread (fraction of T)
    autocorr: float = 0.35       # AR(1) coefficient between consecutive calls
    epoch_bias_sigma: float = 0.02  # per-launch-epoch mean shift (§5.2)
    warm_cache_discount: float = 0.12  # §5.8: warm buffers run faster
    _ar_state: float = field(default=0.0, init=False, repr=False)
    _epoch_bias: "weakref.WeakKeyDictionary[SimNet, float]" = field(
        default_factory=weakref.WeakKeyDictionary, init=False, repr=False)

    def base_time(self, p: int, msize: int) -> float:
        levels = max(1, int(np.ceil(np.log2(max(2, p)))))
        return self.alpha * levels + self.beta * self.msize_factor * msize + self.gamma

    def _bias_for(self, net: SimNet) -> float:
        bias = self._epoch_bias.get(net)
        if bias is None:
            # derive_stream(Generator) consumes one draw from net.rng —
            # bit-identical to the historic inline derivation here, and the
            # same helper the clock drift paths and the JAX engine use, so
            # engine ports cannot diverge on stream derivation.
            rng = derive_stream(net.rng)
            bias = float(np.exp(rng.normal(0.0, self.epoch_bias_sigma)))
            self._epoch_bias[net] = bias
        return bias

    def sample_duration(self, net: SimNet, p: int, msize: int,
                        warm: bool = True) -> float:
        """Common (synchronized-start) duration of one call."""
        t0 = self.base_time(p, msize) * self._bias_for(net)
        if not warm:
            t0 *= 1.0 + self.warm_cache_discount
        rng = net.rng
        # AR(1) lognormal noise (Fig. 18's autocorrelation).
        eps = float(rng.normal(0.0, self.noise_sigma))
        self._ar_state = self.autocorr * self._ar_state + eps
        t = t0 * float(np.exp(self._ar_state))
        if rng.random() < self.tail_prob:
            t *= 1.0 + self.tail_shift * float(rng.uniform(0.7, 1.3))
        if rng.random() < self.spike_prob:
            t *= self.spike_scale
        return t

    def sample_durations(self, net: SimNet, p: int, msize: int, nrep: int,
                         warm: bool = True) -> np.ndarray:
        """Vectorized :meth:`sample_duration`: ``nrep`` consecutive common
        durations with the same AR(1)/bimodal/spike structure.

        RNG draws are batched per quantity (noise, tail, tail magnitude,
        spike), so the stream order differs from ``nrep`` scalar calls; the
        marginal and joint (autocorrelation) distributions are identical.
        The AR(1) state is carried in and out, so mixing scalar and batch
        calls keeps the lag-1 correlation across the boundary.
        """
        if nrep <= 0:
            return np.empty(0)
        t0 = self.base_time(p, msize) * self._bias_for(net)
        if not warm:
            t0 *= 1.0 + self.warm_cache_discount
        rng = net.rng
        eps = rng.normal(0.0, self.noise_sigma, size=nrep)
        s = _ar1_filter(eps, self.autocorr, self._ar_state)
        self._ar_state = float(s[-1])
        t = t0 * np.exp(s)
        tails = rng.random(nrep) < self.tail_prob
        tail_mag = 1.0 + self.tail_shift * rng.uniform(0.7, 1.3, size=nrep)
        t = np.where(tails, t * tail_mag, t)
        spikes = rng.random(nrep) < self.spike_prob
        t = np.where(spikes, t * self.spike_scale, t)
        return t

    def execute(self, net: SimNet, msize: int, ranks: list[int] | None = None,
                warm: bool = True) -> CollectiveExecution:
        """Run one collective call on the simulated cluster.

        Semantics of a synchronizing collective: no rank can finish before
        every rank has entered the call, so skewed entries inflate early
        entrants' *local* durations (§4.6 / Fig. 11's mechanism).
        """
        ranks = list(range(net.p)) if ranks is None else ranks
        p = len(ranks)
        start = net.t[ranks].copy()
        t_all_in = float(np.max(start))
        dur = self.sample_duration(net, p, msize, warm)
        imb = net.rng.normal(0.0, self.rank_imbalance, size=p)
        # one randomly-chosen "late" rank pattern per call
        end = t_all_in + dur * np.maximum(0.25, 1.0 + imb)
        for i, r in enumerate(ranks):
            net.t[r] = end[i]
        return CollectiveExecution(start_true=start, end_true=end)

    def execute_batch(
        self,
        net: SimNet,
        msize: int,
        nrep: int,
        ranks: list[int] | None = None,
        warm: bool = True,
        min_start_true: np.ndarray | None = None,
    ) -> BatchExecution:
        """Run ``nrep`` consecutive collective calls in closed form.

        Semantically equivalent to ``nrep`` calls of :meth:`execute` (same
        synchronizing-collective entry rule), optionally with a per-call
        per-rank earliest start ``min_start_true`` of shape ``(nrep, p)``
        (the window scheme's deadlines in *true* time): rank ``r`` enters
        call ``i`` at ``max(min_start_true[i, r], end[i-1, r])``.

        The cross-call recurrence ``all_in_i = max(deadline_max_i,
        all_in_{i-1} + e_{i-1})`` (``e_i`` = duration times the slowest
        rank's imbalance factor) is solved with a prefix-sum +
        running-maximum identity, so no Python loop over ``nrep`` remains.
        """
        ranks = list(range(net.p)) if ranks is None else ranks
        p = len(ranks)
        if nrep <= 0:
            empty = np.empty((0, p))
            return BatchExecution(empty, empty.copy(), np.empty(0))
        dur = self.sample_durations(net, p, msize, nrep, warm)
        imb = net.rng.normal(0.0, self.rank_imbalance, size=(nrep, p))
        m = np.maximum(0.25, 1.0 + imb)
        span = dur[:, None] * m          # per-rank duration after all-in
        e = span.max(axis=1)             # slowest rank per call
        t0 = net.t[ranks].copy()
        if min_start_true is None:
            dmax = np.full(nrep, -np.inf)
        else:
            dmax = np.max(min_start_true, axis=1)
        # all_in_i = max(dmax_i, all_in_{i-1} + e_{i-1}) with
        # all_in_{-1} + e_{-1} := max(t0).  Unrolled:
        #   all_in_i = C_i + max(max_r t0_r, max_{j<=i} (dmax_j - C_j))
        # where C_i = sum_{k<i} e_k.
        C = np.concatenate(([0.0], np.cumsum(e[:-1])))
        all_in = C + np.maximum(
            float(np.max(t0)), np.maximum.accumulate(dmax - C))
        end = all_in[:, None] + span
        prev_end = np.vstack((t0[None, :], end[:-1]))
        if min_start_true is None:
            start = prev_end
        else:
            start = np.maximum(min_start_true, prev_end)
        net.t[ranks] = end[-1]
        return BatchExecution(start_true=start, end_true=end, durations=dur)


@dataclass
class SimCompositeOp(SimCollective):
    """A guideline mock-up: constituent collectives run back to back.

    ``terms`` holds ``(op, msize_scale, p_scale)`` triples. One call of the
    composite is every term executed in sequence inside one timed region —
    its common duration is the *sum* of the terms' sampled durations, each
    term at its own message size (``round(msize_scale * msize)``) and
    process count (``round(p_scale * p)``, the split-robustness mock-up
    ``p -> p/2 + p/2``). Entry/exit semantics (synchronizing-collective
    all-in rule, per-rank finish imbalance) are inherited unchanged from
    :class:`SimCollective`, so the composite runs through
    :func:`~repro.core.window.run_windowed`'s batch and scalar engines like
    any other op. Each constituent keeps its own AR(1) state and per-epoch
    bias, so the composite's noise structure is the sum of its parts'.
    """

    terms: tuple = ()   # tuple[(SimCollective, float msize_scale, float p_scale)]

    def __post_init__(self):
        if self.terms:
            # the slowest-rank exit spread of the sequence is dominated by
            # its most imbalanced constituent
            self.rank_imbalance = max(op.rank_imbalance
                                      for op, _, _ in self.terms)

    @staticmethod
    def _term_p(p: int, p_scale: float) -> int:
        return max(2, int(round(p_scale * p)))

    def base_time(self, p: int, msize: int) -> float:
        return sum(op.base_time(self._term_p(p, ps),
                                max(0, int(round(ms * msize))))
                   for op, ms, ps in self.terms)

    def sample_duration(self, net: SimNet, p: int, msize: int,
                        warm: bool = True) -> float:
        return float(sum(
            op.sample_duration(net, self._term_p(p, ps),
                               max(0, int(round(ms * msize))), warm)
            for op, ms, ps in self.terms))

    def sample_durations(self, net: SimNet, p: int, msize: int, nrep: int,
                         warm: bool = True) -> np.ndarray:
        if nrep <= 0:
            return np.empty(0)
        total = np.zeros(nrep)
        for op, ms, ps in self.terms:
            total += op.sample_durations(net, self._term_p(p, ps),
                                         max(0, int(round(ms * msize))),
                                         nrep, warm)
        return total


def make_composite_op(expr: str, per_op_kw: dict | None = None,
                      **overrides) -> SimCollective:
    """Build the simulated op for an op *expression* (see
    :mod:`repro.core.opexpr`).

    A plain name returns :func:`make_op` unchanged; anything composite (a
    ``+`` sequence, a ``*scale`` or ``@half`` modifier) returns a
    :class:`SimCompositeOp`. ``overrides`` apply to every constituent;
    ``per_op_kw`` maps constituent names to extra overrides (how a single
    deliberately mis-tuned collective is modeled). ``#impl`` tags are not
    meaningful in the simulator and are rejected.
    """
    from .opexpr import is_composite, parse_opexpr

    per_op_kw = per_op_kw or {}

    def _mk(name: str) -> SimCollective:
        kw = dict(overrides)
        kw.update(per_op_kw.get(name, {}))
        return make_op(name, **kw)

    terms = parse_opexpr(expr)
    for t in terms:
        if t.impl is not None:
            raise ValueError(
                f"opexpr {expr!r}: '#{t.impl}' implementation tags are not "
                "supported by the simulator backend")
    if not is_composite(expr):
        return _mk(terms[0].op)
    return SimCompositeOp(
        name=expr,
        terms=tuple((_mk(t.op), t.msize_scale,
                     0.5 if t.procs == "half" else 1.0) for t in terms),
    )


def make_op(name: str, **overrides) -> SimCollective:
    """Factory for the collectives studied in the paper."""
    presets = {
        # msize_factor approximates the algorithmic volume multiplier.
        "bcast":     dict(msize_factor=1.0, alpha=2.5e-6),
        "allreduce": dict(msize_factor=2.0, alpha=3.0e-6),
        "alltoall":  dict(msize_factor=4.0, alpha=4.0e-6, rank_imbalance=0.10),
        "scan":      dict(msize_factor=2.0, alpha=3.5e-6, tail_prob=0.12),
        "reduce":    dict(msize_factor=1.0, alpha=2.5e-6),
        "barrier":   dict(msize_factor=0.0, alpha=2.0e-6, gamma=1.0e-6),
    }
    kw = dict(presets.get(name, {}))
    kw.update(overrides)
    return SimCollective(name=name, **kw)


OP_LIBRARY = tuple(sorted(["bcast", "allreduce", "alltoall", "scan", "reduce", "barrier"]))
