"""Measuring *real* jitted JAX computations with the paper's method (§6).

This is the deployment path of the methodology: the object under test is a
compiled XLA executable (a collective, a ``train_step``, a ``serve_step``)
rather than the simulator's cost model. The same experimental design
applies:

  * a **launch epoch** = a fresh executable. ``epoch_isolation``:
      - ``"clear_caches"``: ``jax.clear_caches()`` + re-trace per epoch
        (in-process analogue of a fresh mpirun; captures compilation/layout
        nondeterminism),
      - ``"none"``: same executable reused (isolates pure run-time noise).
    On a real multi-host pod, epochs are separate launcher invocations and
    this module is driven once per process by ``launch/train.py``.
  * ``nrep`` timed calls per case, each fenced by ``block_until_ready``
    (the device-level "barrier"; host timestamps around a fenced dispatch
    are the §3.2.1 local-times scheme),
  * Tukey filtering + per-epoch averages downstream, via
    :mod:`repro.core.design`.
"""

from __future__ import annotations

import gc
import time
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

__all__ = ["timed_calls", "JaxEpochContext", "make_jax_measure", "MeterConfig"]


def timed_calls(fn: Callable[[], Any], nrep: int, warmup: int = 3) -> np.ndarray:
    """Time ``nrep`` calls of a nullary ``fn`` whose result supports
    ``block_until_ready`` (or is a pytree of such)."""
    import jax

    def _block(x):
        return jax.block_until_ready(x)

    for _ in range(warmup):
        _block(fn())
    out = np.empty(nrep)
    for i in range(nrep):
        t0 = time.perf_counter_ns()
        _block(fn())
        out[i] = (time.perf_counter_ns() - t0) * 1e-9
    return out


@dataclass
class MeterConfig:
    warmup: int = 3
    epoch_isolation: str = "clear_caches"   # or "none"
    cold_buffers: bool = False               # §5.8 cache factor: fresh inputs per call


class JaxEpochContext:
    """Per-epoch context: builds (and owns) freshly-jitted callables.

    Warm-up is paid once per callable per epoch: adaptive-``nrep`` stopping
    asks for a sample in growing chunks, and re-warming every chunk would
    both waste wall-clock and re-measure the §5.8 cold-cache factor the
    epoch already amortized.
    """

    def __init__(self, build: Callable[[int], dict[str, Callable[[], Any]]],
                 epoch: int, config: MeterConfig):
        self.epoch = epoch
        self.config = config
        if config.epoch_isolation == "clear_caches":
            import jax

            jax.clear_caches()
            gc.collect()
        self.callables = build(epoch)
        self._warmed: set[str] = set()

    def measure(self, name: str, nrep: int) -> np.ndarray:
        fn = self.callables[name]
        warmup = 0 if name in self._warmed else self.config.warmup
        self._warmed.add(name)
        return timed_calls(fn, nrep, warmup=warmup)


def make_jax_measure(build: Callable[[int], dict[str, Callable[[], Any]]],
                     config: MeterConfig | None = None):
    """Adapters for :func:`repro.core.design.run_design`.

    ``build(epoch)`` returns a dict mapping case names (``op@msize``) to
    nullary jitted callables. Returns ``(epoch_factory, measure)``.
    """
    cfg = config or MeterConfig()

    def epoch_factory(epoch: int) -> JaxEpochContext:
        return JaxEpochContext(build, epoch, cfg)

    def measure(ctx: JaxEpochContext, case, nrep: int) -> np.ndarray:
        name = f"{case.op}@{case.msize}"
        if name not in ctx.callables and case.op in ctx.callables:
            name = case.op
        return ctx.measure(name, nrep)

    return epoch_factory, measure
