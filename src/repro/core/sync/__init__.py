"""Clock-synchronization algorithms studied and introduced by the paper (§4)."""

from .base import (
    ClockSync,
    SyncResult,
    compute_rtt,
    probe_offsets,
    skampi_pingpong_adjusted,
    true_offsets,
)
from .hca import HCASync, learn_model_hca
from .jk import JKSync, collect_fitpoint, collect_fitpoints_batch
from .netgauge import NetgaugeSync, compute_offset_minrtt
from .skampi import SkampiSync

__all__ = [
    "ClockSync",
    "SyncResult",
    "compute_rtt",
    "probe_offsets",
    "skampi_pingpong_adjusted",
    "true_offsets",
    "HCASync",
    "JKSync",
    "NetgaugeSync",
    "SkampiSync",
    "learn_model_hca",
    "collect_fitpoint",
    "collect_fitpoints_batch",
    "compute_offset_minrtt",
    "ALGORITHMS",
    "make_sync",
]

ALGORITHMS = ("skampi", "netgauge", "jk", "hca", "hca2")


def make_sync(name: str, **kw) -> ClockSync:
    """Factory by paper name."""
    if name == "skampi":
        return SkampiSync(**kw)
    if name == "netgauge":
        return NetgaugeSync(**kw)
    if name == "jk":
        return JKSync(**kw)
    if name == "hca":
        return HCASync(hierarchical_intercepts=False, **kw)
    if name == "hca2":
        return HCASync(hierarchical_intercepts=True, **kw)
    raise ValueError(f"unknown sync algorithm {name!r}; known: {ALGORITHMS}")
