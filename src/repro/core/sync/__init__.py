"""Clock-synchronization algorithms studied and introduced by the paper (§4)."""

from .base import (
    ClockSync,
    SyncResult,
    compute_rtt,
    probe_offsets,
    skampi_pingpong_adjusted,
    true_offsets,
)
from .hca import HCASync, learn_model_hca
from .jk import JKSync, collect_fitpoint, collect_fitpoints_batch
from .netgauge import NetgaugeSync, compute_offset_minrtt
from .skampi import SkampiSync

__all__ = [
    "ClockSync",
    "SyncResult",
    "compute_rtt",
    "probe_offsets",
    "skampi_pingpong_adjusted",
    "true_offsets",
    "HCASync",
    "JKSync",
    "NetgaugeSync",
    "SkampiSync",
    "learn_model_hca",
    "collect_fitpoint",
    "collect_fitpoints_batch",
    "compute_offset_minrtt",
    "ALGORITHMS",
    "SYNC_CLASSES",
    "make_sync",
]

#: Paper name -> implementation class: the single authority for sync-name
#: resolution, shared by :func:`make_sync` and by callers that need to
#: introspect an algorithm's constructor (e.g. the campaign backends
#: filtering their ``sync_kw`` when a sweep swaps algorithms).
SYNC_CLASSES: dict[str, type] = {
    "skampi": SkampiSync,
    "netgauge": NetgaugeSync,
    "jk": JKSync,
    "hca": HCASync,
    "hca2": HCASync,
}

ALGORITHMS = tuple(SYNC_CLASSES)


def make_sync(name: str, **kw) -> ClockSync:
    """Factory by paper name."""
    cls = SYNC_CLASSES.get(name)
    if cls is None:
        raise ValueError(f"unknown sync algorithm {name!r}; "
                         f"known: {ALGORITHMS}")
    if cls is HCASync:
        # implied by the name; accepting an override would let 'hca' run
        # with hca2 semantics while every factor record still says 'hca'
        if "hierarchical_intercepts" in kw:
            raise TypeError(
                "make_sync: hierarchical_intercepts is implied by the "
                "algorithm name ('hca'/'hca2'); do not pass it")
        kw["hierarchical_intercepts"] = name == "hca2"
    return cls(**kw)
