"""Jones & Koenig clock synchronization (§4.3, Algorithms 15-17) [19].

Learns a *linear model of the clock drift* of every rank relative to the
root by linear regression over ``N_FITPTS`` fitpoints, each the median of
``N_EXCHANGES`` ping-pong offset measurements corrected by ``rtt/2``.

The fitpoint loops are interleaved across ranks exactly as in Alg. 15
(``for idx: for r: for i:``), which is what gives every rank's regression a
time base spanning the whole O(p * N_FITPTS * N_EXCHANGES * RTT)
synchronization phase — the source of JK's accuracy *and* of its cost
(Fig. 10: the most precise clocks, but ~30s to synchronize).
"""

from __future__ import annotations

import numpy as np

from ..clocks import LinearModel, linear_fit
from ..simnet import SimNet
from .base import ClockSync, SyncResult, compute_rtt

__all__ = ["JKSync", "collect_fitpoint"]


def collect_fitpoint(
    net: SimNet,
    client: int,
    ref: int,
    rtt: float,
    n_exchanges: int,
    init_client: float = 0.0,
    init_ref: float = 0.0,
) -> tuple[float, float]:
    """One fitpoint: median offset over ``n_exchanges`` ping-pongs
    (Alg. 15 lines 11-20 / Alg. 4 lines 10-19).

    Returns ``(xfit, yfit)`` where ``yfit`` is the median of
    ``local_time - tremote - rtt/2`` (client clock minus reference clock)
    and ``xfit`` the client local time at which that median was observed.
    """
    send, srv, recv = net.pingpong_batch(client, ref, n_exchanges)
    local_times = recv - init_client
    diffs = local_times - (srv - init_ref) - rtt / 2.0
    order = np.argsort(diffs)
    mid = order[len(order) // 2]  # the paper selects the element == median
    return float(local_times[mid]), float(diffs[mid])


class JKSync(ClockSync):
    name = "jk"

    def __init__(self, n_fitpts: int = 100, n_exchanges: int = 30):
        self.n_fitpts = n_fitpts
        self.n_exchanges = n_exchanges

    def synchronize(self, net: SimNet, ranks: list[int] | None = None) -> SyncResult:
        ranks = list(range(net.p)) if ranks is None else ranks
        root = ranks[0]
        others = [r for r in ranks if r != root]
        net.align(ranks)
        snap = net.elapsed_snapshot()
        msgs0 = net.msg_count

        # Alg. 15 lines 24-27: RTT of every pair first.
        rtts = {r: compute_rtt(net, root, r) for r in others}

        xs = {r: np.empty(self.n_fitpts) for r in others}
        ys = {r: np.empty(self.n_fitpts) for r in others}
        # Interleaved fitpoint collection (root serves ranks round-robin).
        for idx in range(self.n_fitpts):
            for r in others:
                x, y = collect_fitpoint(net, r, root, rtts[r], self.n_exchanges)
                xs[r][idx] = x
                ys[r][idx] = y

        models = [LinearModel(0.0, 0.0) for _ in range(net.p)]
        for r in others:
            models[r] = linear_fit(xs[r], ys[r])

        net.align(ranks)
        duration = net.max_elapsed_since(snap)
        return SyncResult(
            algorithm=self.name,
            models=models,
            initial_times=[0.0] * net.p,
            duration=duration,
            n_messages=net.msg_count - msgs0,
            params={"n_fitpts": self.n_fitpts, "n_exchanges": self.n_exchanges},
        )
