"""Jones & Koenig clock synchronization (§4.3, Algorithms 15-17) [19].

Learns a *linear model of the clock drift* of every rank relative to the
root by linear regression over ``N_FITPTS`` fitpoints, each the median of
``N_EXCHANGES`` ping-pong offset measurements corrected by ``rtt/2``.

The fitpoint loops are interleaved across ranks exactly as in Alg. 15
(``for idx: for r: for i:``), which is what gives every rank's regression a
time base spanning the whole O(p * N_FITPTS * N_EXCHANGES * RTT)
synchronization phase — the source of JK's accuracy *and* of its cost
(Fig. 10: the most precise clocks, but ~30s to synchronize).

Fitpoint collection is executed by a vectorized *sweep engine*
(:func:`collect_fitpoints_batch`): all ``nseg x n_exchanges`` network
latencies of a sweep are sampled up front and the ping-pong recurrence is
rolled forward with one scalar max per *segment* (the only place the
server's availability matters) plus closed-form within-segment cumulative
sums — semantically the same serialization through the root's timeline as
back-to-back :meth:`~repro.core.simnet.SimNet.pingpong_batch` calls, at a
fraction of the Python overhead.
"""

from __future__ import annotations

import numpy as np

from ..clocks import LinearModel, linear_fit
from ..simnet import SimNet
from .base import ClockSync, SyncResult, compute_rtt

__all__ = ["JKSync", "collect_fitpoint", "collect_fitpoints_batch"]


def collect_fitpoint(
    net: SimNet,
    client: int,
    ref: int,
    rtt: float,
    n_exchanges: int,
    init_client: float = 0.0,
    init_ref: float = 0.0,
) -> tuple[float, float]:
    """One fitpoint: median offset over ``n_exchanges`` ping-pongs
    (Alg. 15 lines 11-20 / Alg. 4 lines 10-19).

    Returns ``(xfit, yfit)`` where ``yfit`` is the median of
    ``local_time - tremote - rtt/2`` (client clock minus reference clock)
    and ``xfit`` the client local time at which that median was observed.
    """
    send, srv, recv = net.pingpong_batch(client, ref, n_exchanges)
    local_times = recv - init_client
    diffs = local_times - (srv - init_ref) - rtt / 2.0
    order = np.argsort(diffs)
    mid = order[len(order) // 2]  # the paper selects the element == median
    return float(local_times[mid]), float(diffs[mid])


def _fitpoint_sweep_true(
    net: SimNet,
    ref: int,
    clients_seq: np.ndarray,
    n_exchanges: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Roll a whole fitpoint sweep forward in true time.

    ``clients_seq`` holds one client rank per *segment*; each segment is
    ``n_exchanges`` ping-pongs between that client and ``ref``, executed
    back-to-back in the given order (the root serializes everything).
    Returns per-exchange true times ``(srv, recv)`` of shape
    ``(nseg, n_exchanges)`` and advances ``net.t``/``net.msg_count``.

    Equivalent to one :meth:`SimNet.pingpong_batch` call per segment; only
    the first exchange of a segment needs the ``max`` against the server's
    availability, so the cross-segment recurrence is a cheap scalar loop
    while everything within a segment is a cumulative sum.
    """
    nseg = len(clients_seq)
    nx = int(n_exchanges)
    oh = net.net.proc_overhead
    lat1 = net._latencies(nseg * nx).reshape(nseg, nx)
    lat2 = net._latencies(nseg * nx).reshape(nseg, nx)
    # Within a segment (srv_0 known):
    #   srv_j  = srv_0 + sum_{u<=j} (lat2_{u-1} + lat1_u + 3 oh)
    #   recv_j = srv_j + lat2_j + oh
    incr = np.zeros((nseg, nx))
    if nx > 1:
        incr[:, 1:] = lat2[:, :-1] + lat1[:, 1:] + 3.0 * oh
    srv_off = np.cumsum(incr, axis=1)            # srv_j - srv_0
    lat1_first = lat1[:, 0]
    seg_srv_last = srv_off[:, -1]                # srv_last - srv_0
    seg_recv_last = seg_srv_last + lat2[:, -1] + oh

    # Cross-segment recurrence in plain Python floats (numpy scalar access
    # inside the loop costs ~10x more than list indexing).
    srv0 = np.empty(nseg)
    t = net.t                                     # true-time program counters
    t_ref = float(t[ref])
    client_t: dict[int, float] = {}
    seq = clients_seq.tolist()
    l1f = lat1_first.tolist()
    ssl = seg_srv_last.tolist()
    srl = seg_recv_last.tolist()
    s0_list = srv0.tolist()
    for s in range(nseg):
        c = seq[s]
        r_c = client_t.get(c)
        if r_c is None:
            r_c = float(t[c])
        send0 = r_c + oh
        s0 = max(t_ref, send0 + l1f[s]) + oh
        s0_list[s] = s0
        t_ref = s0 + ssl[s]
        client_t[c] = s0 + srl[s]
    srv0 = np.asarray(s0_list)
    srv = srv0[:, None] + srv_off
    recv = srv + lat2 + oh
    t[ref] = t_ref
    for c, tc in client_t.items():
        t[c] = tc
    net.msg_count += 2 * nseg * nx
    return srv, recv


def collect_fitpoints_batch(
    net: SimNet,
    clients_seq,
    ref: int,
    rtts,
    n_fitpts_total: int,
    n_exchanges: int,
    initial_times: list[float] | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized fitpoint collection: ``n_fitpts_total`` fitpoints against
    ``ref``, one per entry of ``clients_seq`` (a single rank, or a sequence
    of length ``n_fitpts_total`` for interleaved multi-client sweeps).

    ``rtts`` is a scalar RTT or a dict ``{client: rtt}``. Returns arrays
    ``(xs, ys)`` of shape ``(n_fitpts_total,)`` with the same per-fitpoint
    median selection as :func:`collect_fitpoint`.
    """
    clients = np.asarray(
        [clients_seq] * n_fitpts_total if np.isscalar(clients_seq)
        else list(clients_seq), dtype=np.int64)
    if clients.size != n_fitpts_total:
        raise ValueError("clients_seq length must equal n_fitpts_total")
    srv_true, recv_true = _fitpoint_sweep_true(net, ref, clients, n_exchanges)

    # True -> raw local clocks (same affine map as pingpong_batch).
    srv_local = net.clocks[ref].read_affine(srv_true)
    recv_local = np.empty_like(recv_true)
    for c in np.unique(clients):
        sel = clients == c
        recv_local[sel] = net.clocks[c].read_affine(recv_true[sel])

    init_ref = initial_times[ref] if initial_times is not None else 0.0
    if initial_times is not None:
        init_cli = np.asarray(initial_times, dtype=np.float64)[clients][:, None]
    else:
        init_cli = 0.0
    if isinstance(rtts, dict):
        rtt_col = np.asarray([rtts[int(c)] for c in clients])[:, None]
    else:
        rtt_col = float(rtts)
    local_times = recv_local - init_cli
    diffs = local_times - (srv_local - init_ref) - rtt_col / 2.0
    mid = np.argsort(diffs, axis=1)[:, n_exchanges // 2]
    take = np.arange(len(clients))
    return local_times[take, mid], diffs[take, mid]


class JKSync(ClockSync):
    name = "jk"

    def __init__(self, n_fitpts: int = 100, n_exchanges: int = 30):
        self.n_fitpts = n_fitpts
        self.n_exchanges = n_exchanges

    def synchronize(self, net: SimNet, ranks: list[int] | None = None) -> SyncResult:
        ranks = list(range(net.p)) if ranks is None else ranks
        root = ranks[0]
        others = [r for r in ranks if r != root]
        net.align(ranks)
        snap = net.elapsed_snapshot()
        msgs0 = net.msg_count

        # Alg. 15 lines 24-27: RTT of every pair first.
        rtts = {r: compute_rtt(net, root, r) for r in others}

        # Interleaved fitpoint collection (root serves ranks round-robin,
        # `for idx: for r:` as in Alg. 15), executed as one vectorized sweep.
        if others:
            seq = np.tile(np.asarray(others, dtype=np.int64), self.n_fitpts)
            xs_all, ys_all = collect_fitpoints_batch(
                net, seq, root, rtts, seq.size, self.n_exchanges)
            xs_all = xs_all.reshape(self.n_fitpts, len(others))
            ys_all = ys_all.reshape(self.n_fitpts, len(others))

        models = [LinearModel(0.0, 0.0) for _ in range(net.p)]
        for j, r in enumerate(others):
            models[r] = linear_fit(xs_all[:, j], ys_all[:, j])

        net.align(ranks)
        duration = net.max_elapsed_since(snap)
        return SyncResult(
            algorithm=self.name,
            models=models,
            initial_times=[0.0] * net.p,
            duration=duration,
            n_messages=net.msg_count - msgs0,
            params={"n_fitpts": self.n_fitpts, "n_exchanges": self.n_exchanges},
        )
