"""Netgauge / NBCBench hierarchical clock synchronization (§4.1, Algs. 11-12).

Offset-only like SKaMPI, but O(log p) rounds: ranks synchronize pairwise in a
binary-tree pattern and the per-level offsets are *summed* along the tree
path. Scalable, but each level contributes its own measurement error, so the
offset error grows with the number of rounds (Fig. 8(b)) — one of the paper's
key observations, and the same error-accumulation mechanism that HCA inherits
for its slopes (where it is harmless, §4.4) and HCA2 for its intercepts
(where it is not, Fig. 9).
"""

from __future__ import annotations

import math

import numpy as np

from ..clocks import LinearModel
from ..simnet import SimNet
from .base import ClockSync, SyncResult

__all__ = ["NetgaugeSync", "compute_offset_minrtt"]


def compute_offset_minrtt(
    net: SimNet,
    client: int,
    server: int,
    window: int = 100,
    max_exchanges: int = 1000,
) -> float:
    """COMPUTE_OFFSET (Alg. 12): ping-pong until no new minimum RTT has been
    seen for ``window`` consecutive exchanges; the offset estimate
    ``clock_client - clock_server`` is taken from the minimum-RTT exchange
    (``diff = s_time + rtt/2 - tremote``).
    """
    best_rtt = np.inf
    best_diff = 0.0
    since_improve = 0
    done = 0
    while since_improve < window and done < max_exchanges:
        batch = min(window, max_exchanges - done)
        send, srv, recv = net.pingpong_batch(client, server, batch)
        rtt = recv - send
        diff = send + rtt / 2.0 - srv
        for j in range(batch):
            if rtt[j] < best_rtt:
                best_rtt = rtt[j]
                best_diff = diff[j]
                since_improve = 0
            else:
                since_improve += 1
        done += batch
    return float(best_diff)


class NetgaugeSync(ClockSync):
    name = "netgauge"

    def __init__(self, window: int = 100, max_exchanges: int = 300):
        self.window = window
        self.max_exchanges = max_exchanges

    def synchronize(self, net: SimNet, ranks: list[int] | None = None) -> SyncResult:
        ranks = list(range(net.p)) if ranks is None else ranks
        p = len(ranks)
        net.align(ranks)
        snap = net.elapsed_snapshot()
        msgs0 = net.msg_count

        maxpower = 2 ** int(math.floor(math.log2(p))) if p > 1 else 1
        # offset[r] below is the estimated clock offset of rank ``ranks[r]``
        # relative to the subtree reference it is currently attached to; the
        # tree combination sums the per-level estimates (Alg. 11 lines 9-10).
        offset_rel_ref: dict[int, float] = {0: 0.0}
        # subtree[i] = members (local indices) whose offsets are known
        # relative to i.
        subtree: dict[int, dict[int, float]] = {i: {i: 0.0} for i in range(p)}

        # SYNC_CLOCKS_POW2: log2(maxpower) rounds of concurrent pairs.
        rnd = 1
        while 2 ** rnd <= maxpower:
            half = 2 ** (rnd - 1)
            for ref in range(0, maxpower, 2 ** rnd):
                client = ref + half
                # offset of client vs ref (client initiates; Alg. 12).
                d = compute_offset_minrtt(
                    net, ranks[client], ranks[ref], self.window, self.max_exchanges
                )
                # Fold the client's subtree into the ref's, adding the level
                # offset (one model message up the tree).
                net.transfer(ranks[client], ranks[ref])
                for m, off in subtree[client].items():
                    subtree[ref][m] = d + off
            rnd += 1

        # SYNC_CLOCKS_REMAINING: ranks >= maxpower attach in one extra round.
        for j in range(p - maxpower):
            q = maxpower + j
            d = compute_offset_minrtt(
                net, ranks[q], ranks[j], self.window, self.max_exchanges
            )
            net.transfer(ranks[q], ranks[0])
            subtree[0][q] = subtree[0][j] + d

        net.align(ranks)
        duration = net.max_elapsed_since(snap)

        models = [LinearModel(0.0, 0.0) for _ in range(net.p)]
        for i, r in enumerate(ranks):
            models[r] = LinearModel(0.0, subtree[0].get(i, 0.0))
        return SyncResult(
            algorithm=self.name,
            models=models,
            initial_times=[0.0] * net.p,
            duration=duration,
            n_messages=net.msg_count - msgs0,
            params={"window": self.window, "max_exchanges": self.max_exchanges},
        )
