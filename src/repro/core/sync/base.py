"""Common interfaces for clock-synchronization algorithms (§4).

Every algorithm produces a :class:`SyncResult`: a per-rank
:class:`~repro.core.clocks.LinearModel` mapping *adjusted local time*
(raw local clock minus a per-rank ``initial_time`` epoch) to the root's
reference time, plus bookkeeping used by the evaluation experiments
(sync-phase duration for the Fig. 10 Pareto, message counts, parameters).

Offset-only algorithms (SKaMPI, Netgauge) return models with ``slope == 0``:
that is precisely the paper's point — without a drift slope, the global
clock error grows linearly in time (Figs. 6, 9, 20, 22).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..clocks import LinearModel
from ..simnet import SimNet

__all__ = [
    "SyncResult",
    "ClockSync",
    "compute_rtt",
    "skampi_pingpong_adjusted",
    "probe_offsets",
    "true_offsets",
]


@dataclass
class SyncResult:
    """Outcome of one clock-synchronization phase."""

    algorithm: str
    models: list[LinearModel]
    initial_times: list[float]
    duration: float            # true seconds, max over hosts (Fig. 10 x-axis)
    n_messages: int
    params: dict = field(default_factory=dict)

    def adjusted_local(self, r: int, raw_local: float) -> float:
        return raw_local - self.initial_times[r]

    def global_time(self, net: SimNet, r: int, raw_local: float | None = None) -> float:
        """Estimated reference ("global") time from rank ``r``'s clock."""
        if raw_local is None:
            raw_local = net.local_time(r)
        return self.models[r].normalize(raw_local - self.initial_times[r])

    def local_deadline(self, r: int, global_target: float) -> float:
        """Raw local clock value at which rank ``r`` believes the global
        clock reads ``global_target`` (used by the window-based scheme)."""
        return self.models[r].denormalize(global_target) + self.initial_times[r]


class ClockSync:
    """Base class; subclasses implement :meth:`synchronize`."""

    name: str = "abstract"

    def synchronize(self, net: SimNet, ranks: list[int] | None = None) -> SyncResult:
        raise NotImplementedError


# --------------------------------------------------------------------------
# Shared measurement primitives (Algorithms 7 and 17)
# --------------------------------------------------------------------------

def compute_rtt(net: SimNet, p1: int, p2: int, n_pingpongs: int = 100,
                warmup: int = 10) -> float:
    """COMPUTE_RTT (Alg. 17): mean RTT after Tukey outlier removal.

    ``p2`` is the client measuring the RTT to ``p1`` (matching the paper's
    argument order where ``p1`` holds the reference clock).
    """
    from ..stats import tukey_filter  # local import to avoid cycle

    if warmup:
        net.pingpong_batch(p2, p1, warmup)
    send, _, recv = net.pingpong_batch(p2, p1, n_pingpongs)
    rtt = recv - send
    kept = tukey_filter(rtt)
    return float(np.mean(kept)) if kept.size else float(np.mean(rtt))


def skampi_pingpong_adjusted(
    net: SimNet,
    p1: int,
    p2: int,
    initial_times: list[float] | None = None,
    n_pingpongs: int = 100,
) -> float:
    """SKAMPI_PINGPONG (Alg. 7): returns the estimated clock offset
    ``clock_p2 - clock_p1`` (on adjusted clocks when ``initial_times`` given).

    Uses the min/max window technique: every exchange yields a lower bound
    ``t_server - t_recv_client`` and an upper bound ``t_server - t_send_client``
    on the offset; the estimate is the midpoint of the tightest bounds.
    """
    i1 = i2 = 0.0
    if initial_times is not None:
        i1, i2 = initial_times[p1], initial_times[p2]
    send, srv, recv = net.pingpong_batch(p1, p2, n_pingpongs)
    send = send - i1
    recv = recv - i1
    srv = srv - i2
    td_min = float(np.max(srv - recv))   # lower bound on clock_p2 - clock_p1
    td_max = float(np.min(srv - send))   # upper bound
    return 0.5 * (td_min + td_max)


# --------------------------------------------------------------------------
# Post-sync evaluation probes (§4.5, Figs. 8-9; Appendix Alg. 20)
# --------------------------------------------------------------------------

def probe_offsets(net: SimNet, result: SyncResult, n_rounds: int = 10,
                  root: int = 0) -> np.ndarray:
    """Paper-faithful measurement of the global-clock offset of every rank
    vs. the root *through the network* (Alg. 20): root exchanges ping-pongs
    with each rank, ranks report their estimated global time, and the probe
    with the smallest magnitude over ``n_rounds`` is kept (the paper's
    ``min over j`` of ``diff``). Returns an array of length p (root slot 0).
    """
    p = net.p
    out = np.zeros(p)
    for r in range(p):
        if r == root:
            continue
        best = np.inf
        send, srv, recv = net.pingpong_batch(root, r, n_rounds)
        for j in range(n_rounds):
            g_client = result.global_time(net, r, srv[j])
            g_root_mid = 0.5 * (
                result.global_time(net, root, send[j])
                + result.global_time(net, root, recv[j])
            )
            d = g_client - g_root_mid
            if abs(d) < abs(best):
                best = d
        out[r] = best
    return out


def true_offsets(net: SimNet, result: SyncResult, root: int = 0) -> np.ndarray:
    """Simulator ground truth: disagreement of the estimated global clocks
    at one common true instant. Zero for a perfect synchronization."""
    p = net.p
    t_now = float(np.max(net.t))
    g = np.array([
        result.models[r].normalize(net.clocks[r].read(t_now) - result.initial_times[r])
        for r in range(p)
    ])
    return g - g[root]
