"""HCA clock synchronization — the paper's contribution (§4.4, Algs. 2-4).

HCA (Hunold / Carpen-Amarie) combines:

  * the *linear drift model* of Jones & Koenig (slope + intercept learned
    from ping-pong fitpoints), so the global clock stays accurate over long
    measurement horizons, with
  * the *hierarchical* O(log p) pair structure of Netgauge, so the
    synchronization phase scales,
  * transitive merging of linear models (MERGE_LMS, the exact composition —
    see :meth:`repro.core.clocks.LinearModel.merge`),
  * intercept re-anchoring with the SKaMPI ping-pong offset (the regression
    intercept has a ~100 ms-wide confidence interval, §4.4, so it is
    discarded and recomputed from a direct offset measurement).

Two variants, as in the paper:

  * ``HCA``  (first approach): slopes hierarchically in O(log p) rounds,
    intercepts linearly — root re-anchors every rank in O(p) rounds.
  * ``HCA2`` (second approach, ``hierarchical_intercepts=True``): intercepts
    are re-anchored per-pair and *merged* hierarchically in O(log p) rounds;
    faster, but the intercept error now accumulates along the tree (Fig. 9
    shows HCA2 offsets larger than HCA at p = 512).
"""

from __future__ import annotations

import math


from ..clocks import LinearModel, linear_fit
from ..simnet import SimNet
from .base import ClockSync, SyncResult, compute_rtt, skampi_pingpong_adjusted
from .jk import collect_fitpoints_batch

__all__ = ["HCASync", "learn_model_hca"]


def learn_model_hca(
    net: SimNet,
    ref: int,
    client: int,
    rtt: float,
    n_fitpts: int,
    n_exchanges: int,
    initial_times: list[float],
) -> LinearModel:
    """LEARN_MODEL_HCA (Alg. 4): drift model of ``client`` relative to
    ``ref`` on *adjusted* clocks, via linear regression over fitpoints.

    The ``n_fitpts x n_exchanges`` ping-pong sweep runs through the
    vectorized engine (:func:`repro.core.sync.jk.collect_fitpoints_batch`)
    in one shot — the pair's fitpoints are back-to-back in Alg. 4, so the
    merged sweep has the same timeline as per-fitpoint round-trips."""
    xs, ys = collect_fitpoints_batch(
        net, client, ref, rtt, n_fitpts, n_exchanges,
        initial_times=initial_times,
    )
    return linear_fit(xs, ys)


class HCASync(ClockSync):
    name = "hca"

    def __init__(
        self,
        n_fitpts: int = 100,
        n_exchanges: int = 10,
        hierarchical_intercepts: bool = False,
        intercept_pingpongs: int = 100,
    ):
        self.n_fitpts = n_fitpts
        self.n_exchanges = n_exchanges
        self.hierarchical_intercepts = hierarchical_intercepts
        self.intercept_pingpongs = intercept_pingpongs
        if hierarchical_intercepts:
            self.name = "hca2"

    # -- helpers ------------------------------------------------------------
    def _set_intercept(
        self,
        net: SimNet,
        lm: LinearModel,
        client: int,
        ref: int,
        initial_times: list[float],
    ) -> LinearModel:
        """COMPUTE_AND_SET_INTERCEPT (Alg. 4 lines 22-28): re-anchor the
        intercept from a SKaMPI offset measured at a known adjusted time."""
        diff = skampi_pingpong_adjusted(
            net, ref, client, initial_times, self.intercept_pingpongs
        )
        diff_timestamp = net.local_time(client) - initial_times[client]
        return lm.with_intercept_from_offset(diff, diff_timestamp)

    # -- main ---------------------------------------------------------------
    def synchronize(self, net: SimNet, ranks: list[int] | None = None) -> SyncResult:
        ranks = list(range(net.p)) if ranks is None else ranks
        p = len(ranks)
        root = ranks[0]
        net.align(ranks)
        snap = net.elapsed_snapshot()
        msgs0 = net.msg_count

        # Alg. 2/3 line 1: logical local clocks start at zero.
        initial_times = [0.0] * net.p
        for r in ranks:
            initial_times[r] = net.local_time(r)

        maxpower = 2 ** int(math.floor(math.log2(p))) if p > 1 else 1

        # subtree[i]: models of members (local indices) relative to local
        # index i, built bottom-up; mirrors the l_model tables of Alg. 3.
        subtree: dict[int, dict[int, LinearModel]] = {
            i: {i: LinearModel(0.0, 0.0)} for i in range(p)
        }

        # ---- SYNC_CLOCKS_POW2: hierarchical slope (and HCA2: intercept) ----
        rnd = 1
        while 2 ** rnd <= maxpower:
            half = 2 ** (rnd - 1)
            for ref_i in range(0, maxpower, 2 ** rnd):
                cli_i = ref_i + half
                ref_r, cli_r = ranks[ref_i], ranks[cli_i]
                rtt = compute_rtt(net, ref_r, cli_r)
                lm = learn_model_hca(
                    net, ref_r, cli_r, rtt,
                    self.n_fitpts, self.n_exchanges, initial_times,
                )
                if self.hierarchical_intercepts:
                    lm = self._set_intercept(net, lm, cli_r, ref_r, initial_times)
                # Client ships its model table one level up (one message).
                net.transfer(cli_r, ref_r)
                for m, sub_lm in subtree[cli_i].items():
                    subtree[ref_i][m] = LinearModel.merge(lm, sub_lm)
            rnd += 1

        # ---- SYNC_CLOCKS_REMAINING: non-power-of-two ranks, one round ------
        for j in range(p - maxpower):
            q_i = maxpower + j
            ref_i = j
            q_r, ref_r = ranks[q_i], ranks[ref_i]
            rtt = compute_rtt(net, ref_r, q_r)
            lm = learn_model_hca(
                net, ref_r, q_r, rtt, self.n_fitpts, self.n_exchanges, initial_times
            )
            if self.hierarchical_intercepts:
                lm = self._set_intercept(net, lm, q_r, ref_r, initial_times)
            net.transfer(q_r, ranks[0])  # gather on root (sub-communicator)
            subtree[0][q_i] = LinearModel.merge(subtree[0][ref_i], lm)

        # ---- models now live on root; scatter (Alg. 2 line 5) --------------
        models = [LinearModel(0.0, 0.0) for _ in range(net.p)]
        for i, r in enumerate(ranks):
            models[r] = subtree[0].get(i, LinearModel(0.0, 0.0))

        # ---- first approach: linear intercept re-anchoring (O(p)) ----------
        if not self.hierarchical_intercepts:
            for i, r in enumerate(ranks):
                if r == root:
                    continue
                models[r] = self._set_intercept(
                    net, models[r], r, root, initial_times
                )

        net.align(ranks)  # MPI_BARRIER of Alg. 2 line 7
        duration = net.max_elapsed_since(snap)
        return SyncResult(
            algorithm=self.name,
            models=models,
            initial_times=initial_times,
            duration=duration,
            n_messages=net.msg_count - msgs0,
            params={
                "n_fitpts": self.n_fitpts,
                "n_exchanges": self.n_exchanges,
                "hierarchical_intercepts": self.hierarchical_intercepts,
            },
        )
