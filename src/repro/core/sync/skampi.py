"""SKaMPI clock synchronization (§4.1, Algorithms 7-8).

Offset-only, O(p) rounds: the root measures its clock offset to every other
rank with the ping-pong min/max-window technique (Cristian-style [18]) and
the offsets define a logical global clock. Very accurate immediately after
synchronization (Fig. 8) but drifts because no slope is learned (Fig. 9).
"""

from __future__ import annotations


from ..clocks import LinearModel
from ..simnet import SimNet
from .base import ClockSync, SyncResult, skampi_pingpong_adjusted

__all__ = ["SkampiSync"]


class SkampiSync(ClockSync):
    name = "skampi"

    def __init__(self, n_pingpongs: int = 100):
        self.n_pingpongs = n_pingpongs

    def synchronize(self, net: SimNet, ranks: list[int] | None = None) -> SyncResult:
        ranks = list(range(net.p)) if ranks is None else ranks
        root = ranks[0]
        net.align(ranks)
        snap = net.elapsed_snapshot()
        msgs0 = net.msg_count

        models = {r: LinearModel(0.0, 0.0) for r in ranks}
        # COMPUTE_AND_SET_CLOCK_OFFSETS (Alg. 8): root pairs with each rank
        # in turn. (The per-pair MPI_Barrier of Alg. 8 line 5 is modeled by
        # the serialization of the pairs on the root's timeline.)
        for r in ranks:
            if r == root:
                continue
            diff = skampi_pingpong_adjusted(net, root, r, None, self.n_pingpongs)
            # diff ~= clock_r - clock_root  =>  normalize: local_r - diff.
            models[r] = LinearModel(0.0, diff)

        net.align(ranks)
        duration = net.max_elapsed_since(snap)
        p = net.p
        full = [models.get(r, LinearModel(0.0, 0.0)) for r in range(p)]
        return SyncResult(
            algorithm=self.name,
            models=full,
            initial_times=[0.0] * p,
            duration=duration,
            n_messages=net.msg_count - msgs0,
            params={"n_pingpongs": self.n_pingpongs},
        )
