"""Window-based process synchronization (§3.3, scheme (4) of Fig. 1).

Processes agree on a logical-global-clock *start time*; measurement ``i``
begins at ``start_time + i * win_size``. Each rank converts the global
deadline to its own local clock through its drift model (the inverse of
GET_NORMALIZED_TIME) and busy-waits. Two error flags per measurement,
exactly as SKaMPI/NBCBench record them (Algs. 9/13):

  * ``START_LATE``    — the rank reached the sync point after the window
    opened (its global-clock estimate was behind),
  * ``TOOK_TOO_LONG`` — the operation did not finish within the window.

Measurements with either flag set on any rank are *invalid* and discarded
(Figs. 21-22 study the trade-off between window size and the fraction of
discarded measurements).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .mpi_ops import SimCollective
from .simnet import SimNet
from .sync.base import SyncResult

__all__ = ["WindowRun", "run_windowed"]

START_LATE = 1
TOOK_TOO_LONG = 2


@dataclass
class WindowRun:
    """Raw output of a window-synchronized measurement campaign."""

    times: np.ndarray          # global-clock run-times, shape (nrep,)
    errors: np.ndarray         # per-obs error bitmask (max over ranks)
    start_global_est: np.ndarray  # (nrep, p) estimated-global start stamps
    end_global_est: np.ndarray    # (nrep, p)
    start_true: np.ndarray     # (nrep, p) simulator ground truth
    end_true: np.ndarray       # (nrep, p)

    @property
    def valid(self) -> np.ndarray:
        return self.errors == 0

    @property
    def valid_times(self) -> np.ndarray:
        return self.times[self.valid]

    @property
    def invalid_fraction(self) -> float:
        return float(np.mean(~self.valid)) if self.times.size else 0.0


def run_windowed(
    net: SimNet,
    sync: SyncResult,
    op: SimCollective,
    msize: int,
    nrep: int,
    win_size: float,
    ranks: list[int] | None = None,
) -> WindowRun:
    """Measure ``nrep`` calls of ``op`` under window-based synchronization.

    Completion time per observation follows §3.2.2 (global times):
    ``max_r global(end_r) - min_r global(start_r)``.
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    p = len(ranks)

    # Root picks a start time in the (global-clock) future and broadcasts it
    # (Alg. 2 line 8). Give every rank a slack window to reach the loop.
    g_now = max(sync.global_time(net, r) for r in ranks)
    start_time = g_now + win_size

    times = np.empty(nrep)
    errors = np.zeros(nrep, dtype=np.int64)
    sg = np.empty((nrep, p))
    eg = np.empty((nrep, p))
    st = np.empty((nrep, p))
    et = np.empty((nrep, p))

    for obs in range(nrep):
        target = start_time + obs * win_size
        err = 0
        for i, r in enumerate(ranks):
            deadline_local = sync.local_deadline(r, target)
            on_time = net.wait_until_local(r, deadline_local)
            if not on_time:
                err |= START_LATE
        ex = op.execute(net, msize, ranks)
        st[obs] = ex.start_true
        et[obs] = ex.end_true
        for i, r in enumerate(ranks):
            sg[obs, i] = sync.global_time(
                net, r, net.clocks[r].read(ex.start_true[i]))
            eg[obs, i] = sync.global_time(
                net, r, net.clocks[r].read(ex.end_true[i]))
            if eg[obs, i] > target + win_size:
                err |= TOOK_TOO_LONG
        times[obs] = float(np.max(eg[obs]) - np.min(sg[obs]))
        errors[obs] = err

    return WindowRun(
        times=times, errors=errors,
        start_global_est=sg, end_global_est=eg,
        start_true=st, end_true=et,
    )
