"""Window-based process synchronization (§3.3, scheme (4) of Fig. 1).

Processes agree on a logical-global-clock *start time*; measurement ``i``
begins at ``start_time + i * win_size``. Each rank converts the global
deadline to its own local clock through its drift model (the inverse of
GET_NORMALIZED_TIME) and busy-waits. Two error flags per measurement,
exactly as SKaMPI/NBCBench record them (Algs. 9/13):

  * ``START_LATE``    — the rank reached the sync point after the window
    opened (its global-clock estimate was behind),
  * ``TOOK_TOO_LONG`` — the operation did not finish within the window.

Measurements with either flag set on any rank are *invalid* and discarded
(Figs. 21-22 study the trade-off between window size and the fraction of
discarded measurements).

Four engines compute the same campaign:

  * ``engine="scalar"`` — the semantic reference: a per-observation,
    per-rank Python loop of busy-waits and scalar clock reads;
  * ``engine="batch"`` — both the hardware clock
    (:class:`~repro.core.clocks.SimClock` with ``rw_sigma == 0``) and the
    learned sync model are affine, so every local↔global conversion —
    deadlines, START_LATE and TOOK_TOO_LONG flags, global start/end
    estimates — is evaluated in closed form over all ``nrep`` windows at
    once, on top of
    :meth:`~repro.core.mpi_ops.SimCollective.execute_batch`;
  * ``engine="batch_rw"`` — the same vectorized scheduling for
    *random-walk* clocks: the walk is pre-sampled on a window-spaced grid
    (:class:`~repro.core.clocks.DriftPath`), which makes the local clock a
    monotone piecewise-affine map of true time, so the deadline inversion
    becomes a batched binary search over path nodes plus an in-segment
    affine solve;
  * ``engine="jax"`` — the accelerator-resident port
    (:mod:`repro.simjax`): duration sampling and the cross-call entry
    recurrence jit-compiled over the whole ``(nrep, p)`` grid. Affine
    clocks only; raises :class:`~repro.simjax.SimJaxUnavailable`
    otherwise (callers that want a soft fallback use
    :func:`resolve_engine`).

``engine="auto"`` picks ``batch`` for drift-affine clocks and
``batch_rw`` for random-walk clocks — every stock clock model runs a
vectorized path (the historic silent scalar fallback is retired). The
engines are bit-identical given identical noise samples and statistically
indistinguishable under a live RNG (``tests/test_batch_equivalence.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .mpi_ops import SimCollective
from .simnet import SimNet
from .sync.base import SyncResult

__all__ = ["WindowRun", "resolve_engine", "run_windowed",
           "run_windowed_rw_batch", "run_windowed_scalar"]

ENGINES = ("auto", "batch", "batch_rw", "scalar", "jax")

START_LATE = 1
TOOK_TOO_LONG = 2


@dataclass
class WindowRun:
    """Raw output of a window-synchronized measurement campaign."""

    times: np.ndarray          # global-clock run-times, shape (nrep,)
    errors: np.ndarray         # per-obs error bitmask (max over ranks)
    start_global_est: np.ndarray  # (nrep, p) estimated-global start stamps
    end_global_est: np.ndarray    # (nrep, p)
    start_true: np.ndarray     # (nrep, p) simulator ground truth
    end_true: np.ndarray       # (nrep, p)

    @property
    def valid(self) -> np.ndarray:
        return self.errors == 0

    @property
    def valid_times(self) -> np.ndarray:
        return self.times[self.valid]

    @property
    def invalid_fraction(self) -> float:
        return float(np.mean(~self.valid)) if self.times.size else 0.0

    @classmethod
    def concat(cls, runs: "list[WindowRun]") -> "WindowRun":
        """Merge consecutive chunks over the same ``(net, sync, op)`` into
        one campaign — the accumulation step of adaptive-``nrep``
        measurement and of valid-sample top-up after window discards."""
        runs = list(runs)
        if not runs:
            raise ValueError("WindowRun.concat: empty run list")
        if len(runs) == 1:
            return runs[0]
        return cls(
            times=np.concatenate([r.times for r in runs]),
            errors=np.concatenate([r.errors for r in runs]),
            start_global_est=np.vstack([r.start_global_est for r in runs]),
            end_global_est=np.vstack([r.end_global_est for r in runs]),
            start_true=np.vstack([r.start_true for r in runs]),
            end_true=np.vstack([r.end_true for r in runs]),
        )


def _clocks_affine(net: SimNet, ranks: list[int]) -> bool:
    """True when every participating clock is a pure affine map of true
    time (no random-walk state), so deadline conversion has a closed form."""
    return all(net.clocks[r].rw_sigma <= 0.0 for r in ranks)


def resolve_engine(engine: str, net: SimNet,
                   ranks: list[int] | None = None) -> tuple[str, str | None]:
    """Map a requested engine to the one that will actually run.

    Returns ``(resolved, fallback_note)``; ``fallback_note`` is ``None``
    unless the request cannot be honored and a slower-but-equivalent engine
    is substituted (``jax`` on random-walk clocks or without an importable
    jax). ``run_windowed`` itself never falls back silently — callers that
    want the soft behavior (``SimBackend``) resolve here first, record the
    resolved engine in each record's meta, and warn once per campaign.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; use "
                         + "|".join(ENGINES))
    ranks = list(range(net.p)) if ranks is None else ranks
    affine = _clocks_affine(net, ranks)
    if engine == "auto":
        return ("batch" if affine else "batch_rw"), None
    if engine == "jax":
        if not affine:
            return "batch_rw", ("engine='jax' supports affine clocks only; "
                                "resolved to 'batch_rw'")
        from repro.simjax import have_jax
        if not have_jax():
            return "batch", "jax is not importable; resolved to 'batch'"
    return engine, None


def run_windowed(
    net: SimNet,
    sync: SyncResult,
    op: SimCollective,
    msize: int,
    nrep: int,
    win_size: float,
    ranks: list[int] | None = None,
    engine: str = "auto",
) -> WindowRun:
    """Measure ``nrep`` calls of ``op`` under window-based synchronization.

    Completion time per observation follows §3.2.2 (global times):
    ``max_r global(end_r) - min_r global(start_r)``.

    ``engine`` is ``"auto"`` (``batch`` for affine clocks, ``batch_rw``
    for random-walk clocks), ``"batch"``, ``"batch_rw"``, ``"jax"`` or
    ``"scalar"``. Explicit engines are strict: ``batch`` and ``jax``
    raise on random-walk clocks rather than silently degrading.
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    if engine == "auto":
        engine = "batch" if _clocks_affine(net, ranks) else "batch_rw"
    elif engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; use "
                         + "|".join(ENGINES))
    if engine == "scalar":
        return run_windowed_scalar(net, sync, op, msize, nrep, win_size, ranks)
    if engine == "batch_rw":
        return run_windowed_rw_batch(net, sync, op, msize, nrep, win_size,
                                     ranks)
    if engine == "jax":
        from repro.simjax import run_windowed_jax
        return run_windowed_jax(net, sync, op, msize, nrep, win_size, ranks)
    if not _clocks_affine(net, ranks):
        raise ValueError(
            "engine='batch' requires affine clocks (rw_sigma == 0); "
            "use engine='batch_rw' (or 'auto') for random-walk clocks")
    p = len(ranks)

    # Root picks a start time in the (global-clock) future and broadcasts it
    # (Alg. 2 line 8). Give every rank a slack window to reach the loop.
    g_now = max(sync.global_time(net, r) for r in ranks)
    start_time = g_now + win_size
    targets = start_time + win_size * np.arange(nrep)

    # Closed-form local<->global conversion: the window deadline in *true*
    # time is affine in the global target, composed from the sync model's
    # denormalize and the (affine) clock inverse.
    deadline_true = np.empty((nrep, p))
    for i, r in enumerate(ranks):
        deadline_local = sync.models[r].denormalize(targets) + sync.initial_times[r]
        deadline_true[:, i] = net.true_time_at_local(r, deadline_local)

    t0 = net.t[ranks].copy()
    ex = op.execute_batch(net, msize, nrep, ranks,
                          min_start_true=deadline_true)
    prev_end = np.vstack((t0[None, :], ex.end_true[:-1]))
    # wait_until_local() reports START_LATE when the deadline is <= the
    # rank's current time (i.e. <= its previous finish).
    late = deadline_true <= prev_end

    sg = np.empty((nrep, p))
    eg = np.empty((nrep, p))
    for i, r in enumerate(ranks):
        clk, init = net.clocks[r], sync.initial_times[r]
        model = sync.models[r]
        sg[:, i] = model.normalize(clk.read(ex.start_true[:, i]) - init)
        eg[:, i] = model.normalize(clk.read(ex.end_true[:, i]) - init)
    took = eg > (targets + win_size)[:, None]

    errors = np.zeros(nrep, dtype=np.int64)
    errors[late.any(axis=1)] |= START_LATE
    errors[took.any(axis=1)] |= TOOK_TOO_LONG
    times = eg.max(axis=1) - sg.min(axis=1)

    return WindowRun(
        times=times, errors=errors,
        start_global_est=sg, end_global_est=eg,
        start_true=ex.start_true, end_true=ex.end_true,
    )


def run_windowed_rw_batch(
    net: SimNet,
    sync: SyncResult,
    op: SimCollective,
    msize: int,
    nrep: int,
    win_size: float,
    ranks: list[int] | None = None,
) -> WindowRun:
    """Vectorized windowed engine for random-walk clocks.

    The only thing separating a random-walk clock from an affine one is
    that local↔global conversion has no single closed form. But once the
    walk is pre-sampled on a fixed grid
    (:meth:`~repro.core.clocks.SimClock.drift_path`), the local clock is a
    *monotone piecewise-affine* map of true time: deadline inversion is a
    batched binary search over the path nodes plus an in-segment affine
    solve, and forward reads are vectorized interpolation. Everything else
    — the cross-call entry recurrence, flags, global estimates — is the
    affine batch engine unchanged.

    Activating the path changes how the walk's future is sampled
    (grid nodes + linear interpolation instead of an increment per read):
    statistically equivalent to the lazy walk, and *bit-identical* to the
    scalar engine run against the same frozen paths
    (``SimNet.freeze_drift_paths``; see ``tests/test_batch_equivalence.py``).
    Also valid for affine clocks, where the path is identically zero.
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    p = len(ranks)
    # Pre-sample every participating walk on a window-spaced grid *before*
    # the first clock read, so all conversions below — and any later scalar
    # read of the same net — interpolate the same path.
    for r in ranks:
        net.clocks[r].drift_path(win_size)

    g_now = max(sync.global_time(net, r) for r in ranks)
    start_time = g_now + win_size
    targets = start_time + win_size * np.arange(nrep)

    deadline_true = np.empty((nrep, p))
    for i, r in enumerate(ranks):
        deadline_local = sync.models[r].denormalize(targets) + sync.initial_times[r]
        deadline_true[:, i] = net.clocks[r].true_at_local(deadline_local)

    t0 = net.t[ranks].copy()
    ex = op.execute_batch(net, msize, nrep, ranks,
                          min_start_true=deadline_true)
    prev_end = np.vstack((t0[None, :], ex.end_true[:-1]))
    late = deadline_true <= prev_end

    sg = np.empty((nrep, p))
    eg = np.empty((nrep, p))
    for i, r in enumerate(ranks):
        clk, init = net.clocks[r], sync.initial_times[r]
        model = sync.models[r]
        sg[:, i] = model.normalize(clk.read(ex.start_true[:, i]) - init)
        eg[:, i] = model.normalize(clk.read(ex.end_true[:, i]) - init)
    took = eg > (targets + win_size)[:, None]

    errors = np.zeros(nrep, dtype=np.int64)
    errors[late.any(axis=1)] |= START_LATE
    errors[took.any(axis=1)] |= TOOK_TOO_LONG
    times = eg.max(axis=1) - sg.min(axis=1)

    return WindowRun(
        times=times, errors=errors,
        start_global_est=sg, end_global_est=eg,
        start_true=ex.start_true, end_true=ex.end_true,
    )


def run_windowed_scalar(
    net: SimNet,
    sync: SyncResult,
    op: SimCollective,
    msize: int,
    nrep: int,
    win_size: float,
    ranks: list[int] | None = None,
) -> WindowRun:
    """Scalar semantic reference for :func:`run_windowed`.

    One busy-wait and one clock read per (observation, rank) — exactly the
    per-measurement control flow of Alg. 9/13. Kept verbatim so the batch
    engine has an executable specification to be verified against, and as
    the only valid engine for non-affine (random-walk) clocks.
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    p = len(ranks)

    g_now = max(sync.global_time(net, r) for r in ranks)
    start_time = g_now + win_size

    times = np.empty(nrep)
    errors = np.zeros(nrep, dtype=np.int64)
    sg = np.empty((nrep, p))
    eg = np.empty((nrep, p))
    st = np.empty((nrep, p))
    et = np.empty((nrep, p))

    for obs in range(nrep):
        target = start_time + obs * win_size
        err = 0
        for i, r in enumerate(ranks):
            deadline_local = sync.local_deadline(r, target)
            on_time = net.wait_until_local(r, deadline_local)
            if not on_time:
                err |= START_LATE
        ex = op.execute(net, msize, ranks)
        st[obs] = ex.start_true
        et[obs] = ex.end_true
        for i, r in enumerate(ranks):
            sg[obs, i] = sync.global_time(
                net, r, net.clocks[r].read(ex.start_true[i]))
            eg[obs, i] = sync.global_time(
                net, r, net.clocks[r].read(ex.end_true[i]))
            if eg[obs, i] > target + win_size:
                err |= TOOK_TOO_LONG
        times[obs] = float(np.max(eg[obs]) - np.min(sg[obs]))
        errors[obs] = err

    return WindowRun(
        times=times, errors=errors,
        start_global_est=sg, end_global_est=eg,
        start_true=st, end_true=et,
    )
