"""Store federation: many per-worker shards, one idempotent merge.

One process owning one JSONL file is the store's single-writer
invariant; a fleet keeps it by giving every worker *attempt* its own
shard and making the parent the only writer of the authoritative store.
:func:`merge_stores` is the compaction step: append everything the
destination does not already hold, skip (and count) everything it does.
Records are identified by ``(fingerprint, op, msize, epoch)`` and
campaign declarations by ``(fingerprint, spec)`` — the same identities
the resume path uses — so merging is idempotent: replaying a merge, or
merging a shard that a crashed previous merge half-applied, is a no-op
for the lines that already landed. Corrupt shard lines (torn writes from
killed workers) are skipped by the store loader and surface in
:class:`MergeStats.n_corrupt` instead of poisoning the merge.

The same function federates whole *sweep* stores across hosts: sweep
manifests and completion markers are content-addressed (the sweep id is
a hash of the manifest), so two hosts that measured disjoint cells of
the same grid merge into one resumable sweep.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path

from repro.campaign.store import ResultStore, StoreSnapshot

__all__ = ["MergeStats", "merge_stores"]


@dataclass
class MergeStats:
    """What one merge actually did — and what it refused to double-apply."""

    n_campaigns: int = 0          # campaign declarations appended
    n_records: int = 0            # measurement records appended
    n_duplicates: int = 0         # records already present (idempotent skips)
    n_sweep_lines: int = 0        # sweep manifests + cell markers appended
    n_corrupt: int = 0            # undecodable shard lines skipped
    shards: list = field(default_factory=list)   # shard paths, merge order

    def merged_nothing(self) -> bool:
        return not (self.n_campaigns or self.n_records or self.n_sweep_lines)


def _as_store(s) -> ResultStore:
    return s if isinstance(s, ResultStore) else ResultStore(s)


def merge_stores(dest, shards,
                 snapshot: StoreSnapshot | None = None) -> MergeStats:
    """Merge ``shards`` (paths or :class:`ResultStore`) into ``dest``.

    ``snapshot`` — a snapshot of ``dest`` — skips the re-parse and is
    kept coherent with everything appended, so a scheduler can thread its
    one global snapshot through many incremental merges. Shards are
    merged in the given order (sort paths for a deterministic compaction).
    The destination must not appear among the shards: a self-merge would
    read and append the same file.
    """
    dest = _as_store(dest)
    shards = [_as_store(s) for s in shards]
    for s in shards:
        if s.path.resolve() == Path(dest.path).resolve():
            raise ValueError(f"merge_stores: destination {dest.path} listed "
                             "among its own shards")
    if snapshot is None:
        snapshot = dest.snapshot()
    stats = MergeStats(n_corrupt=snapshot.n_corrupt)

    for shard in shards:
        if not shard.path.exists():
            continue
        snap = shard.snapshot()
        stats.shards.append(str(shard.path))
        stats.n_corrupt += snap.n_corrupt

        for fp, spec in snap.campaign_specs.items():
            if snapshot.campaign_specs.get(fp) != spec:
                dest._append(dict(kind="campaign", fingerprint=fp,
                                  factors=snap.campaign_factors.get(fp, {}),
                                  spec=spec))
                snapshot.campaign_specs[fp] = spec
                snapshot.campaign_factors[fp] = \
                    snap.campaign_factors.get(fp, {})
                stats.n_campaigns += 1
            for rec in snap.records.get(fp, []):
                key = (rec.case.op, rec.case.msize, rec.epoch)
                if key in snapshot.completed(fp):
                    stats.n_duplicates += 1
                    continue
                dest.append_record(fp, rec)
                snapshot.records.setdefault(fp, []).append(rec)
                stats.n_records += 1

        # sweep bookkeeping is content-addressed, so it federates too
        for sweep_id in snap.sweeps:
            if sweep_id not in snapshot.sweeps:
                dest._append(dict(kind="sweep", sweep=sweep_id,
                                  manifest=snap.manifests.get(sweep_id, {})))
                snapshot.sweeps.append(sweep_id)
                snapshot.manifests[sweep_id] = snap.manifests.get(sweep_id, {})
                stats.n_sweep_lines += 1
        for sweep_id, cells in snap.sweep_cells_by_id.items():
            have = snapshot.sweep_cells_by_id.setdefault(sweep_id, {})
            for index, fp in cells.items():
                if index not in have:
                    dest.append_sweep_cell(sweep_id, index, fp)
                    have[index] = fp
                    stats.n_sweep_lines += 1
        for sweep_id, cells in snap.sweep_failed_by_id.items():
            done = snapshot.sweep_cells_by_id.get(sweep_id, {})
            have = snapshot.sweep_failed_by_id.setdefault(sweep_id, {})
            for index, info in cells.items():
                # completion anywhere supersedes quarantine: never merge a
                # stale quarantine over a cell another shard finished
                if index in done or index in have:
                    continue
                dest.append_sweep_cell_failed(
                    sweep_id, index, info.get("fingerprint", ""),
                    info.get("attempts", 0), info.get("error", ""))
                have[index] = info
                stats.n_sweep_lines += 1
    return stats
