"""Fault-tolerant fleet execution for factor sweeps.

§5.2 of the paper treats a benchmark campaign as an *experiment*: which
cells get measured must not depend on which machine happened to die.
This package makes that a property of the scheduler rather than of luck:

- :mod:`~repro.fleet.queue` — the lease-based work queue (claim →
  heartbeat → expiry → jittered-backoff retry → quarantine), a pure
  state machine tests drive on a fake clock;
- :mod:`~repro.fleet.faults` — deterministic, seeded fault injection
  (crashes, stragglers, torn writes, transient exceptions) so every
  failure path above runs in tier-1 tests, not first in production;
- :mod:`~repro.fleet.federation` — idempotent merging of per-worker
  shard stores into one authoritative, resumable sweep store;
- :mod:`~repro.fleet.scheduler` — the :class:`FleetScheduler` driving
  real worker processes through all of the above, with the invariant
  that the merged fleet store is record-identical to a serial no-fault
  run (quarantined cells excepted, and explicitly reported).
"""

from .faults import (CRASH_EXIT_CODE, CrashFault, Fault, FaultPlan,
                     FaultyBackend, TransientFault)
from .federation import MergeStats, merge_stores
from .queue import CellTask, LeaseQueue
from .scheduler import FleetConfig, FleetScheduler, FleetSweepResult

__all__ = [
    "CellTask", "LeaseQueue",
    "Fault", "FaultPlan", "FaultyBackend", "CrashFault", "TransientFault",
    "CRASH_EXIT_CODE",
    "MergeStats", "merge_stores",
    "FleetConfig", "FleetScheduler", "FleetSweepResult",
]
