"""The fleet scheduler: lease-queue sweep execution over real processes.

:class:`FleetScheduler` replaces :class:`~repro.campaign.SweepScheduler`'s
static process-pool sharding (where a dead worker takes its cells with
it) with the :class:`~repro.fleet.queue.LeaseQueue`: workers *claim*
cells, hold them under a heartbeat lease, and lose them — to another
worker, after backoff — when they die or stall. Cells that fail their
whole retry budget are quarantined into a ``sweep-cell-failed`` store
record instead of wedging the campaign.

The durability scheme is all-or-nothing per *attempt*: each claimed cell
runs in its own ``multiprocessing.Process`` (a pool cannot survive a
SIGKILLed member) writing to a private shard store; the parent merges a
shard into the authoritative store only after verifying the full
case x epoch record set landed, and discards the shard of any failed
attempt. A retried cell therefore re-measures from scratch against a
fresh epoch context — which is exactly what makes the merged fleet store
*record-identical* to a serial no-fault run of the same spec: no cell is
ever resumed mid-epoch with an advanced backend RNG, and injected faults
(:mod:`repro.fleet.faults`) decide only whether an attempt lands, never
what it measures.

The heartbeat is progress, not liveness: a worker touches its ``.hb``
file after every durably appended record, so an alive-but-stalled worker
(straggler) goes quiet exactly like a dead one and loses its lease.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import time
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable

from repro.campaign.core import Campaign, CampaignSpec
from repro.campaign.store import ResultStore
from repro.campaign.sweep import (CellResult, SweepResult, SweepScheduler,
                                  SweepSpec)
from repro.core.design import analyze_records
from repro.core.retry import RetryPolicy

from .faults import CRASH_EXIT_CODE, FaultPlan, FaultyBackend
from .federation import merge_stores
from .queue import QUARANTINED, LeaseQueue

__all__ = ["FleetConfig", "FleetSweepResult", "FleetScheduler"]


@dataclass(frozen=True)
class FleetConfig:
    """Knobs of one fleet run.

    ``lease_ttl`` must exceed the worst-case gap between two record
    appends of a healthy worker (the heartbeat period), or healthy
    leases expire; ``retry_budget`` counts *attempts*, so 3 means one
    try plus two retries before quarantine. ``clock``/``sleep`` exist so
    tests can drive the scheduler on a fake clock.
    """

    n_workers: int = 3
    lease_ttl: float = 5.0
    retry_budget: int = 3
    retry: RetryPolicy = RetryPolicy(base=0.05, max_delay=1.0, seed=0)
    poll_s: float = 0.05
    shard_dir: str | None = None   # default: <store>-shards/ next to it
    faults: FaultPlan | None = None
    keep_shards: bool = False      # leave merged/failed shards for forensics
    clock: Callable[[], float] = field(default=time.time, repr=False)
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False)


@dataclass
class FleetSweepResult(SweepResult):
    """A :class:`~repro.campaign.SweepResult` that is honest about holes:
    ``quarantined`` maps cell index -> ``{fingerprint, attempts, error}``
    for every cell the fleet gave up on."""

    quarantined: dict = field(default_factory=dict)
    fleet: dict = field(default_factory=dict)   # scheduler stats

    def degraded(self) -> bool:
        return bool(self.quarantined)


def _fleet_worker(backend, cases, design, name, shard_path, hb_path,
                  plan, cell_index, attempt, epochs=None):
    """One claimed cell, one process, one private shard store.

    Runs the cell as an ordinary campaign against the shard (``epochs``
    windows it to a budgeted round's slice); touches the heartbeat file
    after every durable record append. On any failure the error lands in
    ``<shard>.err`` and the process exits nonzero — the parent discards
    the shard either way, so a worker never has to clean up after itself
    (and an injected hard crash *cannot*).
    """
    try:
        if plan is not None and plan.any_faults():
            backend = FaultyBackend(backend, plan, cell_index,
                                    attempt=attempt, hard=True,
                                    shard_path=str(shard_path))
        store = ResultStore(shard_path)
        hb = Path(hb_path)

        def beat(_rec):
            hb.touch()

        Campaign(CampaignSpec(list(cases), design, name=name),
                 backend, store).run(on_record=beat, epochs=epochs)
        os._exit(0)
    except BaseException as e:   # noqa: BLE001 — the report IS the handling
        try:
            Path(str(shard_path) + ".err").write_text(
                f"{type(e).__name__}: {e}")
        except OSError:
            pass
        os._exit(1)


class FleetScheduler(SweepScheduler):
    """Run a :class:`~repro.campaign.SweepSpec` fault-tolerantly.

    Inherits compilation, the sweep manifest, and cell-granular resume
    from :class:`~repro.campaign.SweepScheduler` — a fleet store is a
    sweep store, loadable and resumable by either scheduler — and
    replaces only how pending cells execute. Quarantined cells are *not*
    marked complete, so a resumed fleet run re-attempts them (with a
    fresh retry budget); success then supersedes the quarantine record.

    ``n_workers == 1`` schedules in-process: same queue, same retry and
    quarantine semantics, soft (exception-based) crash faults — the mode
    tier-1 tests drive deterministically.
    """

    def __init__(self, spec: SweepSpec, backend, store: ResultStore,
                 config: FleetConfig | None = None, policy=None):
        if store is None:
            raise ValueError("FleetScheduler: a store is required — lease "
                             "recovery and shard federation are meaningless "
                             "without durable results")
        self.config = config or FleetConfig()
        super().__init__(spec, backend, store,
                         n_workers=self.config.n_workers, policy=policy)
        self._quarantined: dict[int, dict] = {}
        self._queue_stats: dict = {}
        self._n_corrupt_shard_lines = 0

    # -- public ------------------------------------------------------------

    def run(self) -> FleetSweepResult:
        self._quarantined = {}
        self._queue_stats = {}
        self._n_corrupt_shard_lines = 0
        base = super().run()
        cfg = self.config
        fleet = dict(
            self._queue_stats,
            n_workers=cfg.n_workers,
            lease_ttl=cfg.lease_ttl,
            retry_budget=cfg.retry_budget,
            n_corrupt_shard_lines=self._n_corrupt_shard_lines,
            faults=(None if cfg.faults is None or not cfg.faults.any_faults()
                    else repr(cfg.faults)),
        )
        return FleetSweepResult(
            cells=base.cells, sweep_id=base.sweep_id,
            n_cells_measured=base.n_cells_measured,
            n_cells_resumed=base.n_cells_resumed,
            meta=dict(base.meta, fleet=fleet),
            quarantined=dict(self._quarantined), fleet=fleet)

    # -- SweepScheduler execution hook -------------------------------------

    def _execute_pending(self, pending, sweep_id, snapshot):
        if not pending:
            return {}
        queue = LeaseQueue(
            [(cell.index, fp) for cell, _, _, _, fp in pending],
            lease_ttl=self.config.lease_ttl, policy=self.config.retry,
            retry_budget=self.config.retry_budget)
        if self.config.n_workers <= 1:
            out = self._drive_inprocess(queue, pending, sweep_id, snapshot)
        else:
            out = self._drive_fleet(queue, pending, sweep_id, snapshot)
        # a budgeted sweep calls this hook once per allocation round —
        # accumulate, so the final stats cover every leased work item
        # (the same cell leased in two rounds counts as two items)
        for k, v in queue.stats().items():
            self._queue_stats[k] = self._queue_stats.get(k, 0) + v
        return out

    # -- in-process mode ----------------------------------------------------

    def _drive_inprocess(self, queue, pending, sweep_id, snapshot):
        cfg = self.config
        by_index = {entry[0].index: entry for entry in pending}
        out: dict[int, CellResult] = {}
        while not queue.finished():
            now = cfg.clock()
            task = queue.claim("w0", now)
            if task is None:
                wake = queue.next_wake(now)
                cfg.sleep(max(0.0, (wake - now) if wake is not None
                              else cfg.poll_s))
                continue
            entry = by_index[task.index]
            cell, backend, design, _, _ = entry
            plan = cfg.faults
            if plan is not None and plan.any_faults():
                backend = FaultyBackend(backend, plan, cell.index,
                                        attempt=task.attempts, hard=False)
            try:
                # no store attached: an attempt is all-or-nothing, so a
                # crash mid-cell leaves nothing to mis-resume from
                res = Campaign(self.spec.cell_spec(cell, design),
                               backend).run(epochs=self._epoch_window())
            except Exception as e:   # injected or genuine — same contract
                self._fail(queue, task, sweep_id, snapshot,
                           f"{type(e).__name__}: {e}")
                continue
            out[cell.index] = self._persist_cell(entry, res.records,
                                                 sweep_id, snapshot)
            queue.complete(task.index)
        return out

    def _persist_cell(self, entry, new_records, sweep_id, snapshot):
        """Append a successful attempt's records (deduplicated against
        whatever the store already holds for this fingerprint), then the
        completion marker — the same parent-persists idiom as the pool
        path, so a kill between records costs at most this one cell."""
        cell, _, design, factors, fp = entry
        store = self.store
        have = snapshot.completed(fp)
        store.append_campaign(factors, self.spec.cell_spec(cell, design).meta(),
                              snapshot=snapshot)
        n_new = 0
        for rec in new_records:
            if (rec.case.op, rec.case.msize, rec.epoch) not in have:
                store.append_record(fp, rec)
                snapshot.records.setdefault(fp, []).append(rec)
                n_new += 1
        if self._round_epochs is None:
            store.append_sweep_cell(sweep_id, cell.index, fp)
            snapshot.sweep_cells_by_id.setdefault(sweep_id,
                                                  {})[cell.index] = fp
        records = snapshot.records.get(fp, [])
        return CellResult(cell=cell, factors=factors, fingerprint=fp,
                          table=analyze_records(records,
                                                design.outlier_filter),
                          n_measured=n_new, n_resumed=len(records) - n_new)

    # -- multi-process mode --------------------------------------------------

    def _drive_fleet(self, queue, pending, sweep_id, snapshot):
        cfg = self.config
        shard_dir = (Path(cfg.shard_dir) if cfg.shard_dir else
                     self.store.path.parent /
                     (self.store.path.stem + "-shards"))
        shard_dir.mkdir(parents=True, exist_ok=True)
        methods = mp.get_all_start_methods()
        ctx = mp.get_context("fork" if "fork" in methods else None)
        by_index = {entry[0].index: entry for entry in pending}
        active: dict[int, dict] = {}     # cell index -> live worker state
        out: dict[int, CellResult] = {}
        n_spawned = 0
        try:
            while True:
                now = cfg.clock()
                # 1) reap exited workers (heartbeats first, so a worker
                #    that just finished is not simultaneously "expired")
                for idx in list(active):
                    w = active[idx]
                    try:
                        m = w["hb"].stat().st_mtime
                    except OSError:
                        m = w["last_hb"]
                    if m > w["last_hb"]:
                        w["last_hb"] = m
                        queue.heartbeat(idx, now)
                    if w["proc"].is_alive():
                        continue
                    w["proc"].join()
                    res, err = self._reap(by_index[idx], w,
                                          w["proc"].exitcode,
                                          sweep_id, snapshot)
                    if err is None:
                        queue.complete(idx)
                        out[idx] = res
                    else:
                        self._fail(queue, queue.tasks[idx], sweep_id,
                                   snapshot, err, now=cfg.clock())
                    self._cleanup(w, failed=err is not None)
                    del active[idx]
                # 2) revoke leases whose heartbeat went quiet
                for task in queue.expired(cfg.clock()):
                    w = active.pop(task.index, None)
                    if w is not None:
                        _kill(w["proc"])
                        self._cleanup(w, failed=True)
                    self._fail(queue, task, sweep_id, snapshot,
                               f"lease expired after {cfg.lease_ttl:.1f}s "
                               "without a heartbeat (worker stalled or "
                               "unreachable)", now=cfg.clock())
                # 3) hand free workers the next eligible cells
                now = cfg.clock()
                while len(active) < cfg.n_workers:
                    task = queue.claim(f"w{n_spawned}", now)
                    if task is None:
                        break
                    active[task.index] = self._spawn(
                        ctx, by_index[task.index], task, shard_dir)
                    n_spawned += 1
                if queue.finished():
                    break
                cfg.sleep(cfg.poll_s)
        finally:
            for w in active.values():    # interrupted: leave no orphans
                _kill(w["proc"])
                self._cleanup(w, failed=True)
            if not cfg.keep_shards:
                try:
                    shard_dir.rmdir()    # only if empty — best effort
                except OSError:
                    pass
        return out

    def _spawn(self, ctx, entry, task, shard_dir):
        cell, backend, design, _, _ = entry
        stem = f"cell{cell.index:03d}-a{task.attempts:02d}"
        shard = shard_dir / f"{stem}.jsonl"
        hb = shard_dir / f"{stem}.hb"
        err = shard_dir / f"{stem}.jsonl.err"
        for p in (shard, hb, err):       # stale residue of a killed run
            p.unlink(missing_ok=True)
        hb.touch()
        proc = ctx.Process(
            target=_fleet_worker,
            args=(backend, self.spec.cases, design,
                  self.spec.cell_spec(cell, design).name, str(shard),
                  str(hb), self.config.faults, cell.index, task.attempts,
                  self._epoch_window()),
            daemon=True)
        proc.start()
        return dict(proc=proc, shard=shard, hb=hb, err=err,
                    last_hb=hb.stat().st_mtime)

    def _reap(self, entry, w, exitcode, sweep_id, snapshot):
        """Judge one exited worker: merge its shard on verified success,
        or return the failure message that releases its lease."""
        cell, _, design, factors, fp = entry
        if exitcode != 0:
            if w["err"].exists():
                return None, w["err"].read_text().strip()
            if exitcode == CRASH_EXIT_CODE:
                return None, (f"worker killed mid-cell (exit {exitcode}, "
                              "injected crash)")
            return None, f"worker died with exit code {exitcode}"
        shard = ResultStore(w["shard"])
        with warnings.catch_warnings():
            warnings.simplefilter("always")   # shard corruption is counted,
            ssnap = shard.snapshot()          # not raised, below
        if self.spec.cases:
            window = self._epoch_window() or range(design.n_launch_epochs)
            expected = {(c.op, int(c.msize), e) for c in self.spec.cases
                        for e in window}
            if not expected <= ssnap.completed(fp):
                return None, ("worker exited cleanly but its shard is "
                              f"missing {len(expected - ssnap.completed(fp))} "
                              "of the cell's records")
        stats = merge_stores(self.store, [shard], snapshot=snapshot)
        self._n_corrupt_shard_lines += ssnap.n_corrupt
        if self._round_epochs is None:
            self.store.append_sweep_cell(sweep_id, cell.index, fp)
            snapshot.sweep_cells_by_id.setdefault(sweep_id,
                                                  {})[cell.index] = fp
        records = snapshot.records.get(fp, [])
        res = CellResult(cell=cell, factors=factors, fingerprint=fp,
                         table=analyze_records(records,
                                               design.outlier_filter),
                         n_measured=stats.n_records,
                         n_resumed=len(records) - stats.n_records)
        return res, None

    def _cleanup(self, w, failed: bool):
        if self.config.keep_shards:
            return
        for key in ("shard", "hb", "err"):
            w[key].unlink(missing_ok=True)

    # -- shared failure path -------------------------------------------------

    def _fail(self, queue, task, sweep_id, snapshot, error: str,
              now: float | None = None):
        state = queue.release(task.index, self.config.clock()
                              if now is None else now, error)
        if state != QUARANTINED:
            return
        info = dict(fingerprint=task.fingerprint, attempts=task.attempts,
                    error=str(error)[:500])
        self.store.append_sweep_cell_failed(
            sweep_id, task.index, task.fingerprint, task.attempts, error)
        snapshot.sweep_failed_by_id.setdefault(sweep_id, {})[task.index] = info
        self._quarantined[task.index] = info
        warnings.warn(
            f"fleet: quarantining sweep cell {task.index} "
            f"(fingerprint {task.fingerprint[:12]}…) after "
            f"{task.attempts} failed attempts; last error: {error}",
            RuntimeWarning, stacklevel=4)


def _kill(proc) -> None:
    """Stop a worker that lost its lease: polite, then SIGKILL."""
    if not proc.is_alive():
        proc.join()
        return
    proc.terminate()
    proc.join(0.5)
    if proc.is_alive():
        proc.kill()
        proc.join(1.0)
