"""Lease-based work queue: which cell runs where, and what happens when
a worker dies holding it.

Static sharding (cell *i* belongs to worker ``i % n``) has exactly the
failure mode §5.2 warns about: a dead worker silently removes *its*
cells from the sweep — a systematic, factor-correlated hole in the
design. The :class:`LeaseQueue` replaces it with work stealing under
*leases*: a worker claims the next eligible cell and must keep the lease
alive by heartbeating; a lease that goes quiet past its TTL expires and
the cell returns to the queue, gated by an exponential-backoff-with-full-
jitter delay (:class:`~repro.core.retry.RetryPolicy`). A cell that fails
its whole retry budget is **quarantined** — recorded, reported, and
excluded — instead of wedging the sweep.

The queue is deliberately *pure*: every method takes ``now`` explicitly,
nothing sleeps, nothing spawns. The :class:`~repro.fleet.FleetScheduler`
drives it with wall-clock time and real processes; the tier-1 tests
drive it with a hand-rolled clock and assert the exact lease/backoff/
quarantine schedule.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.retry import RetryPolicy

__all__ = ["CellTask", "LeaseQueue"]

#: Task lifecycle: pending -> leased -> (done | pending (retry) | quarantined)
PENDING, LEASED, DONE, QUARANTINED = ("pending", "leased", "done",
                                      "quarantined")


@dataclass
class CellTask:
    """One sweep cell's place in the queue."""

    index: int                     # grid cell index
    fingerprint: str               # factor fingerprint (the store key)
    state: str = PENDING
    attempts: int = 0              # finished (failed) attempts so far
    not_before: float = 0.0        # backoff gate: ineligible before this
    worker: str | None = None      # current lease holder
    lease_expires: float = 0.0     # heartbeat deadline while leased
    errors: list = field(default_factory=list)   # one entry per failure


class LeaseQueue:
    """Cells → leases → retries → quarantine, as a deterministic state
    machine.

    ``retry_budget`` is the number of *attempts* a cell gets before
    quarantine (a budget of 3 = one initial try + two retries);
    ``policy`` shapes the delay between them, jitter-keyed by the cell
    index so two cells released together do not retry together.
    """

    def __init__(self, cells: list[tuple[int, str]], lease_ttl: float,
                 policy: RetryPolicy | None = None, retry_budget: int = 3):
        if lease_ttl <= 0:
            raise ValueError("LeaseQueue: lease_ttl must be > 0")
        if retry_budget < 1:
            raise ValueError("LeaseQueue: retry_budget must be >= 1")
        self.lease_ttl = float(lease_ttl)
        self.policy = policy or RetryPolicy(seed=0)
        self.retry_budget = int(retry_budget)
        self.tasks: dict[int, CellTask] = {
            int(i): CellTask(index=int(i), fingerprint=fp) for i, fp in cells}

    # -- claiming & heartbeats --------------------------------------------

    def claim(self, worker: str, now: float) -> CellTask | None:
        """Lease the next eligible pending cell to ``worker`` (lowest
        index first, respecting backoff gates); ``None`` when nothing is
        eligible *right now* (there may still be gated retries — see
        :meth:`next_wake`)."""
        for task in sorted(self.tasks.values(), key=lambda t: t.index):
            if task.state == PENDING and task.not_before <= now:
                task.state = LEASED
                task.worker = worker
                task.lease_expires = now + self.lease_ttl
                return task
        return None

    def heartbeat(self, index: int, now: float) -> None:
        """Progress signal from the lease holder: push the expiry out.
        Heartbeats on non-leased cells are ignored (a stale worker may
        still phone home after its lease was revoked)."""
        task = self.tasks[index]
        if task.state == LEASED:
            task.lease_expires = now + self.lease_ttl

    def expired(self, now: float) -> list[CellTask]:
        """Leases whose heartbeat went quiet past the TTL. The scheduler
        must kill the holder (it may be alive-but-stalled) and then
        :meth:`release` the cell."""
        return [t for t in sorted(self.tasks.values(), key=lambda t: t.index)
                if t.state == LEASED and t.lease_expires <= now]

    # -- completion & failure ---------------------------------------------

    def complete(self, index: int) -> None:
        task = self.tasks[index]
        task.state = DONE
        task.worker = None

    def release(self, index: int, now: float, error: str) -> str:
        """A leased attempt failed (crash, stall, exception). Returns the
        cell's new state: ``"pending"`` (requeued behind a jittered
        backoff gate) or ``"quarantined"`` (budget exhausted)."""
        task = self.tasks[index]
        task.worker = None
        task.attempts += 1
        task.errors.append(str(error))
        if task.attempts >= self.retry_budget:
            task.state = QUARANTINED
            return QUARANTINED
        # 0-based backoff attempt: first retry waits ~policy.base
        delay = self.policy.delay(task.attempts - 1, key=task.index)
        task.not_before = now + delay
        task.state = PENDING
        return PENDING

    # -- introspection -----------------------------------------------------

    def finished(self) -> bool:
        """No cell will ever run again: everything done or quarantined."""
        return all(t.state in (DONE, QUARANTINED)
                   for t in self.tasks.values())

    def next_wake(self, now: float) -> float | None:
        """Earliest future instant at which something becomes actionable
        (a backoff gate opens or a lease can expire); ``None`` when
        :meth:`finished`. The scheduler sleeps until then instead of
        spinning."""
        times = [t.not_before for t in self.tasks.values()
                 if t.state == PENDING and t.not_before > now]
        times += [t.lease_expires for t in self.tasks.values()
                  if t.state == LEASED]
        return min(times) if times else None

    def by_state(self, state: str) -> list[CellTask]:
        return [t for t in sorted(self.tasks.values(), key=lambda t: t.index)
                if t.state == state]

    def quarantined(self) -> list[CellTask]:
        return self.by_state(QUARANTINED)

    def stats(self) -> dict:
        tasks = list(self.tasks.values())
        return dict(
            n_cells=len(tasks),
            n_done=sum(t.state == DONE for t in tasks),
            n_quarantined=sum(t.state == QUARANTINED for t in tasks),
            # attempts only ever increments on failure, so this is the
            # total number of failed attempts across the whole sweep
            n_failed_attempts=sum(t.attempts for t in tasks),
        )
