"""Deterministic fault injection: failure as a first-class, *tested* input.

A fault-tolerant scheduler whose failure paths only ever run in
production is not fault-tolerant — it is optimistic. This module makes
the failure modes injectable, seeded, and cheap enough for tier-1 tests:

  ``crash``     the worker process dies mid-cell (``os._exit``, the
                SIGKILL-equivalent: no cleanup, no flush, and — like a
                real kill — a torn half-written line left in its shard);
  ``straggle``  the worker stalls before a measurement long enough for
                its heartbeat to go quiet (exercises lease expiry);
  ``raise``     a transient exception out of ``measure`` (exercises the
                retry path without killing anything);
  ``torn``      a corrupt line written *into* the shard mid-run, as if a
                colocated writer died there (exercises the store's
                skip-warn-count path through a *successful* cell).

A :class:`FaultPlan` decides, as a pure function of ``(seed, cell index,
attempt)``, which faults strike which attempt at which measure call — so
a chaos run is exactly reproducible, and by default only a cell's early
attempts are faulty (``max_faulty_attempts``), so retries converge and
``parallel == serial`` can be asserted *under* injected faults.
:class:`FaultyBackend` wraps any ``MeasurementBackend`` to apply the
plan; it is fingerprint-transparent (``factors()`` delegates), because a
fault changes *whether* a measurement lands, never its value.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

__all__ = ["Fault", "FaultPlan", "FaultyBackend", "CrashFault",
           "TransientFault"]


class CrashFault(RuntimeError):
    """Soft-mode stand-in for a worker crash (in-process schedulers
    cannot survive a real ``os._exit``)."""


class TransientFault(RuntimeError):
    """The injected transient exception (kind ``raise``)."""


@dataclass(frozen=True)
class Fault:
    """One injected fault: strike at the ``at_call``-th measure call."""

    kind: str                      # crash | straggle | raise | torn
    at_call: int                   # 1-based measure-call index


@dataclass(frozen=True)
class FaultPlan:
    """Seeded, per-(cell, attempt) fault decisions.

    Each probability is drawn independently per cell attempt; the strike
    point is a uniformly drawn measure-call index in ``[1,
    within_calls]`` (a cell of C cases x E epochs sees at least C*E
    calls, so small values strike early, where the most bookkeeping is
    still in flight). ``max_faulty_attempts`` bounds *which* attempts can
    fault: the default 1 means only a cell's first attempt is ever
    sabotaged, so the retry path always has a clean run to converge to —
    the configuration the chaos-fleet equivalence test needs. Set it
    higher (with probability 1) to drive a cell into quarantine.
    """

    seed: int = 0
    p_crash: float = 0.0
    p_straggle: float = 0.0
    p_raise: float = 0.0
    p_torn: float = 0.0
    straggle_s: float = 0.5        # stall duration; > lease TTL => expiry
    within_calls: int = 6
    max_faulty_attempts: int = 1
    torn_on_crash: bool = True     # a crash also tears its last write

    def __post_init__(self):
        for name in ("p_crash", "p_straggle", "p_raise", "p_torn"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"FaultPlan: {name} must be in [0, 1], "
                                 f"got {p}")

    def any_faults(self) -> bool:
        return any(p > 0 for p in (self.p_crash, self.p_straggle,
                                   self.p_raise, self.p_torn))

    def decide(self, cell_index: int, attempt: int) -> list[Fault]:
        """The faults striking this (cell, attempt) — deterministic, and
        independent of which worker/host happens to run it."""
        if attempt >= self.max_faulty_attempts:
            return []
        rng = np.random.default_rng(
            (int(self.seed), int(cell_index), int(attempt)))
        out = []
        for kind, p in (("crash", self.p_crash),
                        ("straggle", self.p_straggle),
                        ("raise", self.p_raise),
                        ("torn", self.p_torn)):
            u = float(rng.random())
            at = int(rng.integers(1, self.within_calls + 1))
            if u < p:
                out.append(Fault(kind=kind, at_call=at))
        return out

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """CLI form: ``crash=0.4,straggle=0.2,seed=7,straggle_s=1.0``.
        Keys are the dataclass fields, with ``crash``/``straggle``/
        ``raise``/``torn`` accepted as shorthand for their ``p_*``
        probability fields."""
        kw: dict[str, Any] = {}
        alias = {"crash": "p_crash", "straggle": "p_straggle",
                 "raise": "p_raise", "torn": "p_torn"}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"FaultPlan.parse: expected key=value, "
                                 f"got {part!r}")
            k, v = part.split("=", 1)
            k = alias.get(k.strip(), k.strip())
            if k not in cls.__dataclass_fields__:
                raise ValueError(
                    f"FaultPlan.parse: unknown key {k!r}; one of "
                    f"{sorted(set(cls.__dataclass_fields__) | set(alias))}")
            ftype = str(cls.__dataclass_fields__[k].type)
            v = v.strip()
            if "bool" in ftype:
                kw[k] = v.lower() in ("1", "true", "yes")
            elif "int" in ftype:
                kw[k] = int(v)
            else:
                kw[k] = float(v)
        return cls(**kw)


#: Exit code a hard (process) crash fault dies with — lets the scheduler
#: log "injected crash" distinctly from a genuine worker failure.
CRASH_EXIT_CODE = 113

#: The torn half-line a crash leaves behind: valid JSON prefix, no close,
#: no newline — exactly what a writer killed mid-``write(2)`` produces.
TORN_TAIL = '{"kind": "record", "fingerprint": "torn-by-injected-crash", "t'

#: A survivable mid-run torn line (newline-terminated, so later appends
#: start clean and the garbage ends up *mid-file* once the cell finishes).
TORN_LINE = '{"kind": "record", "fingerprint": "torn-by-fault-plan", "op'


@dataclass
class FaultyBackend:
    """Wrap a ``MeasurementBackend``; apply a :class:`FaultPlan`.

    ``hard=True`` (subprocess workers) makes ``crash`` a real
    ``os._exit`` — un-catchable, un-flushable, the SIGKILL-equivalent;
    ``hard=False`` (in-process scheduling, and any test that must
    survive) raises :class:`CrashFault` instead. ``shard_path`` is where
    torn-write faults land their garbage; without it they are no-ops.
    Everything else — factors, epochs, default cases, and above all the
    *measured values* — delegates untouched to ``inner``.
    """

    inner: Any
    plan: FaultPlan
    cell_index: int
    attempt: int = 0
    hard: bool = False
    shard_path: str | None = None
    _calls: int = field(default=0, init=False, repr=False, compare=False)

    @property
    def name(self) -> str:
        return self.inner.name

    def make_epoch(self, epoch: int) -> Any:
        return self.inner.make_epoch(epoch)

    def factors(self, design) -> Any:
        # fingerprint-transparent by design: injected faults decide
        # whether a measurement lands, never what it measures
        return self.inner.factors(design)

    def default_cases(self) -> list:
        return self.inner.default_cases()

    def _tear(self, text: str) -> None:
        if self.shard_path is None:
            return
        with open(self.shard_path, "a") as f:
            f.write(text)
            f.flush()

    def measure(self, ctx: Any, case: Any, nrep: int) -> np.ndarray:
        self._calls += 1
        for fault in self.plan.decide(self.cell_index, self.attempt):
            if fault.at_call != self._calls:
                continue
            if fault.kind == "torn":
                self._tear(TORN_LINE + "\n")
            elif fault.kind == "straggle":
                time.sleep(self.plan.straggle_s)
            elif fault.kind == "raise":
                raise TransientFault(
                    f"injected transient fault (cell {self.cell_index}, "
                    f"attempt {self.attempt}, call {self._calls})")
            elif fault.kind == "crash":
                if self.hard:
                    if self.plan.torn_on_crash:
                        self._tear(TORN_TAIL)
                    os._exit(CRASH_EXIT_CODE)
                raise CrashFault(
                    f"injected crash (cell {self.cell_index}, attempt "
                    f"{self.attempt}, call {self._calls})")
        return self.inner.measure(ctx, case, nrep)
