"""Fault-tolerant, instrumented training loop (runtime substrate).

Combines the substrates into the production driver:

  * checkpoint/restart (async saves every ``save_every`` steps; restart
    resumes from the latest checkpoint, data position derived from the step
    — counter-based pipeline, nothing else to restore),
  * failure injection for tests/drills (``FailureInjector`` raises at a
    chosen step; the supervisor restarts the loop, which restores),
  * elastic restart: checkpoints are mesh-agnostic, so the supervisor may
    rebuild on a different mesh between attempts,
  * step-time measurement with the paper's methodology
    (:mod:`repro.core`): per-step host timings around fenced dispatches,
    Tukey-filtered per-epoch summaries, and straggler detection via the
    trailing-window Tukey fences (§4.6's decomposition applied to step
    times; on a real pod the per-host (start, end) stamps come from the
    HCA-synchronized global clock).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.checkpoint.store import CheckpointConfig, CheckpointStore
from repro.core.stats import tukey_fences, tukey_filter
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models import ModelConfig, init_params
from repro.optim import OptimizerConfig, init_opt_state
from repro.launch.steps import make_train_step

__all__ = ["TrainerConfig", "Trainer", "FailureInjector", "StragglerMonitor"]


@dataclass
class TrainerConfig:
    total_steps: int = 100
    save_every: int = 20
    log_every: int = 10
    seed: int = 0
    remat: bool = True
    measure_steps: bool = True


class FailureInjector:
    """Deterministic failure drill: raises RuntimeError at given steps."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.fired: set[int] = set()

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class StragglerMonitor:
    """Flags steps whose duration exceeds the Tukey fence of a trailing
    window — the runtime payoff of the paper's outlier methodology."""

    def __init__(self, window: int = 50):
        self.window = window
        self.times: list[float] = []
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = np.array(self.times[-self.window:])
        if hist.size < 10:
            return False
        lo, hi = tukey_fences(hist[:-1])
        if dt > hi:
            self.flagged.append(step)
            return True
        return False


class Trainer:
    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig,
                 opt_cfg: OptimizerConfig | None = None,
                 trainer_cfg: TrainerConfig | None = None,
                 ckpt_cfg: CheckpointConfig | None = None):
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg or OptimizerConfig()
        self.cfg = trainer_cfg or TrainerConfig()
        self.store = CheckpointStore(ckpt_cfg or CheckpointConfig())
        self.monitor = StragglerMonitor()
        self.step_times: list[float] = []
        self.losses: list[float] = []

    def _init_state(self):
        params = init_params(self.model_cfg, jax.random.PRNGKey(self.cfg.seed))
        return {"params": params, "opt": init_opt_state(params)}

    def run(self, failure: FailureInjector | None = None) -> dict:
        """One supervised attempt; raises on injected failure (the
        supervisor catches and re-invokes — see :func:`run_supervised`)."""
        state = self._init_state()
        restored, step0 = self.store.restore(state)
        start_step = 0
        if restored is not None:
            state = restored
            start_step = step0
        step_fn = jax.jit(make_train_step(self.model_cfg, self.opt_cfg,
                                          remat=self.cfg.remat),
                          donate_argnums=(0,))
        source = SyntheticLM(self.data_cfg)
        prefetch = Prefetcher(source, start_step=start_step)
        try:
            for step in range(start_step, self.cfg.total_steps):
                if failure is not None:
                    failure.check(step)
                got_step, batch = prefetch.next()
                assert got_step == step, (got_step, step)
                t0 = time.perf_counter_ns()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])  # fences the dispatch
                dt = (time.perf_counter_ns() - t0) * 1e-9
                self.step_times.append(dt)
                self.losses.append(loss)
                self.monitor.observe(step, dt)
                if (step + 1) % self.cfg.save_every == 0 \
                        or step + 1 == self.cfg.total_steps:
                    self.store.save(step + 1, state)
                if (step + 1) % self.cfg.log_every == 0:
                    print(f"[train] step {step + 1} loss {loss:.4f} "
                          f"dt {dt * 1e3:.1f}ms")
        finally:
            prefetch.close()
        self.store.wait()
        kept = tukey_filter(np.array(self.step_times)) if self.step_times else np.array([])
        return {
            "final_step": self.cfg.total_steps,
            "losses": self.losses,
            "mean_step_time": float(np.mean(kept)) if kept.size else 0.0,
            "stragglers": list(self.monitor.flagged),
            "state": state,
        }


def run_supervised(trainer: Trainer, failure: FailureInjector | None = None,
                   max_restarts: int = 3) -> dict:
    """The supervisor: restart-on-failure from the latest checkpoint."""
    attempts = 0
    while True:
        try:
            out = trainer.run(failure)
            out["restarts"] = attempts
            return out
        except RuntimeError as e:
            attempts += 1
            print(f"[supervisor] attempt {attempts} failed: {e}; restarting")
            if attempts > max_restarts:
                raise
