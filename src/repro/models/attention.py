"""Attention blocks: GQA/MQA, sliding-window + global patterns, soft-capping,
RoPE, MLA (DeepSeek-V2 latent attention), and KV-cache decode paths.

The inner attention product routes through :func:`attention_op`, which
dispatches to the Pallas flash-attention kernel on TPU and to the pure-jnp
reference elsewhere (the dry-run lowers the jnp path; kernels are validated
separately in ``tests/test_kernels``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, apply_rope, dense_init, rms_norm, rotary_embedding, softcap

__all__ = [
    "init_attn_params",
    "attention_op",
    "attn_block",
    "attn_decode_step",
    "init_mla_params",
    "mla_block",
    "mla_decode_step",
]


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def init_attn_params(cfg: ModelConfig, key) -> dict:
    hd = cfg.hd
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dt),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dt),
    }


def init_mla_params(cfg: ModelConfig, key) -> dict:
    """DeepSeek-V2 multi-head latent attention [arXiv:2405.04434]."""
    d, hd, r, rd = cfg.d_model, cfg.hd, cfg.kv_lora_rank, cfg.rope_head_dim
    qr = cfg.q_lora_rank or 0
    nh = cfg.n_heads
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    p = {
        # KV path: compress to latent r (+ shared rope key), decompress per head
        "w_dkv": dense_init(ks[0], (d, r + rd), dt),
        "w_uk": dense_init(ks[1], (r, nh * hd), dt),
        "w_uv": dense_init(ks[2], (r, nh * hd), dt),
        "wo": dense_init(ks[3], (nh * hd, d), dt),
        "kv_norm": jnp.zeros((r,), dt),
    }
    if qr:
        p["w_dq"] = dense_init(ks[4], (d, qr), dt)
        p["w_uq"] = dense_init(ks[5], (qr, nh * (hd + rd)), dt)
        p["q_norm"] = jnp.zeros((qr,), dt)
    else:
        p["wq"] = dense_init(ks[6], (d, nh * (hd + rd)), dt)
    return p


# ---------------------------------------------------------------------------
# Core attention op (reference path; Pallas kernel plugs in on TPU)
# ---------------------------------------------------------------------------

def attention_op(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: jnp.ndarray | int | None = None,
    logit_cap: float = 0.0,
    q_offset: jnp.ndarray | int = 0,
    kv_len: jnp.ndarray | None = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Grouped-query attention.

    q: (B, S, H, Dh); k/v: (B, T, Hkv, Dh). ``window`` may be a traced
    scalar (per-layer local/global selection under scan). ``q_offset`` is
    the absolute position of q[0] (decode). ``kv_len`` masks a padded cache.
    """
    if impl == "auto":
        try:  # prefer the Pallas kernel on TPU backends
            import jax.extend  # noqa: F401 -- probe kernel-capable jax

            if jax.default_backend() == "tpu":
                from repro.kernels import ops as kops

                return kops.flash_attention(
                    q, k, v, causal=causal, window=window,
                    logit_cap=logit_cap, q_offset=q_offset, kv_len=kv_len,
                )
        except Exception:
            pass
    return attention_reference(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        q_offset=q_offset, kv_len=kv_len,
    )


Q_CHUNK = 1024  # reference-path query blocking (memory control on long seqs)


def _attention_dense(q, k, v, *, causal, window, logit_cap, q_offset, kv_len):
    from .tuning import get_tuning

    tune = get_tuning()
    b, s, h, dh = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    scale = 1.0 / np.sqrt(dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if logit_cap and logit_cap > 0:
        logits = softcap(logits, logit_cap)
    qpos = jnp.arange(s) + q_offset          # absolute positions of queries
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        w = jnp.asarray(window)
        mask &= (qpos[:, None] - kpos[None, :]) < w
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    if tune.attn_additive_mask:
        # additive bias fuses with the preceding scale (one fewer f32 pass)
        logits = logits + jnp.where(mask[None, None, None], 0.0, -1e30)
    else:
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if tune.attn_probs_bf16:
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        p16 = jnp.exp((logits - m).astype(jnp.bfloat16).astype(jnp.float32))
        p16 = p16.astype(jnp.bfloat16)
        denom = jnp.sum(p16.astype(jnp.float32), axis=-1, keepdims=True)
        probs = (p16.astype(jnp.float32) / denom).astype(q.dtype)
    else:
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)


def attention_reference(q, k, v, *, causal=True, window=None, logit_cap=0.0,
                        q_offset=0, kv_len=None) -> jnp.ndarray:
    """Reference attention, blocked over query chunks for long sequences.

    The score tensor is O(chunk * T) instead of O(S * T); each chunk body is
    checkpointed so the backward pass rematerializes probabilities chunk by
    chunk (the jnp analogue of the Pallas flash kernel's memory behavior).
    """
    b, s, h, dh = q.shape
    if s <= Q_CHUNK or s % Q_CHUNK != 0:
        return _attention_dense(q, k, v, causal=causal, window=window,
                                logit_cap=logit_cap, q_offset=q_offset,
                                kv_len=kv_len)
    nchunk = s // Q_CHUNK
    qc = q.reshape(b, nchunk, Q_CHUNK, h, dh)

    @jax.checkpoint
    def chunk(carry, inp):
        qi, i = inp
        out = _attention_dense(qi, k, v, causal=causal, window=window,
                               logit_cap=logit_cap,
                               q_offset=q_offset + i * Q_CHUNK, kv_len=kv_len)
        return carry, out

    _, out = jax.lax.scan(chunk, 0,
                          (jnp.moveaxis(qc, 1, 0), jnp.arange(nchunk)))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, dh)


# ---------------------------------------------------------------------------
# Full blocks (project -> rope -> attend -> output)
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    b, s, d = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def attn_block(cfg: ModelConfig, p: dict, x: jnp.ndarray, *,
               is_global=None, positions=None, kv: jnp.ndarray | None = None,
               causal: bool = True) -> jnp.ndarray:
    """Self-attention (kv=None) or cross-attention (kv=encoder memory).

    ``is_global``: traced bool scalar choosing full vs sliding-window
    attention for this layer (the gemma-2/3 alternation under scan).
    """
    b, s, d = x.shape
    hd = cfg.hd
    if kv is None:
        q, k, v = _project_qkv(cfg, p, x)
        if positions is None:
            positions = jnp.arange(s)[None, :]
        cos, sin = rotary_embedding(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        window = None
        if cfg.window is not None:
            if is_global is None:
                window = cfg.window
            else:
                window = jnp.where(jnp.asarray(is_global), jnp.int32(2**30),
                                   jnp.int32(cfg.window))
        out = attention_op(q, k, v, causal=causal, window=window,
                           logit_cap=cfg.attn_softcap)
    else:
        t = kv.shape[1]
        q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
        k = (kv @ p["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
        v = (kv @ p["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
        out = attention_op(q, k, v, causal=False, logit_cap=cfg.attn_softcap)
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


def attn_decode_step(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     pos: jnp.ndarray, *, is_global=None):
    """One-token decode with an in-place KV cache update.

    x: (B, 1, D); cache_k/v: (B, T, Hkv, Dh); pos: scalar current position.
    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    b, s, d = x.shape
    hd = cfg.hd
    q, k, v = _project_qkv(cfg, p, x)
    positions = jnp.full((b, 1), pos)
    cos, sin = rotary_embedding(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, pos, 0, 0))
    window = None
    if cfg.window is not None:
        if is_global is None:
            window = cfg.window
        else:
            window = jnp.where(jnp.asarray(is_global), jnp.int32(2**30),
                               jnp.int32(cfg.window))
    out = attention_op(q, cache_k, cache_v, causal=False, window=window,
                       logit_cap=cfg.attn_softcap, q_offset=pos,
                       kv_len=pos + 1)
    out = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    b, s, _ = x.shape
    nh, hd, rd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
    if "w_dq" in p:
        cq = rms_norm(x @ p["w_dq"], p["q_norm"])
        q = (cq @ p["w_uq"]).reshape(b, s, nh, hd + rd)
    else:
        q = (x @ p["wq"]).reshape(b, s, nh, hd + rd)
    return q[..., :hd], q[..., hd:]


def mla_block(cfg: ModelConfig, p: dict, x: jnp.ndarray,
              positions=None) -> jnp.ndarray:
    """Prefill/train path. The latent cache formulation is exercised in the
    decode path; here keys/values are decompressed in full (standard)."""
    b, s, d = x.shape
    nh, hd, r, rd = cfg.n_heads, cfg.hd, cfg.kv_lora_rank, cfg.rope_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x)
    dkv = x @ p["w_dkv"]                       # (b, s, r + rd)
    c_kv = rms_norm(dkv[..., :r], p["kv_norm"])
    k_rope = dkv[..., r:].reshape(b, s, 1, rd)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    cos, sin = rotary_embedding(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, nh, hd)
    v = (c_kv @ p["w_uv"]).reshape(b, s, nh, hd)
    # Concatenate nope|rope components; rope key shared across heads (MQA-like)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, nh, rd))], axis=-1)
    # pad v to q's feature dim for the shared attention op, then slice back
    out = attention_op(q, k, jnp.concatenate(
        [v, jnp.zeros((b, s, nh, rd), v.dtype)], axis=-1), causal=True)
    out = out[..., :hd]
    return out.reshape(b, s, nh * hd) @ p["wo"]


def mla_decode_step(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                    cache_ckv: jnp.ndarray, cache_krope: jnp.ndarray,
                    pos: jnp.ndarray):
    """Latent-cache decode: cache stores (c_kv, k_rope) only — the memory
    advantage of MLA. Keys/values are decompressed against the cache."""
    b, s, d = x.shape
    nh, hd, r, rd = cfg.n_heads, cfg.hd, cfg.kv_lora_rank, cfg.rope_head_dim
    q_nope, q_rope = _mla_q(cfg, p, x)
    dkv = x @ p["w_dkv"]
    c_kv = rms_norm(dkv[..., :r], p["kv_norm"])      # (b, 1, r)
    k_rope = dkv[..., r:].reshape(b, 1, 1, rd)
    positions = jnp.full((b, 1), pos)
    cos, sin = rotary_embedding(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_kv.astype(cache_ckv.dtype), (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, k_rope[:, :, 0].astype(cache_krope.dtype), (0, pos, 0))
    t = cache_ckv.shape[1]
    k_nope = (cache_ckv @ p["w_uk"]).reshape(b, t, nh, hd)
    v = (cache_ckv @ p["w_uv"]).reshape(b, t, nh, hd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(cache_krope[:, :, None, :], (b, t, nh, rd))],
        axis=-1)
    out = attention_op(
        q, k, jnp.concatenate([v, jnp.zeros((b, t, nh, rd), v.dtype)], axis=-1),
        causal=False, q_offset=pos, kv_len=pos + 1)
    out = out[..., :hd]
    out = out.reshape(b, s, nh * hd) @ p["wo"]
    return out, cache_ckv, cache_krope
