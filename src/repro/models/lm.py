"""Unified language-model definition covering all assigned architectures.

A model is a sequence of homogeneous **segments**; each segment is a stack of
identical blocks executed under ``jax.lax.scan`` (stacked-parameter layout,
HLO size independent of depth). Segment kinds:

  * ``dense``   — attention + GLU FFN (gemma/granite/pixtral/llama family),
                  with optional local/global window alternation via per-layer
                  flags (gemma-2: 1:1, gemma-3: 5:1) and logit soft-capping;
  * ``moe``     — attention (GQA or MLA) + mixture-of-experts FFN
                  (mixtral, deepseek-v2 incl. shared experts);
  * ``ssm``     — Mamba-2 SSD blocks (mamba2);
  * ``hybrid``  — Mamba-2 backbone with a single *shared* attention block
                  applied every ``attn_every`` layers (zamba2) — the shared
                  block's parameters live outside the scanned stack and its
                  KV cache is allocated per *application*, not per layer;
  * ``encoder`` — bidirectional attention blocks (seamless encoder);
  * dense/moe decoders may carry **cross-attention** (seamless decoder).

The public API is purely functional: ``init_params``, ``forward``,
``init_cache``, ``prefill``, ``decode_step``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .attention import (
    attn_block,
    attn_decode_step,
    init_attn_params,
    init_mla_params,
    mla_block,
    mla_decode_step,
)
from .common import ModelConfig, cross_entropy_loss, embed_init, rms_norm, shard_hint
from .ffn import ffn_block, init_ffn_params, init_moe_params, moe_block
from .ssm import init_ssm_cache, init_ssm_params, ssm_block, ssm_decode_step

__all__ = [
    "SegmentSpec", "segment_plan", "init_params", "forward", "encode",
    "init_cache", "prefill", "decode_step", "loss_fn", "num_params",
]


# ---------------------------------------------------------------------------
# Segment planning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SegmentSpec:
    kind: str                  # dense | moe | ssm | hybrid | encoder
    count: int
    d_ff: int = 0              # dense FFN width override (deepseek layer 0)
    global_flags: tuple = ()   # per-layer: full-attention layer?
    attn_flags: tuple = ()     # hybrid: apply shared attention after layer i?
    cross: bool = False        # decoder cross-attention
    inner: int = 0             # hybrid_super: ssm layers per shared-attn app


def segment_plan(cfg: ModelConfig) -> list[SegmentSpec]:
    if cfg.family in ("dense", "vlm"):
        return [SegmentSpec("dense", cfg.n_layers,
                            global_flags=tuple(cfg.global_flags()),
                            cross=cfg.cross_attention)]
    if cfg.family == "audio":  # encoder-decoder
        return [SegmentSpec("dense", cfg.n_layers,
                            global_flags=tuple(cfg.global_flags()),
                            cross=True)]
    if cfg.family == "moe":
        segs = []
        if cfg.first_dense_layers:
            segs.append(SegmentSpec("dense", cfg.first_dense_layers,
                                    d_ff=cfg.d_ff,
                                    global_flags=tuple([True] * cfg.first_dense_layers)))
        n_moe = cfg.n_layers - cfg.first_dense_layers
        segs.append(SegmentSpec("moe", n_moe,
                                global_flags=tuple(cfg.global_flags()[cfg.first_dense_layers:])))
        return segs
    if cfg.family == "ssm":
        return [SegmentSpec("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        k = max(1, cfg.attn_every)
        n_super, tail = divmod(cfg.n_layers, k)
        segs = [SegmentSpec("hybrid_super", n_super, inner=k)]
        if tail:
            segs.append(SegmentSpec("ssm", tail))
        return segs
    raise ValueError(f"unknown family {cfg.family}")


def n_attn_apps(cfg: ModelConfig) -> int:
    """Number of shared-attention applications in a hybrid stack."""
    plan = segment_plan(cfg)
    return sum(s.count for s in plan if s.kind == "hybrid_super")


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, spec: SegmentSpec, key) -> dict:
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    d = cfg.d_model
    p: dict = {"norm1": jnp.zeros((d,), dt), "norm2": jnp.zeros((d,), dt)}
    if spec.kind in ("dense", "moe"):
        if cfg.kv_lora_rank:
            p["attn"] = init_mla_params(cfg, ks[0])
        else:
            p["attn"] = init_attn_params(cfg, ks[0])
        if spec.cross:
            p["cross"] = init_attn_params(cfg, ks[1])
            p["norm_cross"] = jnp.zeros((d,), dt)
        if spec.kind == "dense":
            p["ffn"] = init_ffn_params(cfg, ks[2], spec.d_ff or cfg.d_ff)
        else:
            p["moe"] = init_moe_params(cfg, ks[3])
    elif spec.kind == "ssm":
        p["ssm"] = init_ssm_params(cfg, ks[0])
        del p["norm2"]
    elif spec.kind == "hybrid_super":
        # ``inner`` mamba layers (stacked) followed by one application of the
        # shared attention (+FFN) block; norm2/norm3 gate the shared block.
        def one(k):
            return {"norm1": jnp.zeros((d,), dt), "ssm": init_ssm_params(cfg, k)}

        p["inner"] = jax.vmap(one)(jax.random.split(ks[0], spec.inner))
        del p["norm1"]
        p["norm3"] = jnp.zeros((d,), dt)
    else:
        raise ValueError(spec.kind)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    plan = segment_plan(cfg)
    keys = jax.random.split(key, len(plan) + 4)
    params: dict = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), cfg.jdtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[1], (cfg.vocab_size, cfg.d_model), cfg.jdtype)
    segs = []
    for i, spec in enumerate(plan):
        sk = jax.random.split(keys[2 + i], spec.count)
        segs.append(jax.vmap(lambda k: _init_block(cfg, spec, k))(sk))
    params["segments"] = segs
    if cfg.family == "hybrid":
        # Zamba2's single shared transformer block (attention + FFN),
        # reused at every flagged layer.
        params["shared_attn"] = init_attn_params(cfg, keys[-1])
        if cfg.d_ff:
            params["shared_ffn"] = init_ffn_params(
                cfg, jax.random.fold_in(keys[-1], 1), cfg.d_ff)
    if cfg.n_encoder_layers:
        enc_spec = SegmentSpec("dense", cfg.n_encoder_layers,
                               global_flags=tuple([True] * cfg.n_encoder_layers))
        ek = jax.random.split(keys[-2], cfg.n_encoder_layers)
        params["encoder"] = {
            "segment": jax.vmap(lambda k: _init_block(cfg, enc_spec, k))(ek),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
        }
    return params


def num_params(params) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params)))


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _block_train(cfg: ModelConfig, spec: SegmentSpec, p: dict, x, flag,
                 memory, shared_attn):
    """One block body (runs under scan; ``flag`` is this layer's flag)."""
    aux = jnp.float32(0.0)
    if spec.kind in ("dense", "moe"):
        h = rms_norm(x, p["norm1"])
        if cfg.kv_lora_rank:
            a = mla_block(cfg, p["attn"], h)
        else:
            a = attn_block(cfg, p["attn"], h, is_global=flag)
        x = x + a
        if spec.cross and memory is not None:
            h = rms_norm(x, p["norm_cross"])
            x = x + attn_block(cfg, p["cross"], h, kv=memory)
        h = rms_norm(x, p["norm2"])
        if spec.kind == "dense":
            x = x + ffn_block(cfg, p["ffn"], h)
        else:
            out, aux = moe_block(cfg, p["moe"], h)
            x = x + out
    elif spec.kind == "ssm":
        x = x + ssm_block(cfg, p["ssm"], rms_norm(x, p["norm1"]))
    elif spec.kind == "hybrid_super":
        for j in range(spec.inner):
            pj = jax.tree.map(lambda a: a[j], p["inner"])
            x = x + ssm_block(cfg, pj["ssm"], rms_norm(x, pj["norm1"]))
        h = rms_norm(x, p["norm2"])
        x = x + attn_block(cfg, shared_attn["attn"], h)
        if "ffn" in shared_attn:
            x = x + ffn_block(cfg, shared_attn["ffn"], rms_norm(x, p["norm3"]))
    x = shard_hint(x, "btd")
    return x, aux


def _run_segment(cfg: ModelConfig, spec: SegmentSpec, seg_params, x,
                 memory=None, shared_attn=None, remat: bool = True):
    if spec.global_flags:
        flags = jnp.asarray(spec.global_flags)
    else:
        flags = jnp.ones((spec.count,), bool)

    def body(x, inp):
        p, flag = inp
        return _block_train(cfg, spec, p, x, flag, memory, shared_attn)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxes = jax.lax.scan(body, x, (seg_params, flags))
    return x, jnp.sum(auxes)


def _embed_tokens(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def encode(cfg: ModelConfig, params, frontend_embeds, remat: bool = True):
    """Encoder stack over precomputed frontend embeddings (audio stub)."""
    enc = params["encoder"]
    spec = SegmentSpec("dense", cfg.n_encoder_layers,
                       global_flags=tuple([True] * cfg.n_encoder_layers))
    x = frontend_embeds.astype(cfg.jdtype)

    def body(x, p):
        h = rms_norm(x, p["norm1"])
        x = x + attn_block(cfg, p["attn"], h, causal=False)
        h = rms_norm(x, p["norm2"])
        x = x + ffn_block(cfg, p["ffn"], h)
        return x, jnp.float32(0.0)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, enc["segment"])
    return rms_norm(x, enc["final_norm"])


def _backbone(cfg: ModelConfig, params, tokens, *, embeds=None, memory=None,
              remat: bool = True):
    """Embed -> segments -> final norm; returns (x_text, aux_loss)."""
    x = _embed_tokens(cfg, params, tokens)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    x = shard_hint(x, "btd")
    aux_total = jnp.float32(0.0)
    shared = None
    if "shared_attn" in params:
        shared = {"attn": params["shared_attn"]}
        if "shared_ffn" in params:
            shared["ffn"] = params["shared_ffn"]
    for spec, seg in zip(segment_plan(cfg), params["segments"]):
        x, aux = _run_segment(cfg, spec, seg, x, memory=memory,
                              shared_attn=shared, remat=remat)
        aux_total = aux_total + aux
    x = rms_norm(x, params["final_norm"])
    if embeds is not None:
        x = x[:, embeds.shape[1]:]  # only text positions produce logits
    return x, aux_total


def _head(cfg: ModelConfig, params, x):
    unembed = params.get("unembed", params["embed"])
    logits = shard_hint(x @ unembed.T.astype(x.dtype), "btv")
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def forward(cfg: ModelConfig, params, tokens, *, embeds=None, memory=None,
            remat: bool = True):
    """Full-sequence forward. Returns (logits, aux_loss).

    ``embeds``: precomputed modality embeddings prepended to the token
    embeddings (VLM patch embeddings). ``memory``: encoder output for
    cross-attention (audio/enc-dec).
    """
    x, aux_total = _backbone(cfg, params, tokens, embeds=embeds,
                             memory=memory, remat=remat)
    return _head(cfg, params, x), aux_total


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = True):
    from .tuning import get_tuning

    nchunk = get_tuning().ce_chunk
    s = batch["tokens"].shape[1]
    if nchunk and s % nchunk == 0 and batch.get("mask") is None:
        # Chunked head: never materializes the full (B, S, V) logits — the
        # live f32 logit buffers shrink by n_chunks (§Perf iteration C1).
        x, aux = _backbone(cfg, params, batch["tokens"],
                           embeds=batch.get("embeds"),
                           memory=batch.get("memory"), remat=remat)
        b = x.shape[0]
        c = s // nchunk
        xs = jnp.moveaxis(x.reshape(b, nchunk, c, -1), 1, 0)
        ls = jnp.moveaxis(batch["labels"].reshape(b, nchunk, c), 1, 0)

        @jax.checkpoint
        def chunk_loss(carry, inp):
            xc, lc = inp
            logits = _head(cfg, params, xc)
            return carry + cross_entropy_loss(logits, lc) * lc.size, None

        total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (xs, ls))
        ce = total / (b * s)
    else:
        logits, aux = forward(
            cfg, params, batch["tokens"],
            embeds=batch.get("embeds"), memory=batch.get("memory"),
            remat=remat)
        ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------

def _attn_cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.kv_lora_rank:
        return {
            "ckv": (batch, max_len, cfg.kv_lora_rank),
            "krope": (batch, max_len, cfg.rope_head_dim),
        }
    return {
        "k": (batch, max_len, cfg.n_kv_heads, cfg.hd),
        "v": (batch, max_len, cfg.n_kv_heads, cfg.hd),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Allocate the decode cache pytree (zeros).

    Hybrid models allocate the shared-attention KV cache per *application*
    (``n_attn_apps``), not per layer — 6x smaller for zamba2.
    """
    dtype = dtype or cfg.jdtype
    caches = []
    for spec in segment_plan(cfg):
        if spec.kind in ("dense", "moe"):
            shapes = _attn_cache_shapes(cfg, batch, max_len)
            caches.append({k: jnp.zeros((spec.count,) + s, dtype)
                           for k, s in shapes.items()})
        elif spec.kind == "ssm":
            c = init_ssm_cache(cfg, batch, dtype)
            caches.append(jax.tree.map(
                lambda a: jnp.zeros((spec.count,) + a.shape, a.dtype), c))
        elif spec.kind == "hybrid_super":
            # inner ssm caches stacked (count, inner, ...); one shared-attn
            # KV cache slot per super-block application.
            c = init_ssm_cache(cfg, batch, dtype)
            out = {"inner": jax.tree.map(
                lambda a: jnp.zeros((spec.count, spec.inner) + a.shape, a.dtype), c)}
            out["attn_k"] = jnp.zeros(
                (spec.count, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
            out["attn_v"] = jnp.zeros(
                (spec.count, batch, max_len, cfg.n_kv_heads, cfg.hd), dtype)
            caches.append(out)
    return {"segments": caches, "pos": jnp.zeros((), jnp.int32)}


def _block_decode(cfg: ModelConfig, spec: SegmentSpec, p, cache_l, x, flag,
                  pos, memory, shared_attn, app_idx):
    """One block of the decode step; returns (x, new_cache_l)."""
    if spec.kind in ("dense", "moe"):
        h = rms_norm(x, p["norm1"])
        if cfg.kv_lora_rank:
            a, ckv, krope = mla_decode_step(cfg, p["attn"], h,
                                            cache_l["ckv"], cache_l["krope"], pos)
            new_cache = {"ckv": ckv, "krope": krope}
        else:
            a, ck, cv = attn_decode_step(cfg, p["attn"], h, cache_l["k"],
                                         cache_l["v"], pos, is_global=flag)
            new_cache = {"k": ck, "v": cv}
        x = x + a
        if spec.cross and memory is not None:
            h = rms_norm(x, p["norm_cross"])
            x = x + attn_block(cfg, p["cross"], h, kv=memory)
        h = rms_norm(x, p["norm2"])
        if spec.kind == "dense":
            x = x + ffn_block(cfg, p["ffn"], h)
        else:
            out, _ = moe_block(cfg, p["moe"], h)
            x = x + out
        return x, new_cache, app_idx
    if spec.kind == "ssm":
        out, new_c = ssm_decode_step(cfg, p["ssm"], rms_norm(x, p["norm1"]),
                                     cache_l)
        return x + out, new_c, app_idx
    if spec.kind == "hybrid_super":
        new_inner = []
        for j in range(spec.inner):
            pj = jax.tree.map(lambda a: a[j], p["inner"])
            cj = jax.tree.map(lambda a: a[j], cache_l["inner"])
            out, new_c = ssm_decode_step(cfg, pj["ssm"],
                                         rms_norm(x, pj["norm1"]), cj)
            x = x + out
            new_inner.append(new_c)
        h = rms_norm(x, p["norm2"])
        a, ck, cv = attn_decode_step(cfg, shared_attn["attn"], h,
                                     cache_l["attn_k"], cache_l["attn_v"], pos)
        x = x + a
        if "ffn" in shared_attn:
            x = x + ffn_block(cfg, shared_attn["ffn"], rms_norm(x, p["norm3"]))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_inner)
        return x, {"inner": stacked, "attn_k": ck, "attn_v": cv}, app_idx
    raise ValueError(spec.kind)


def decode_step(cfg: ModelConfig, params, cache, tokens, *, memory=None):
    """One-token decode. tokens: (B, 1). Returns (logits, new_cache)."""
    pos = cache["pos"]
    x = shard_hint(_embed_tokens(cfg, params, tokens), "b1d")
    shared = None
    if "shared_attn" in params:
        shared = {"attn": params["shared_attn"]}
        if "shared_ffn" in params:
            shared["ffn"] = params["shared_ffn"]
    new_segs = []
    for spec, seg, seg_cache in zip(segment_plan(cfg), params["segments"],
                                    cache["segments"]):
        if spec.global_flags:
            flags = jnp.asarray(spec.global_flags)
        else:
            flags = jnp.ones((spec.count,), bool)

        def body(x, inp):
            p, flag, cl = inp
            x, new_c, _ = _block_decode(
                cfg, spec, p, cl, x, flag, pos, memory, shared, 0)
            return x, new_c

        x, new_cache = jax.lax.scan(body, x, (seg, flags, seg_cache))
        new_segs.append(new_cache)

    x = rms_norm(x, params["final_norm"])
    unembed = params.get("unembed", params["embed"])
    logits = shard_hint(x @ unembed.T.astype(x.dtype), "btv")
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, {"segments": new_segs, "pos": pos + 1}


def prefill(cfg: ModelConfig, params, tokens, *, embeds=None, memory=None,
            max_len: int | None = None):
    """Run the prompt through the model, producing a primed cache.

    Implemented as repeated single-token decode under ``lax.scan`` over the
    prompt — compact HLO and exactly consistent with the decode path. For
    high-throughput prefill use ``forward`` + cache extraction (roadmap).
    """
    b, s = tokens.shape
    max_len = max_len or (s + 64)
    cache = init_cache(cfg, b, max_len)

    def body(cache, tok):
        logits, cache = decode_step(cfg, params, cache, tok[:, None],
                                    memory=memory)
        return cache, logits[:, 0]

    cache, logits = jax.lax.scan(body, cache, jnp.moveaxis(tokens, 1, 0))
    return jnp.moveaxis(logits, 0, 1), cache
