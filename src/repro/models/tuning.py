"""Performance-tuning knobs (the §Perf hillclimb registry).

Global, mutable, *explicitly recorded* knobs — every dry-run report states
the tuning fingerprint so baselines and optimized variants are never mixed
(the paper's factor discipline applied to ourselves).

Knobs (all default to the paper-faithful/baseline behavior):

  * ``moe_defer_combine_psum`` — drop the sharding hint on the MoE output
    buffer so GSPMD can defer the model-axis reduction until *after* the
    combine gather (reduces the reduced tensor from (B,E,C,D) to (B,S,D)).
  * ``ce_chunk`` — compute the cross-entropy over sequence chunks
    (bounds the f32 logit buffers: live set /= n_chunks).
  * ``attn_additive_mask`` — apply attention masks as an additive bias
    fused into the scale instead of a separate ``where`` pass.
  * ``attn_probs_bf16`` — cast softmax numerator/denominator intermediates
    to bf16 before the HBM round-trip (kernel-adjacent traffic halving).
"""

from __future__ import annotations

from dataclasses import dataclass, asdict, replace

__all__ = ["Tuning", "get_tuning", "set_tuning", "reset_tuning", "tuning_tag"]


@dataclass
class Tuning:
    moe_defer_combine_psum: bool = False
    moe_vmap_dispatch: bool = False    # batched scatter/gather (GSPMD keeps
                                       # the batch dim sharded; avoids the
                                       # full-batch all-reduce fallback)
    ce_chunk: int = 0
    attn_additive_mask: bool = False
    attn_probs_bf16: bool = False
    norm_bf16_io: bool = False         # rms_norm keeps x in bf16; only the
                                       # variance reduction accumulates f32


_TUNING = Tuning()


def get_tuning() -> Tuning:
    return _TUNING


def set_tuning(**kw) -> Tuning:
    global _TUNING
    _TUNING = replace(_TUNING, **kw)
    return _TUNING


def reset_tuning() -> Tuning:
    global _TUNING
    _TUNING = Tuning()
    return _TUNING


def tuning_tag() -> str:
    d = asdict(_TUNING)
    on = [f"{k}={v}" for k, v in d.items() if v not in (False, 0)]
    return ",".join(on) if on else "baseline"
