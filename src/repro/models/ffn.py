"""Feed-forward blocks: gated-linear-unit FFNs and Mixture-of-Experts.

The MoE uses a scatter/gather dispatch with per-expert capacity (GShard
style, capacity factor configurable): FLOP-faithful (expert compute is
``2 * E * C * D * F`` batched matmuls = ``~cf * k * tokens`` worth of expert
work) and shardable (experts over the ``model``/expert axis, tokens over
``data``). Dropped tokens fall back to the residual path, as in Switch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig, activation_fn, dense_init, shard_hint

__all__ = ["init_ffn_params", "ffn_block", "init_moe_params", "moe_block"]


def init_ffn_params(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    p = {
        "w_up": dense_init(ks[1], (cfg.d_model, d_ff), dt),
        "w_down": dense_init(ks[2], (d_ff, cfg.d_model), dt),
    }
    if cfg.glu:
        p["w_gate"] = dense_init(ks[0], (cfg.d_model, d_ff), dt)
    return p


def ffn_block(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    act = activation_fn(cfg.act)
    if "w_gate" in p:
        return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return act(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def init_moe_params(cfg: ModelConfig, key) -> dict:
    d_ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    dt = cfg.jdtype
    p = {
        "router": dense_init(ks[0], (cfg.d_model, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, cfg.d_model, d_ff), dt, in_axis=1),
        "w_up": dense_init(ks[2], (e, cfg.d_model, d_ff), dt, in_axis=1),
        "w_down": dense_init(ks[3], (e, d_ff, cfg.d_model), dt, in_axis=1),
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.n_shared_experts * d_ff
        sub = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sub[0], (cfg.d_model, shared_ff), dt),
            "w_up": dense_init(sub[1], (cfg.d_model, shared_ff), dt),
            "w_down": dense_init(sub[2], (shared_ff, cfg.d_model), dt),
        }
    return p


def moe_block(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed experts, capacity-based scatter dispatch.

    x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Two dispatch regimes (both FLOP-faithful: expert matmul work =
    ``capacity_factor * k * tokens * D * F``):

      * **grouped** (training/prefill, S > 64): each sequence is a dispatch
        group — the scatter/gather and position cumsum stay *local to the
        batch shard* under data parallelism, per-group capacity
        ``cf * k * S / E`` (GShard-style groups == data shards);
      * **global** (decode, S <= 64): all B tokens form one group with a
        small (E, C, D) buffer; cross-shard scatter is a cheap collective
        at decode sizes.

    Dropped tokens (over capacity) fall back to the residual path (Switch).
    aux_loss is the Switch/GShard load-balancing loss.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    act = activation_fn(cfg.act)

    gate_logits = (x.astype(jnp.float32) @ p["router"])             # (B,S,E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                            # (B,S,k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # Load-balancing auxiliary loss (Switch eq. 4).
    density = jnp.mean(
        jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * e

    grouped = s > 64
    if grouped:
        capacity = max(int(np.ceil(cfg.capacity_factor * k * s / e)), 4)
        flat_e = topi.reshape(b, s * k)                             # (B, S*k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (B,S*k,E)
        pos_all = jnp.cumsum(onehot, axis=1) - onehot               # exclusive
        pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=2)[..., 0]
        valid = pos < capacity
        pos_c = jnp.where(valid, pos, capacity - 1)
        src = jnp.repeat(x, k, axis=1)                              # (B,S*k,D)
        src = jnp.where(valid[..., None], src, 0)
        from .tuning import get_tuning
        tune = get_tuning()
        if tune.moe_vmap_dispatch:
            # Batched scatter/gather: the scatter indices are per-sequence,
            # so vmapping over B emits operand-batching-dims scatter/gather
            # HLO that GSPMD partitions along the (data-sharded) batch dim —
            # without this it falls back to a full-batch f32 all-reduce of
            # the (B, S*k, D) buffers per layer (96 GiB/layer on mixtral).
            def _scatter_one(src_1, fe_1, pc_1):
                z = jnp.zeros((e, capacity, d), dtype=x.dtype)
                return z.at[fe_1, pc_1].add(src_1, mode="drop")

            buf = jax.vmap(_scatter_one)(src, flat_e, pos_c)
        else:
            bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, s * k))
            buf = jnp.zeros((b, e, capacity, d), dtype=x.dtype)
            buf = buf.at[bidx, flat_e, pos_c].add(src, mode="drop")
        buf = shard_hint(buf, "becd")
        h = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * \
            jnp.einsum("becd,edf->becf", buf, p["w_up"])
        out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
        if not tune.moe_defer_combine_psum:
            out_buf = shard_hint(out_buf, "becd")
        if tune.moe_vmap_dispatch:
            gathered = jax.vmap(lambda ob, fe, pc: ob[fe, pc])(
                out_buf, flat_e, pos_c)
        else:
            gathered = out_buf[bidx, flat_e, pos_c]                 # (B,S*k,D)
        gathered = jnp.where(valid[..., None], gathered, 0)
        out = jnp.sum(
            gathered.reshape(b, s, k, d)
            * topw[..., None].astype(gathered.dtype), axis=2)
    else:
        t = b * s
        tokens = x.reshape(t, d)
        capacity = max(int(np.ceil(cfg.capacity_factor * k * t / e)), 4)
        flat_e = topi.reshape(t * k)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos_all = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
        valid = pos < capacity
        pos_c = jnp.where(valid, pos, capacity - 1)
        src = jnp.repeat(tokens, k, axis=0)
        src = jnp.where(valid[:, None], src, 0)
        buf = jnp.zeros((e, capacity, d), dtype=tokens.dtype)
        buf = buf.at[flat_e, pos_c].add(src, mode="drop")
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
        gathered = out_buf[flat_e, pos_c]
        gathered = jnp.where(valid[:, None], gathered, 0)
        out = jnp.sum(
            gathered.reshape(t, k, d) * topw.reshape(t, k)[..., None]
            .astype(gathered.dtype), axis=1).reshape(b, s, d).reshape(t, d)
        out = out.reshape(b, s, d)
    out = out.reshape(b, s, d)

    if cfg.n_shared_experts:
        sp = p["shared"]
        out = out + (act(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]

    return out, aux
