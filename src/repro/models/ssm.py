"""Mamba-2 state-space blocks via SSD (state-space duality) [arXiv:2405.21060].

Chunked algorithm: within-chunk attention-like quadratic term + cross-chunk
linear state recurrence — the TPU-friendly decomposition (dense matmuls for
the MXU inside chunks, a short ``lax.scan`` across chunks). The same math is
implemented as a Pallas kernel in ``repro.kernels.ssd_scan`` with this module
as its oracle.

Projections for z / x / B / C / dt are stored as separate matrices (rather
than one fused ``in_proj``) so tensor parallelism can shard the ``d_inner``
and head dimensions cleanly over the ``model`` mesh axis without resharding
at the split points; the depthwise convs are likewise separate per stream.

Decode maintains an O(1) recurrent state — why the SSM/hybrid architectures
are the ones that run the ``long_500k`` shape (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, rms_norm

__all__ = [
    "init_ssm_params",
    "ssm_block",
    "ssm_decode_step",
    "init_ssm_cache",
    "ssd_chunked",
]

CONV_WIDTH = 4


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    hd = cfg.ssm_head_dim
    nh = cfg.ssm_heads or d_inner // hd
    n = cfg.ssm_state
    return d_inner, hd, nh, n


def init_ssm_params(cfg: ModelConfig, key) -> dict:
    d_inner, hd, nh, n = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    dt = cfg.jdtype

    def conv(k, dim):
        return (jax.random.normal(k, (CONV_WIDTH, dim), jnp.float32) * 0.1).astype(dt)

    return {
        "w_z": dense_init(ks[0], (d, d_inner), dt),
        "w_x": dense_init(ks[1], (d, d_inner), dt),
        "w_b": dense_init(ks[2], (d, n), dt),
        "w_c": dense_init(ks[3], (d, n), dt),
        "w_dt": dense_init(ks[4], (d, nh), dt),
        "conv_x": conv(ks[5], d_inner),
        "conv_b": conv(ks[6], n),
        "conv_c": conv(jax.random.fold_in(key, 7), n),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dt),
        "out_proj": dense_init(jax.random.fold_in(key, 8), (d_inner, d), dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv (width CONV_WIDTH) via shifted adds + SiLU."""
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(CONV_WIDTH):
        shift = CONV_WIDTH - 1 - i
        shifted = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :s]
        out = out + shifted * w[i]
    return jax.nn.silu(out)


def ssd_chunked(x, dta, B, C, chunk: int):
    """Chunked SSD scan.

    x:   (b, s, h, p)   per-head inputs (dt already folded in by caller)
    dta: (b, s, h)      dt * A  (negative)
    B,C: (b, s, n)      input/output projections (single group)
    Returns y (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    c = s // chunk
    l = chunk
    xr = x.reshape(b, c, l, h, p)
    ar = dta.reshape(b, c, l, h)
    Br = B.reshape(b, c, l, n)
    Cr = C.reshape(b, c, l, n)

    cs = jnp.cumsum(ar, axis=2)                       # (b,c,l,h) inclusive
    last = cs[:, :, -1:, :]                           # (b,c,1,h)

    # ---- intra-chunk (quadratic in l) -----------------------------------
    # decay(t, s) = exp(cs_t - cs_s) for s <= t
    dec = cs[:, :, :, None, :] - cs[:, :, None, :, :]          # (b,c,t,s,h)
    mask = jnp.tril(jnp.ones((l, l), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    dec = jnp.exp(dec)
    g = jnp.einsum("bctn,bcsn->bcts", Cr, Br)                  # (b,c,t,s)
    m = (g[..., None] * dec).astype(x.dtype)                   # (b,c,t,s,h)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m, xr)

    # ---- chunk boundary states ------------------------------------------
    w = jnp.exp(last - cs).astype(x.dtype)                     # (b,c,l,h)
    states = jnp.einsum("bclh,bcln,bclhp->bchpn", w, Br, xr)   # (b,c,h,p,n)

    # ---- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(last[:, :, 0, :])                    # (b,c,h)

    def step(carry, inp):
        st, cd = inp                                           # (b,h,p,n), (b,h)
        new = carry * cd[:, :, None, None].astype(carry.dtype) + st
        return new, carry                                      # emit pre-chunk state

    init = jnp.zeros((b, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (b,c,h,p,n)

    y_inter = jnp.einsum("bctn,bchpn,bcth->bcthp",
                         Cr, prev_states, jnp.exp(cs).astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def ssm_block(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Full Mamba-2 block: projections -> conv -> SSD -> gated norm -> out."""
    d_inner, hd, nh, n = _dims(cfg)
    b, s, _ = x.shape
    z = x @ p["w_z"]
    xin = _causal_conv(x @ p["w_x"], p["conv_x"])
    B = _causal_conv(x @ p["w_b"], p["conv_b"])
    C = _causal_conv(x @ p["w_c"], p["conv_c"])
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                           # (nh,)
    dta = dt * a                                                       # (b,s,nh)
    xh = xin.reshape(b, s, nh, hd)
    xdt = xh * dt[..., None].astype(xh.dtype)
    y, _ = ssd_chunked(xdt, dta, B, C, cfg.ssm_chunk)
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode path: O(1) recurrent state
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_inner, hd, nh, n = _dims(cfg)
    return {
        "state": jnp.zeros((batch, nh, hd, n), dtype),
        "conv_x": jnp.zeros((batch, CONV_WIDTH - 1, d_inner), dtype),
        "conv_b": jnp.zeros((batch, CONV_WIDTH - 1, n), dtype),
        "conv_c": jnp.zeros((batch, CONV_WIDTH - 1, n), dtype),
    }


def _conv_step(cache_win, new, w):
    win = jnp.concatenate([cache_win, new[:, None]], axis=1)   # (b, 4, dim)
    out = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, w))
    return out, win[:, 1:]


def ssm_decode_step(cfg: ModelConfig, p: dict, x: jnp.ndarray, cache: dict):
    """One-token step. x: (b, 1, d). Returns (out, new_cache)."""
    d_inner, hd, nh, n = _dims(cfg)
    b = x.shape[0]
    x0 = x[:, 0]
    z = x0 @ p["w_z"]
    xin, cx = _conv_step(cache["conv_x"], x0 @ p["w_x"], p["conv_x"])
    B, cb = _conv_step(cache["conv_b"], x0 @ p["w_b"], p["conv_b"])
    C, cc = _conv_step(cache["conv_c"], x0 @ p["w_c"], p["conv_c"])
    dt = jax.nn.softplus((x0 @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                      # (b, nh)
    xh = xin.reshape(b, nh, hd)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, B, dt.astype(xh.dtype))
    state = cache["state"] * decay[:, :, None, None].astype(xh.dtype) + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C)
    y = y + xh * p["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(b, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["out_proj"])[:, None]
    return out, {"state": state, "conv_x": cx, "conv_b": cb, "conv_c": cc}
