"""Model zoo: unified LM covering dense / MoE / SSM / hybrid / VLM / enc-dec."""

from .common import ModelConfig, cross_entropy_loss
from .lm import (
    SegmentSpec,
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    loss_fn,
    num_params,
    prefill,
    segment_plan,
)

__all__ = [
    "ModelConfig", "cross_entropy_loss", "SegmentSpec", "segment_plan",
    "init_params", "forward", "encode", "init_cache", "prefill",
    "decode_step", "loss_fn", "num_params",
]
