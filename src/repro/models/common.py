"""Shared model components: configs, norms, rotary embeddings, init helpers.

All models are pure-functional JAX: parameters are pytrees of arrays with a
leading stacked-layer axis so the layer stack runs under ``jax.lax.scan``
(keeps HLO size O(1) in depth — essential for the 512-device dry-run).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ModelConfig",
    "rms_norm",
    "rotary_embedding",
    "apply_rope",
    "softcap",
    "activation_fn",
    "dense_init",
    "embed_init",
    "cross_entropy_loss",
    "with_layer_axis",
]


@dataclass(frozen=True)
class ModelConfig:
    """One config dataclass covers every assigned architecture family; the
    per-arch modules read only the fields relevant to their family."""

    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1000
    head_dim: int | None = None     # default d_model // n_heads
    # --- attention pattern -------------------------------------------------
    window: int | None = None       # sliding-window size for local layers
    global_every: int = 0           # 0: all layers global; k: layer i is
                                    # global iff (i+1) % k == 0 (gemma3 5:1)
    attn_softcap: float = 0.0       # attention logit soft-capping (gemma2)
    final_softcap: float = 0.0      # final-logit soft-capping (gemma2)
    rope_theta: float = 10000.0
    act: str = "swiglu"             # swiglu | geglu
    glu: bool = True                # gated FFN (3 matrices) vs plain MLP (2)
    qk_norm: bool = False
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0               # routed-expert hidden size (deepseek)
    first_dense_layers: int = 0     # deepseek: first layer(s) stay dense
    # --- MLA (deepseek) ----------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    # --- SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0              # mamba2 heads (d_inner // head_dim)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0             # hybrid: shared attn block every k layers
    # --- embeddings / misc ---------------------------------------------------
    tie_embeddings: bool = True
    embed_scale: bool = False       # gemma multiplies embeddings by sqrt(D)
    # --- encoder-decoder -----------------------------------------------------
    n_encoder_layers: int = 0
    cross_attention: bool = False
    # --- modality frontend (stub) -------------------------------------------
    frontend: str | None = None     # 'vision' | 'audio'
    frontend_tokens: int = 0        # precomputed embedding positions per item
    dtype: str = "bfloat16"

    # --------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def is_global_layer(self, i: int) -> bool:
        if self.global_every < 0:
            return False          # all layers sliding-window (Mixtral SWA)
        if self.global_every == 0 or self.window is None:
            return True
        return (i + 1) % self.global_every == 0

    def global_flags(self) -> np.ndarray:
        return np.array([self.is_global_layer(i) for i in range(self.n_layers)])

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D)."""
        return int(_param_count(self))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k routed)."""
        return int(_param_count(self, active_only=True))


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    return (3 if cfg.glu else 2) * cfg.d_model * d_ff


def _attn_params(cfg: ModelConfig) -> int:
    if cfg.kv_lora_rank:  # MLA
        d = cfg.d_model
        r = cfg.kv_lora_rank
        qr = cfg.q_lora_rank or d
        nh, hd, rd = cfg.n_heads, cfg.hd, cfg.rope_head_dim
        q = d * qr + qr * nh * (hd + rd)
        kv = d * (r + rd) + r * nh * (hd + hd)
        o = nh * hd * d
        return q + kv + o
    hd = cfg.hd
    return cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * cfg.d_model


def _ssm_params(cfg: ModelConfig) -> int:
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or max(1, d_inner // cfg.ssm_head_dim)
    # in_proj: z, x, B, C, dt ; out_proj
    d_bc = 2 * cfg.ssm_state * nh if False else 2 * cfg.ssm_state
    in_proj = cfg.d_model * (2 * d_inner + 2 * cfg.ssm_state + nh)
    out_proj = d_inner * cfg.d_model
    return in_proj + out_proj + d_inner  # + conv/bias-ish small terms


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model  # embeddings (tied: counted once)
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model
    per_layer = 0
    if cfg.family in ("ssm",):
        per_layer = _ssm_params(cfg)
        n += cfg.n_layers * per_layer
        return n
    if cfg.family == "hybrid":
        n += cfg.n_layers * _ssm_params(cfg)
        n_attn = cfg.n_layers // max(1, cfg.attn_every)
        n += _attn_params(cfg)  # ONE shared attention block (zamba2)
        n += n_attn * 2 * cfg.d_model  # per-use norms
        return n
    layers = cfg.n_layers + cfg.n_encoder_layers
    attn = _attn_params(cfg)
    if cfg.n_experts:
        d_ff_routed = cfg.moe_d_ff or cfg.d_ff
        router = cfg.d_model * cfg.n_experts
        shared = cfg.n_shared_experts * _ffn_params(cfg, d_ff_routed)
        n_dense = cfg.first_dense_layers
        n_moe = cfg.n_layers - n_dense
        experts_total = cfg.n_experts * _ffn_params(cfg, d_ff_routed)
        experts_active = cfg.moe_top_k * _ffn_params(cfg, d_ff_routed)
        dense_ffn = _ffn_params(cfg, cfg.d_ff if not cfg.moe_d_ff else cfg.n_experts * 0 + cfg.d_ff)
        n += n_dense * (attn + dense_ffn)
        n += n_moe * (attn + router + shared + (experts_active if active_only else experts_total))
        if cfg.cross_attention:
            n += cfg.n_layers * attn
        return n
    ffn = _ffn_params(cfg, cfg.d_ff)
    n += layers * (attn + ffn)
    if cfg.cross_attention:
        n += cfg.n_layers * attn  # decoder cross-attention blocks
    return n


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

_HINT_SPECS = {
    # activations (B, S, D): batch over the data axes
    "btd": (("pod", "data"), None, None),
    # logits (B, S, V): batch over data, vocab over model
    "btv": (("pod", "data"), None, "model"),
    # decode activations (B, 1, D)
    "b1d": (("pod", "data"), None, None),
    # MoE dispatch buffers (B, E, C, D): batch over data, experts over model
    "becd": (("pod", "data"), "model", None, None),
}


def shard_hint(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Best-effort activation sharding constraint.

    GSPMD does not reliably propagate the batch sharding through scanned
    layer stacks and the tied-embedding logit matmul (observed: full-batch
    f32 logit buffers per device). These hints pin the canonical layout:
    batch over ``("pod","data")``, vocab over ``"model"``. Outside a mesh
    context (unit tests, single device) they are no-ops.
    """
    from jax.sharding import PartitionSpec as P

    full = _HINT_SPECS[kind]
    for spec in (P(*full), P(("data",) if isinstance(full[0], tuple) else full[0],
                            *full[1:])):
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except Exception:
            continue
    return x


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    from .tuning import get_tuning

    if get_tuning().norm_bf16_io and x.dtype == jnp.bfloat16:
        # keep the (B, S, D) stream in bf16; f32 only inside the reduction
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        scale = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
        return x * scale * (1.0 + gamma.astype(jnp.float32)).astype(x.dtype)
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def rotary_embedding(positions: jnp.ndarray, dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given positions; shapes (..., dim//2)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (np.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def activation_fn(name: str):
    if name in ("swiglu", "silu"):
        return jax.nn.silu
    if name in ("geglu", "gelu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jnp.ndarray:
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def with_layer_axis(init_fn, n_layers: int, key):
    """vmap an init over a leading stacked-layer axis."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-mean cross entropy; logits (..., V), labels (...).

    Sharded-vocab-safe: never gathers along the vocab axis (which may be
    sharded over the ``model`` mesh axis). The gold logit is extracted with
    a fused one-hot reduction (partial-sum + all-reduce under GSPMD) instead
    of ``take_along_axis`` (which would force a full vocab all-gather —
    67 GB/device for gemma-scale vocabularies).
    """
    v = logits.shape[-1]
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    onehot = (labels[..., None] == jnp.arange(v)[None, :]).astype(jnp.float32)
    gold = jnp.sum(shifted * onehot, axis=-1)
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
