"""Deterministic synthetic data pipeline with host sharding and prefetch.

Production layout: each host materializes only its shard of the global
batch (``host_index / host_count``), generated counter-based (stateless) so
restarts are exactly reproducible from the step number alone — the data
analogue of the paper's reproducibility requirement, and what makes
checkpoint/restart byte-identical.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "Prefetcher"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 1234
    host_index: int = 0
    host_count: int = 1


class SyntheticLM:
    """Markov-ish synthetic token stream (counter-based, stateless).

    ``batch_at(step)`` is a pure function of (config, step) — no iterator
    state to checkpoint. Labels are next-token shifted.
    """

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
        b, s = self.local_batch, cfg.seq_len
        # structured stream: random walk over the vocab with resets, so the
        # model has something learnable (tests train loss down on this)
        start = rng.integers(0, cfg.vocab_size, size=(b, 1))
        steps = rng.integers(-3, 4, size=(b, s))
        toks = (start + np.cumsum(steps, axis=1)) % cfg.vocab_size
        toks = toks.astype(np.int32)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        return {"tokens": toks, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (compute/data overlap on the host)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)
