"""JIT-compiled windowed-measurement engine (the ``engine="jax"`` path).

One measurement call lowers to two jitted programs:

  * ``sample`` — per cost-model term: draw the AR(1) innovations and
    mixture uniforms, run the linear recurrence as a
    ``lax.associative_scan`` over affine maps ``(a, b)`` (composition
    ``(a1, b1) ∘ (a2, b2) = (a1 a2, b1 a2 + b2)`` is associative, so the
    scan is exact, not an approximation), and apply the
    lognormal/bimodal-tail/spike mixture — the jnp reference of the
    optional fused Pallas kernel in :mod:`repro.kernels.sim_scan`;
  * ``window`` — deadline conversion, the cross-call entry recurrence
    ``all_in_i = C_i + max(max_r t0_r, cummax_i(dmax - C))``, per-rank
    finish imbalance, START_LATE / TOOK_TOO_LONG flags and global-time
    estimates, over the whole ``(nrep, p)`` grid.

Host-side work per call is O(p): clock/sync model coefficients, per-term
epoch biases (through the same :func:`~repro.core.clocks.derive_stream`
helper as the numpy engines) and the AR(1) carry in/out. Small ``nrep``
are padded to a power-of-two bucket so adaptive campaigns hit a handful of
compiled shapes instead of recompiling per top-up; padded windows are
computed and discarded (the entry recurrence is forward-only, so the first
``nrep`` windows are unaffected).
"""

from __future__ import annotations

import functools
import os

import numpy as np

from repro.core.window import START_LATE, TOOK_TOO_LONG, WindowRun

__all__ = ["SimJaxUnavailable", "have_jax", "run_windowed_jax"]


class SimJaxUnavailable(RuntimeError):
    """The jax engine cannot run this request (no jax, or non-affine
    clocks). ``resolve_engine`` maps this to a numpy-engine fallback."""


@functools.lru_cache(maxsize=1)
def have_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def _use_pallas_default() -> bool:
    return os.environ.get("REPRO_SIMJAX_PALLAS", "") not in ("", "0")


def _bucket(nrep: int) -> int:
    """Compiled-shape bucket: next power of two (>= 32) below 1024, exact
    above — campaigns reuse a few small shapes, benchmarks compile once."""
    if nrep >= 1024:
        return nrep
    n = 32
    while n < nrep:
        n *= 2
    return n


@functools.lru_cache(maxsize=1)
def _jitted():
    """Build (once) the jitted sample/window cores. Raises
    :class:`SimJaxUnavailable` when jax is missing."""
    if not have_jax():
        raise SimJaxUnavailable("engine='jax' requires jax, which is not "
                                "importable in this environment")
    import jax
    import jax.numpy as jnp
    from jax import lax

    def sample(key, t0_op, ar_state, noise_sigma, autocorr, tail_prob,
               tail_shift, spike_prob, spike_scale, *, n, use_pallas):
        k_eps, k_tail, k_mag, k_spike = jax.random.split(key, 4)
        eps = noise_sigma * jax.random.normal(k_eps, (n,), jnp.float64)
        u_tail = jax.random.uniform(k_tail, (n,), jnp.float64)
        u_mag = jax.random.uniform(k_mag, (n,), jnp.float64)
        u_spike = jax.random.uniform(k_spike, (n,), jnp.float64)
        if use_pallas:
            from repro.kernels.sim_scan.kernel import sim_durations_scan as fn
        else:
            from repro.kernels.sim_scan.ref import sim_durations_ref as fn
        return fn(eps, u_tail, u_mag, u_spike, coeff=autocorr,
                  state=ar_state, t0=t0_op, tail_prob=tail_prob,
                  tail_shift=tail_shift, spike_prob=spike_prob,
                  spike_scale=spike_scale)

    def window(durations, key, t0, off, skew, scale, slope, intercept,
               init_t, rank_imbalance, start_time, win_size):
        n = durations.shape[0]
        p = t0.shape[0]
        targets = start_time + win_size * jnp.arange(n, dtype=jnp.float64)
        # deadline: sync-model denormalize, then the affine clock inverse
        dl_local = (targets[:, None] + intercept[None, :]) \
            / (1.0 - slope[None, :]) + init_t[None, :]
        raw = dl_local / (1.0 + scale[None, :])
        deadline_true = (raw - off[None, :]) / (1.0 + skew[None, :])
        # f32 draw, f64 math: threefry bit generation is the hot spot and a
        # multiplicative spread factor needs ~1e-2 resolution, not 1e-16
        imb = rank_imbalance * jax.random.normal(
            key, (n, p), jnp.float32).astype(jnp.float64)
        span = durations[:, None] * jnp.maximum(0.25, 1.0 + imb)
        e = span.max(axis=1)
        dmax = deadline_true.max(axis=1)
        C = jnp.concatenate([jnp.zeros((1,), e.dtype), jnp.cumsum(e[:-1])])
        all_in = C + jnp.maximum(jnp.max(t0), lax.cummax(dmax - C))
        end = all_in[:, None] + span
        prev_end = jnp.concatenate([t0[None, :], end[:-1]], axis=0)
        start = jnp.maximum(deadline_true, prev_end)
        late = (deadline_true <= prev_end).any(axis=1)

        def to_global(t_true):
            local = (off[None, :] + (1.0 + skew[None, :]) * t_true) \
                * (1.0 + scale[None, :])
            adj = local - init_t[None, :]
            return adj - (adj * slope[None, :] + intercept[None, :])

        sg = to_global(start)
        eg = to_global(end)
        took = (eg > (targets + win_size)[:, None]).any(axis=1)
        errors = jnp.where(late, START_LATE, 0) \
            | jnp.where(took, TOOK_TOO_LONG, 0)
        times = eg.max(axis=1) - sg.min(axis=1)
        return times, errors, sg, eg, start, end

    return (jax,
            jax.jit(sample, static_argnames=("n", "use_pallas")),
            jax.jit(window))


def _terms(op, p: int, msize: int):
    """Flatten an op into ``(term, term_p, term_msize)`` triples —
    composites sample each constituent at its own size/count and sum,
    exactly like ``SimCompositeOp.sample_durations``."""
    sub_terms = getattr(op, "terms", None)
    if not sub_terms:
        return [(op, p, msize)]
    out = []
    for sub, ms, ps in sub_terms:
        out.append((sub, op._term_p(p, ps), max(0, int(round(ms * msize)))))
    return out


def run_windowed_jax(net, sync, op, msize, nrep, win_size,
                     ranks=None, use_pallas: bool | None = None) -> WindowRun:
    """JAX port of ``run_windowed``'s batch engine (affine clocks only).

    Strict by design: raises :class:`SimJaxUnavailable` on random-walk
    clocks or a missing jax instead of silently degrading —
    ``resolve_engine`` is the sanctioned soft-fallback path.
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    p = len(ranks)
    if not all(net.clocks[r].rw_sigma <= 0.0 for r in ranks):
        raise SimJaxUnavailable(
            "engine='jax' requires affine clocks (rw_sigma == 0); use "
            "engine='batch_rw' (or 'auto') for random-walk clocks")
    jax, sample, window = _jitted()
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if nrep <= 0:
        empty = np.empty((0, p))
        return WindowRun(times=np.empty(0),
                         errors=np.empty(0, dtype=np.int64),
                         start_global_est=empty, end_global_est=empty.copy(),
                         start_true=empty.copy(), end_true=empty.copy())

    g_now = max(sync.global_time(net, r) for r in ranks)
    start_time = g_now + win_size
    n = _bucket(nrep)
    seed = int(net.rng.integers(2**31))
    terms = _terms(op, p, msize)

    t0 = np.asarray(net.t[ranks], dtype=np.float64)
    off = np.array([net.clocks[r].offset for r in ranks])
    skew = np.array([net.clocks[r].skew for r in ranks])
    scale = np.array([net.clocks[r].scale_error for r in ranks])
    slope = np.array([sync.models[r].slope for r in ranks])
    intercept = np.array([sync.models[r].intercept for r in ranks])
    init_t = np.array([sync.initial_times[r] for r in ranks])

    from jax.experimental import enable_x64
    with enable_x64():
        key = jax.random.PRNGKey(seed)
        durations = None
        for j, (sub, tp, tm) in enumerate(terms):
            t0_op = sub.base_time(tp, tm) * sub._bias_for(net)
            dur, s = sample(jax.random.fold_in(key, j), t0_op,
                            sub._ar_state, sub.noise_sigma, sub.autocorr,
                            sub.tail_prob, sub.tail_shift, sub.spike_prob,
                            sub.spike_scale, n=n, use_pallas=use_pallas)
            sub._ar_state = float(s[nrep - 1])
            durations = dur if durations is None else durations + dur
        times, errors, sg, eg, st, et = window(
            durations, jax.random.fold_in(key, len(terms)), t0, off, skew,
            scale, slope, intercept, init_t, op.rank_imbalance, start_time,
            win_size)
        et = np.asarray(et, dtype=np.float64)[:nrep]

    net.t[ranks] = et[nrep - 1]
    return WindowRun(
        times=np.asarray(times, dtype=np.float64)[:nrep],
        errors=np.asarray(errors, dtype=np.int64)[:nrep],
        start_global_est=np.asarray(sg, dtype=np.float64)[:nrep],
        end_global_est=np.asarray(eg, dtype=np.float64)[:nrep],
        start_true=np.asarray(st, dtype=np.float64)[:nrep],
        end_true=et,
    )
