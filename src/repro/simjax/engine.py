"""JIT-compiled windowed-measurement engine (the ``engine="jax"`` path).

One measurement call lowers to two jitted programs:

  * ``sample`` — per cost-model term: draw the AR(1) innovations and
    mixture uniforms, run the linear recurrence as a
    ``lax.associative_scan`` over affine maps ``(a, b)`` (composition
    ``(a1, b1) ∘ (a2, b2) = (a1 a2, b1 a2 + b2)`` is associative, so the
    scan is exact, not an approximation), and apply the
    lognormal/bimodal-tail/spike mixture — the jnp reference of the
    optional fused Pallas kernel in :mod:`repro.kernels.sim_scan`;
  * ``window`` — deadline conversion, the cross-call entry recurrence
    ``all_in_i = C_i + max(max_r t0_r, cummax_i(dmax - C))``, per-rank
    finish imbalance, START_LATE / TOOK_TOO_LONG flags and global-time
    estimates, over the whole ``(nrep, p)`` grid.

Host-side work per call is O(p): clock/sync model coefficients, per-term
epoch biases (through the same :func:`~repro.core.clocks.derive_stream`
helper as the numpy engines) and the AR(1) carry in/out. Small ``nrep``
are padded to a power-of-two bucket so adaptive campaigns hit a handful of
compiled shapes instead of recompiling per top-up; padded windows are
computed and discarded (the entry recurrence is forward-only, so the first
``nrep`` windows are unaffected).

:func:`run_windowed_epochs_jax` is the campaign-resident variant: duration
sampling is vmapped over a per-epoch key axis (``fold_in`` of each epoch's
seed, so per-epoch draws stay bit-identical to the per-epoch engine) and
the window recurrence runs as a chunked ``lax.scan`` whose ``(chunk, p)``
working set stays cache-resident — one compiled trace per ``(op,
shape-bucket)`` serves every epoch and grid cell of a campaign. The fused
window computes its per-rank arithmetic in float32 on window-relative
times (the f64 absolute frame is carried by the O(nrep) chain only) and
draws the finish-imbalance factors from a 2^16-entry normal-quantile
table instead of per-value erfinv; its observations are therefore
statistically indistinguishable from the per-epoch engine's rather than
bit-identical (the sampled *durations* remain bit-identical).

Both engines meter themselves: :func:`engine_stats` counts compiled traces
and dispatches, so "one trace per campaign" is a measured quantity.
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass

import numpy as np

from repro.core.window import START_LATE, TOOK_TOO_LONG, WindowRun

__all__ = [
    "SimJaxUnavailable",
    "have_jax",
    "run_windowed_jax",
    "run_windowed_epochs_jax",
    "FusedWindowRun",
    "engine_stats",
    "reset_engine_stats",
]


class SimJaxUnavailable(RuntimeError):
    """The jax engine cannot run this request (no jax, or non-affine
    clocks). ``resolve_engine`` maps this to a numpy-engine fallback."""


@functools.lru_cache(maxsize=1)
def have_jax() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def _use_pallas_default() -> bool:
    return os.environ.get("REPRO_SIMJAX_PALLAS", "") not in ("", "0")


def _bucket(nrep: int) -> int:
    """Compiled-shape bucket: next power of two (>= 32) below 1024, exact
    above — campaigns reuse a few small shapes, benchmarks compile once."""
    if nrep >= 1024:
        return nrep
    n = 32
    while n < nrep:
        n *= 2
    return n


class _EngineStats:
    """Process-global jit telemetry: every device dispatch is counted, and
    trace keys (jitted function x static/shape signature) are collected so
    ``n_traces`` measures distinct compilations. Monotone by design — like
    the jit cache it mirrors — so a snapshot-delta of the counts is the
    per-campaign telemetry."""

    __slots__ = ("dispatches", "trace_keys")

    def __init__(self) -> None:
        self.dispatches = 0
        self.trace_keys: set = set()

    def count(self, trace_key: tuple) -> None:
        self.dispatches += 1
        self.trace_keys.add(trace_key)


_STATS = _EngineStats()


def engine_stats() -> dict:
    """Cumulative jit telemetry: ``n_traces`` (distinct compiled
    signatures) and ``n_dispatches`` (device calls). Campaigns and the
    bench harness snapshot this before/after and report the delta."""
    return {"n_traces": len(_STATS.trace_keys),
            "n_dispatches": _STATS.dispatches}


def reset_engine_stats() -> None:
    _STATS.dispatches = 0
    _STATS.trace_keys.clear()


def _chunk_for(p: int, n: int) -> int:
    """Rep-axis chunk of the fused window scan: sized so one ``(chunk, p)``
    float32 block is ~512 KB (cache-resident through the ~10 elementwise
    passes), never larger than the bucketed ``n`` itself."""
    ch = max(1, 131072 // max(1, p))
    ch = max(256, min(8192, 1 << (ch.bit_length() - 1)))
    return min(ch, n)


@functools.lru_cache(maxsize=1)
def _cores():
    """The raw (un-jitted) sample/window math, built once. Shared by the
    per-epoch and the fused builders so the fused engine's vmapped duration
    sampling runs byte-for-byte the same program per epoch key. Raises
    :class:`SimJaxUnavailable` when jax is missing."""
    if not have_jax():
        raise SimJaxUnavailable("engine='jax' requires jax, which is not "
                                "importable in this environment")
    import jax
    import jax.numpy as jnp
    from jax import lax

    def sample(key, t0_op, ar_state, noise_sigma, autocorr, tail_prob,
               tail_shift, spike_prob, spike_scale, *, n, use_pallas):
        k_eps, k_tail, k_mag, k_spike = jax.random.split(key, 4)
        eps = noise_sigma * jax.random.normal(k_eps, (n,), jnp.float64)
        u_tail = jax.random.uniform(k_tail, (n,), jnp.float64)
        u_mag = jax.random.uniform(k_mag, (n,), jnp.float64)
        u_spike = jax.random.uniform(k_spike, (n,), jnp.float64)
        if use_pallas:
            from repro.kernels.sim_scan.kernel import sim_durations_scan as fn
        else:
            from repro.kernels.sim_scan.ref import sim_durations_ref as fn
        return fn(eps, u_tail, u_mag, u_spike, coeff=autocorr,
                  state=ar_state, t0=t0_op, tail_prob=tail_prob,
                  tail_shift=tail_shift, spike_prob=spike_prob,
                  spike_scale=spike_scale)

    def window(durations, key, t0, off, skew, scale, slope, intercept,
               init_t, rank_imbalance, start_time, win_size):
        n = durations.shape[0]
        p = t0.shape[0]
        targets = start_time + win_size * jnp.arange(n, dtype=jnp.float64)
        # deadline: sync-model denormalize, then the affine clock inverse
        dl_local = (targets[:, None] + intercept[None, :]) \
            / (1.0 - slope[None, :]) + init_t[None, :]
        raw = dl_local / (1.0 + scale[None, :])
        deadline_true = (raw - off[None, :]) / (1.0 + skew[None, :])
        # f32 draw, f64 math: threefry bit generation is the hot spot and a
        # multiplicative spread factor needs ~1e-2 resolution, not 1e-16
        imb = rank_imbalance * jax.random.normal(
            key, (n, p), jnp.float32).astype(jnp.float64)
        span = durations[:, None] * jnp.maximum(0.25, 1.0 + imb)
        e = span.max(axis=1)
        dmax = deadline_true.max(axis=1)
        C = jnp.concatenate([jnp.zeros((1,), e.dtype), jnp.cumsum(e[:-1])])
        all_in = C + jnp.maximum(jnp.max(t0), lax.cummax(dmax - C))
        end = all_in[:, None] + span
        prev_end = jnp.concatenate([t0[None, :], end[:-1]], axis=0)
        start = jnp.maximum(deadline_true, prev_end)
        late = (deadline_true <= prev_end).any(axis=1)

        def to_global(t_true):
            local = (off[None, :] + (1.0 + skew[None, :]) * t_true) \
                * (1.0 + scale[None, :])
            adj = local - init_t[None, :]
            return adj - (adj * slope[None, :] + intercept[None, :])

        sg = to_global(start)
        eg = to_global(end)
        took = (eg > (targets + win_size)[:, None]).any(axis=1)
        errors = jnp.where(late, START_LATE, 0) \
            | jnp.where(took, TOOK_TOO_LONG, 0)
        times = eg.max(axis=1) - sg.min(axis=1)
        return times, errors, sg, eg, start, end

    return jax, sample, window


@functools.lru_cache(maxsize=1)
def _jitted():
    """Build (once) the jitted per-epoch sample/window cores."""
    jax, sample, window = _cores()
    return (jax,
            jax.jit(sample, static_argnames=("n", "use_pallas")),
            jax.jit(window))


@functools.lru_cache(maxsize=1)
def _norm_lut():
    """2^16-entry float32 normal-quantile table (quantile midpoints, so
    the discretized draw is exactly stratified): the fused window's
    imbalance draw replaces per-value erfinv with 16 random bits + a
    cache-resident gather."""
    from scipy.special import ndtri

    q = (np.arange(65536, dtype=np.float64) + 0.5) / 65536.0
    return ndtri(q).astype(np.float32)


@functools.lru_cache(maxsize=1)
def _jitted_fused():
    """Build (once) the campaign-resident cores:

    * ``sample_epochs`` — the per-epoch :func:`_cores` ``sample`` vmapped
      over an epoch axis of keys derived per epoch seed (bit-identical per
      lane to the per-epoch engine);
    * ``window_fused``  — the window recurrence as a chunked ``lax.scan``:
      per-rank arithmetic in float32 on window-relative times, the
      sequential f64 chain (entry cumsum/cummax, previous-window rows)
      carried across chunks, LUT-quantile imbalance draw, and only the
      O(nrep) outputs materialized.
    """
    jax, sample, _ = _cores()
    import jax.numpy as jnp
    from jax import lax

    lut = jnp.asarray(_norm_lut())

    def sample_epochs(seeds, j, t0_op, ar_state, noise_sigma, autocorr,
                      tail_prob, tail_shift, spike_prob, spike_scale, nrep,
                      *, n, use_pallas):
        def one(seed, t0e, are):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), j)
            dur, s = sample(key, t0e, are, noise_sigma, autocorr, tail_prob,
                            tail_shift, spike_prob, spike_scale, n=n,
                            use_pallas=use_pallas)
            return dur, s[nrep - 1]
        return jax.vmap(one)(seeds, t0_op, ar_state)

    def window_fused(durations, key, t0, off, skew, scale, slope, intercept,
                     init_t, rank_imbalance, start_time, win_size, nrep,
                     *, ch):
        npad = durations.shape[0]
        nch = npad // ch
        p = t0.shape[0]
        # Per-rank affine constants: deadline_true and to_global are both
        # affine in the target time, so the (n, p) grids reduce to
        # slope/anchor pairs evaluated on window-relative f32 offsets.
        alpha = 1.0 / ((1.0 - slope) * (1.0 + scale) * (1.0 + skew))
        beta = ((intercept / (1.0 - slope) + init_t) / (1.0 + scale)
                - off) / (1.0 + skew)
        gamma = (1.0 - slope) * (1.0 + scale) * (1.0 + skew)
        delta = (off * (1.0 + scale) - init_t) * (1.0 - slope) - intercept
        T0 = start_time
        d0_32 = ((alpha - 1.0) * T0 + beta).astype(jnp.float32)
        g0_32 = ((gamma - 1.0) * T0 + delta).astype(jnp.float32)
        am1_32 = (alpha - 1.0).astype(jnp.float32)
        gm1_32 = (gamma - 1.0).astype(jnp.float32)
        gam32 = gamma.astype(jnp.float32)
        maxt0 = jnp.max(t0)
        ws32 = jnp.asarray(win_size, jnp.float32)
        ri32 = jnp.asarray(rank_imbalance, jnp.float32)
        t0rel32 = (t0 - T0).astype(jnp.float32)
        k2 = (p + 1) // 2
        keys = jax.random.split(key, nch)
        nrep1 = nrep - 1

        def step(carry, xs):
            Crun, cmax, prev_last, et_sel = carry
            dur_i, key_i, ic = xs
            tau = win_size * (ic * ch + jnp.arange(ch, dtype=jnp.float64))
            tau32 = tau.astype(jnp.float32)[:, None]
            bits = jax.random.bits(key_i, (ch, k2), jnp.uint32)
            idx = jnp.concatenate([bits & 0xFFFF, bits >> 16],
                                  axis=1)[:, :p]
            z = lut[idx]
            drel = am1_32[None, :] * tau32 + d0_32[None, :]
            dur32 = dur_i.astype(jnp.float32)[:, None]
            span = dur32 * jnp.maximum(jnp.float32(0.25), 1.0 + ri32 * z)
            e = span.max(axis=1).astype(jnp.float64)
            dmaxrel = drel.max(axis=1).astype(jnp.float64)
            T = T0 + tau
            C = Crun + jnp.concatenate(
                [jnp.zeros((1,), jnp.float64), jnp.cumsum(e[:-1])])
            cm = lax.cummax(jnp.concatenate([cmax[None],
                                             T + dmaxrel - C]))[1:]
            all_in = C + jnp.maximum(maxt0, cm)
            A32 = (all_in - T).astype(jnp.float32)[:, None]
            endrel = A32 + span
            prevrel = jnp.concatenate([prev_last[None, :], endrel[:-1]],
                                      axis=0) - ws32
            startrel = jnp.maximum(drel, prevrel)
            late = (drel <= prevrel).any(axis=1)
            base = gm1_32[None, :] * tau32 + g0_32[None, :]
            egrel = base + gam32[None, :] * endrel
            sgrel = base + gam32[None, :] * startrel
            took = (egrel > ws32).any(axis=1)
            errors = jnp.where(late, START_LATE, 0) \
                | jnp.where(took, TOOK_TOO_LONG, 0)
            times = egrel.max(axis=1).astype(jnp.float64) \
                - sgrel.min(axis=1).astype(jnp.float64)
            # end_true row nrep-1 (the net.t carry-out) without
            # materializing the (n, p) grid: grab it in the chunk it lives
            local = nrep1 - ic * ch
            hit = (local >= 0) & (local < ch)
            row = lax.dynamic_slice_in_dim(
                endrel, jnp.clip(local, 0, ch - 1), 1, axis=0)[0]
            et_sel = jnp.where(hit, row, et_sel)
            return (C[-1] + e[-1], cm[-1], endrel[-1], et_sel), \
                (times, errors)

        init = (jnp.float64(0.0), jnp.float64(-jnp.inf), t0rel32 + ws32,
                jnp.zeros((p,), jnp.float32))
        (_, _, _, et_sel), (times, errors) = lax.scan(
            step, init, (durations.reshape(nch, ch), keys,
                         jnp.arange(nch)))
        et_last = et_sel.astype(jnp.float64) + (T0 + win_size * nrep1)
        return times.reshape(-1), errors.reshape(-1), et_last

    return (jax,
            jax.jit(sample_epochs, static_argnames=("n", "use_pallas")),
            jax.jit(window_fused, static_argnames=("ch",)))


@dataclass
class FusedWindowRun:
    """O(nrep) outputs of one fused epoch. The ``(nrep, p)`` global-time
    grids of :class:`WindowRun` are deliberately not materialized — the
    fused engine keeps only what campaign records consume."""

    times: np.ndarray
    errors: np.ndarray

    @property
    def valid_times(self) -> np.ndarray:
        return self.times[self.errors == 0]


def _rank_sharding(p: int):
    """NamedSharding splitting the rank axis across all visible devices
    (None when single-device, or when ``p`` does not divide evenly). The
    fused window's cross-rank reductions (max / min / any) are
    order-independent, so the sharded program is bitwise-identical to the
    single-device one — which is what the forced-host-device CI asserts."""
    if not have_jax():
        return None
    import jax

    devs = jax.devices()
    if len(devs) <= 1 or p % len(devs) != 0:
        return None
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.array(devs), ("ranks",))
    return NamedSharding(mesh, PartitionSpec("ranks"))


def _terms(op, p: int, msize: int):
    """Flatten an op into ``(term, term_p, term_msize)`` triples —
    composites sample each constituent at its own size/count and sum,
    exactly like ``SimCompositeOp.sample_durations``."""
    sub_terms = getattr(op, "terms", None)
    if not sub_terms:
        return [(op, p, msize)]
    out = []
    for sub, ms, ps in sub_terms:
        out.append((sub, op._term_p(p, ps), max(0, int(round(ms * msize)))))
    return out


def run_windowed_jax(net, sync, op, msize, nrep, win_size,
                     ranks=None, use_pallas: bool | None = None) -> WindowRun:
    """JAX port of ``run_windowed``'s batch engine (affine clocks only).

    Strict by design: raises :class:`SimJaxUnavailable` on random-walk
    clocks or a missing jax instead of silently degrading —
    ``resolve_engine`` is the sanctioned soft-fallback path.
    """
    ranks = list(range(net.p)) if ranks is None else ranks
    p = len(ranks)
    if not all(net.clocks[r].rw_sigma <= 0.0 for r in ranks):
        raise SimJaxUnavailable(
            "engine='jax' requires affine clocks (rw_sigma == 0); use "
            "engine='batch_rw' (or 'auto') for random-walk clocks")
    jax, sample, window = _jitted()
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if nrep <= 0:
        empty = np.empty((0, p))
        return WindowRun(times=np.empty(0),
                         errors=np.empty(0, dtype=np.int64),
                         start_global_est=empty, end_global_est=empty.copy(),
                         start_true=empty.copy(), end_true=empty.copy())

    g_now = max(sync.global_time(net, r) for r in ranks)
    start_time = g_now + win_size
    n = _bucket(nrep)
    seed = int(net.rng.integers(2**31))
    terms = _terms(op, p, msize)

    t0 = np.asarray(net.t[ranks], dtype=np.float64)
    off = np.array([net.clocks[r].offset for r in ranks])
    skew = np.array([net.clocks[r].skew for r in ranks])
    scale = np.array([net.clocks[r].scale_error for r in ranks])
    slope = np.array([sync.models[r].slope for r in ranks])
    intercept = np.array([sync.models[r].intercept for r in ranks])
    init_t = np.array([sync.initial_times[r] for r in ranks])

    from jax.experimental import enable_x64
    with enable_x64():
        key = jax.random.PRNGKey(seed)
        durations = None
        for j, (sub, tp, tm) in enumerate(terms):
            t0_op = sub.base_time(tp, tm) * sub._bias_for(net)
            _STATS.count(("sample", n, use_pallas))
            dur, s = sample(jax.random.fold_in(key, j), t0_op,
                            sub._ar_state, sub.noise_sigma, sub.autocorr,
                            sub.tail_prob, sub.tail_shift, sub.spike_prob,
                            sub.spike_scale, n=n, use_pallas=use_pallas)
            sub._ar_state = float(s[nrep - 1])
            durations = dur if durations is None else durations + dur
        _STATS.count(("window", n, p))
        times, errors, sg, eg, st, et = window(
            durations, jax.random.fold_in(key, len(terms)), t0, off, skew,
            scale, slope, intercept, init_t, op.rank_imbalance, start_time,
            win_size)
        et = np.asarray(et, dtype=np.float64)[:nrep]

    net.t[ranks] = et[nrep - 1]
    return WindowRun(
        times=np.asarray(times, dtype=np.float64)[:nrep],
        errors=np.asarray(errors, dtype=np.int64)[:nrep],
        start_global_est=np.asarray(sg, dtype=np.float64)[:nrep],
        end_global_est=np.asarray(eg, dtype=np.float64)[:nrep],
        start_true=np.asarray(st, dtype=np.float64)[:nrep],
        end_true=et,
    )


def run_windowed_epochs_jax(nets, syncs, ops, msize, nrep, win_size,
                            ranks=None,
                            use_pallas: bool | None = None
                            ) -> "list[FusedWindowRun]":
    """Measure one case across all launch epochs in fused device programs.

    ``nets[e] / syncs[e] / ops[e]`` are epoch ``e``'s simulator objects (one
    triple per launch epoch, exactly what the per-epoch engine would see).
    Duration sampling runs as ONE vmapped dispatch per cost-model term
    (bit-identical per epoch lane to :func:`run_windowed_jax`: the same
    ``_cores`` sample program under the same per-epoch ``fold_in`` keys);
    the window recurrence dispatches per epoch — start times differ — but
    every dispatch reuses one chunked-scan trace per ``(p, shape-bucket)``.
    Host-side RNG order per epoch (window seed, then per-term epoch biases)
    matches the per-epoch engine, and the AR(1) carry and ``net.t``
    writebacks land exactly as ``E`` sequential per-epoch calls would, so a
    campaign may interleave fused and per-epoch measurement of *different*
    cases freely.

    When several devices are visible and ``p`` divides evenly, the per-rank
    inputs are placed with a rank-axis :class:`~jax.sharding.NamedSharding`
    and GSPMD shards the window grid; cross-rank reductions are
    order-independent, so sharded results are bitwise-identical.

    Returns one :class:`FusedWindowRun` per epoch. Raises
    :class:`SimJaxUnavailable` under the same conditions as
    :func:`run_windowed_jax`.
    """
    E = len(nets)
    if E == 0:
        return []
    ranks = list(range(nets[0].p)) if ranks is None else list(ranks)
    p = len(ranks)
    for net in nets:
        if not all(net.clocks[r].rw_sigma <= 0.0 for r in ranks):
            raise SimJaxUnavailable(
                "engine='jax' requires affine clocks (rw_sigma == 0); use "
                "engine='batch_rw' (or 'auto') for random-walk clocks")
    jax, sample_epochs, window_fused = _jitted_fused()
    if use_pallas is None:
        use_pallas = _use_pallas_default()
    if nrep <= 0:
        return [FusedWindowRun(times=np.empty(0),
                               errors=np.empty(0, dtype=np.int64))
                for _ in range(E)]

    n = _bucket(nrep)
    ch = _chunk_for(p, n)
    npad = -(-n // ch) * ch

    # Host pass 1 — per-epoch seeds and window origins. Per-net RNG order
    # (seed before biases) matches the per-epoch engine; epochs own
    # independent nets, so interleaving across epochs is free.
    start_times = np.empty(E, dtype=np.float64)
    seeds = np.empty(E, dtype=np.int64)
    term_lists = []
    for e, (net, sync, op) in enumerate(zip(nets, syncs, ops)):
        start_times[e] = max(sync.global_time(net, r)
                             for r in ranks) + win_size
        seeds[e] = int(net.rng.integers(2**31))
        term_lists.append(_terms(op, p, msize))
    nterms = len(term_lists[0])

    # Host pass 2 — per-epoch clock/sync coefficient stacks, (E, p).
    def stack(fn):
        return np.stack([np.array([fn(e, r) for r in ranks])
                         for e in range(E)])

    t0 = stack(lambda e, r: nets[e].t[r])
    off = stack(lambda e, r: nets[e].clocks[r].offset)
    skew = stack(lambda e, r: nets[e].clocks[r].skew)
    scale = stack(lambda e, r: nets[e].clocks[r].scale_error)
    slope = stack(lambda e, r: syncs[e].models[r].slope)
    intercept = stack(lambda e, r: syncs[e].models[r].intercept)
    init_t = stack(lambda e, r: syncs[e].initial_times[r])

    sharding = _rank_sharding(p)

    def put(a):
        return jax.device_put(a, sharding) if sharding is not None else a

    from jax.experimental import enable_x64
    with enable_x64():
        durations = None
        for j in range(nterms):
            subs = [term_lists[e][j][0] for e in range(E)]
            tp, tm = term_lists[0][j][1], term_lists[0][j][2]
            t0_op = np.array([sub.base_time(tp, tm) * sub._bias_for(net)
                              for sub, net in zip(subs, nets)])
            ar_state = np.array([sub._ar_state for sub in subs])
            s0 = subs[0]
            _STATS.count(("sample_epochs", E, n, use_pallas))
            dur, s_last = sample_epochs(
                seeds, j, t0_op, ar_state, s0.noise_sigma, s0.autocorr,
                s0.tail_prob, s0.tail_shift, s0.spike_prob, s0.spike_scale,
                nrep, n=n, use_pallas=use_pallas)
            s_last = np.asarray(s_last)
            for e, sub in enumerate(subs):
                sub._ar_state = float(s_last[e])
            durations = dur if durations is None else durations + dur

        import jax.numpy as jnp
        if npad > n:
            durations = jnp.concatenate(
                [durations, jnp.broadcast_to(durations[:, n - 1:n],
                                             (E, npad - n))], axis=1)
        runs = []
        for e in range(E):
            key = jax.random.fold_in(jax.random.PRNGKey(int(seeds[e])),
                                     nterms)
            _STATS.count(("window_fused", ch, npad, p))
            times, errors, et_last = window_fused(
                durations[e], key, put(t0[e]), put(off[e]), put(skew[e]),
                put(scale[e]), put(slope[e]), put(intercept[e]),
                put(init_t[e]), ops[e].rank_imbalance,
                float(start_times[e]), win_size, nrep, ch=ch)
            nets[e].t[ranks] = np.asarray(et_last, dtype=np.float64)
            runs.append(FusedWindowRun(
                times=np.asarray(times, dtype=np.float64)[:nrep],
                errors=np.asarray(errors, dtype=np.int64)[:nrep]))
    return runs
