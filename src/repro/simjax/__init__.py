"""Accelerator-resident (JAX) port of the simulation hot path.

``run_windowed_jax`` jit-compiles the whole windowed-measurement grid —
AR(1)-lognormal duration sampling with the bimodal-tail/spike/imbalance
mixture of :class:`~repro.core.mpi_ops.SimCollective`, the cross-call
entry recurrence (a prefix-sum + running-max, mapped to
``jax.lax.associative_scan`` / ``lax.cummax``), and every local↔global
clock conversion — over the full ``(nrep, p)`` array at once. It is
exposed as ``run_windowed(..., engine="jax")`` and
``SimBackend(engine="jax")`` with zero call-site changes.

The port is float64 end to end (via ``jax.experimental.enable_x64``), so
its absolute-time arithmetic carries the same resolution as the numpy
engine; draws use JAX's counter-based PRNG, so — like PR 1's batching —
campaigns are statistically, not bit-wise, identical to the numpy engines
(``tests/test_batch_equivalence.py``).
"""

from .engine import (FusedWindowRun, SimJaxUnavailable, engine_stats,
                     have_jax, reset_engine_stats, run_windowed_epochs_jax,
                     run_windowed_jax)

__all__ = [
    "SimJaxUnavailable",
    "have_jax",
    "run_windowed_jax",
    "run_windowed_epochs_jax",
    "FusedWindowRun",
    "engine_stats",
    "reset_engine_stats",
]
