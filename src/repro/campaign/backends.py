"""Pluggable measurement backends for the paper's method.

The experimental design (Alg. 5/6) is engine-agnostic: it needs a fresh
context per *launch epoch*, a way to *measure* one test case, and the
:class:`~repro.core.factors.FactorSet` describing everything else that was
held fixed. A :class:`MeasurementBackend` packages exactly those three
capabilities, so the same :class:`~repro.campaign.Campaign` spec runs
against

  * :class:`SimBackend`    — the calibrated cluster simulator
    (:class:`~repro.core.simnet.SimNet` + window-based sync, §3.3/§4),
  * :class:`JaxBackend`    — real jitted JAX collectives (``psum`` /
    ``all_gather`` / ``all_to_all``) over a host-device mesh
    (``--xla_force_host_platform_device_count`` off-TPU),
  * :class:`KernelBackend` — Pallas kernels vs. their jnp references as
    the operations under test (interpret mode off-TPU).

Backends are plain picklable dataclasses so
:func:`~repro.core.design.run_design` can fan their launch epochs over a
process pool, and deterministic per ``(seed0, epoch)`` so a resumed
campaign reproduces the original records bit-for-bit.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Protocol, runtime_checkable

import numpy as np

from repro.core.design import ExperimentDesign, TestCase
from repro.core.factors import FactorSet, capture_factors
from repro.core.mpi_ops import make_composite_op
from repro.core.opexpr import parse_opexpr
from repro.core.runtime_meter import JaxEpochContext, MeterConfig
from repro.core.simnet import ClockParams, SimNet
from repro.core.sync import make_sync
from repro.core.warnutil import warn_external
from repro.core.window import WindowRun, resolve_engine, run_windowed

__all__ = [
    "MeasurementBackend",
    "FunctionBackend",
    "SimBackend",
    "JaxBackend",
    "KernelBackend",
    "ensure_host_devices",
    "fallback_warning_scope",
]

_SYNC_KW = dict(n_fitpts=200, n_exchanges=40)

# Active fallback-warning dedup scopes (innermost last). A sweep pushes one
# scope around all of its cell campaigns so each distinct engine-fallback
# reason warns once per *sweep*, not once per cell.
_WARN_SCOPE: list = []


@contextmanager
def fallback_warning_scope():
    """Deduplicate engine-fallback ``RuntimeWarning``s across every campaign
    run inside the scope. Without an active scope each backend instance
    dedups on its own (once per campaign)."""
    _WARN_SCOPE.append(set())
    try:
        yield
    finally:
        _WARN_SCOPE.pop()


def _filter_sync_kw(sync_name: str, kw: dict) -> dict:
    """``sync_kw`` restricted to what the chosen algorithm's constructor
    accepts. Fitpoint knobs mean nothing to skampi/netgauge, and a sweep's
    ``sync_method`` axis must be able to swap algorithms under one backend
    configuration without the unused knobs turning into TypeErrors."""
    import inspect

    from repro.core.sync import SYNC_CLASSES

    cls = SYNC_CLASSES.get(sync_name)
    if cls is None:          # unknown name: let make_sync raise its error
        return dict(kw)
    params = inspect.signature(cls.__init__).parameters
    if any(p.kind == p.VAR_KEYWORD for p in params.values()):
        return dict(kw)
    return {k: v for k, v in kw.items() if k in params}


def _sequence_calls(fns):
    """One timed callable running ``fns`` back to back — the composite
    mock-up region. The epoch meter blocks on the *returned* value only,
    so return the last term's output (each prior dispatch is enqueued
    before it and completes under JAX's per-device program order)."""
    if len(fns) == 1:
        return fns[0]

    def composite():
        out = None
        for f in fns:
            out = f()
        return out

    return composite


@runtime_checkable
class MeasurementBackend(Protocol):
    """What a measurement engine must provide to run the paper's method."""

    name: str

    def make_epoch(self, epoch: int) -> Any:
        """Fresh launch-epoch context (the §5.2 blocking factor)."""
        ...

    def measure(self, ctx: Any, case: TestCase, nrep: int) -> np.ndarray:
        """``nrep`` run-times [s] of ``case`` inside an epoch context."""
        ...

    def factors(self, design: ExperimentDesign) -> FactorSet:
        """The Table-4 factor set a campaign on this backend must carry."""
        ...

    def default_cases(self) -> list[TestCase]:
        """Cases to run when the campaign spec does not name any."""
        ...


def _design_factor_kw(design: ExperimentDesign) -> dict:
    return dict(
        n_launch_epochs=design.n_launch_epochs,
        nrep=0 if design.adaptive else design.nrep,
        nrep_min=design.nrep_min if design.adaptive else 0,
        nrep_max=(design.nrep_max or 0) if design.adaptive else 0,
        rel_ci_target=design.rel_ci_target if design.adaptive else 0.0,
        design_seed=design.seed,
        shuffle=design.shuffle,
    )


# ---------------------------------------------------------------------------
# Simulator backend
# ---------------------------------------------------------------------------

def _apply_cold_buffers(op) -> None:
    """§5.8's cache factor for the simulator: cold buffers forfeit the
    cost model's own ``warm_cache_discount``, scaling every affine cost
    term by ``1 + discount`` (exactly what ``sample_duration(warm=False)``
    would do, applied once at op-construction time so both window engines
    and composites inherit it)."""
    if hasattr(op, "terms"):                 # SimCompositeOp
        for sub, _, _ in op.terms:
            _apply_cold_buffers(sub)
        return
    f = 1.0 + op.warm_cache_discount
    op.alpha *= f
    op.beta *= f
    op.gamma *= f


class _SimEpoch:
    """One simulated launch epoch: a fresh cluster, synchronized clocks,
    and a lazily-built cost model per op name."""

    def __init__(self, backend: "SimBackend", epoch: int):
        self.backend = backend
        self.net = SimNet(
            backend.p,
            clocks=ClockParams(**backend.clock_kw) if backend.clock_kw
            else None,
            seed=backend.seed0 + 1000 * epoch)
        sync_kw = _filter_sync_kw(backend.sync_name, backend.sync_kw)
        self.sync = make_sync(backend.sync_name,
                              **sync_kw).synchronize(self.net)
        # Resolve once per epoch: what will actually run. A substitution
        # (jax requested but unusable) is never silent — it is warned once
        # per campaign and recorded per record (`meta["engine"]`).
        self.engine, self.engine_note = resolve_engine(backend.engine,
                                                       self.net)
        if self.engine_note is not None:
            backend._warn_fallback(self.engine_note)
        self._ops: dict[str, Any] = {}

    def op(self, name: str):
        if name not in self._ops:
            # `name` may be a composite op expression (a guideline mock-up
            # such as "scatter+allgather" or "allreduce@half+allreduce@half")
            op = make_composite_op(
                name, per_op_kw=self.backend.per_op_kw, **self.backend.op_kw)
            if self.backend.buffer_policy == "cold":
                _apply_cold_buffers(op)
            self._ops[name] = op
        return self._ops[name]


@dataclass
class SimBackend:
    """Simulated cluster measured through window-based synchronization.

    ``case.op`` selects the collective's cost-model preset (unknown names
    get the generic model) — or a composite op *expression* (see
    :mod:`repro.core.opexpr`) sequencing several collectives inside one
    timed region, the mock-up side of a performance guideline. ``case.msize``
    is the message size; ``op_kw`` overrides apply to every case, which is
    how two "MPI libraries" with different latency terms are modeled, and
    ``per_op_kw`` overrides one named collective only (how a single
    mis-tuned collective — the thing guideline verification exists to catch
    — is seeded). Window discards (START_LATE / TOOK_TOO_LONG) are topped
    up so the returned sample has ~``nrep`` valid observations.

    Three Table-4 factors are sweepable knobs here so a
    :class:`~repro.core.factors.FactorGrid` can vary them:
    ``buffer_policy`` (``"cold"`` forfeits the cost model's warm-cache
    discount, §5.8), ``epoch_isolation`` (``"none"`` *reuses* one
    simulated cluster across every launch epoch — the §5.2 anti-pattern a
    sweep should expose as biased), and ``dtype`` (a pure label in the
    simulator: it must rank as a null factor, which is the negative
    control of the factor-impact analysis).
    """

    p: int = 8
    seed0: int = 0
    op_kw: dict = field(default_factory=dict)
    per_op_kw: dict = field(default_factory=dict)
    sync_name: str = "hca"
    sync_kw: dict = field(default_factory=lambda: dict(_SYNC_KW))
    win_size: float = 400e-6
    engine: str = "auto"
    clock_kw: dict = field(default_factory=dict)
    buffer_policy: str = "warm"        # warm | cold
    epoch_isolation: str = "process"   # process | none
    dtype: str = "float32"             # label-only (null factor by design)
    fuse_epochs: bool = True           # execution knob, not a factor
    name: str = "sim"
    _shared_epoch: Any = field(default=None, init=False, repr=False,
                               compare=False)
    _fallback_warned: set = field(default_factory=set, init=False,
                                  repr=False, compare=False)

    def _warn_fallback(self, note: str) -> None:
        """Warn once per campaign (per distinct reason) when the requested
        engine is substituted — the audit trail for the historic bug where
        ``engine="auto"`` silently dropped to the scalar path. Inside a
        :func:`fallback_warning_scope` (a sweep), dedup widens to the whole
        scope so the report is not drowned in per-cell repeats. The warning
        is attributed to the first frame *outside* ``repro`` — the call
        depth differs between a bare ``make_epoch`` and a full
        ``Campaign.run``, so no fixed ``stacklevel`` can point at the
        caller for both."""
        seen = _WARN_SCOPE[-1] if _WARN_SCOPE else self._fallback_warned
        if note in seen:
            return
        seen.add(note)
        warn_external(f"SimBackend(engine={self.engine!r}): {note}",
                      RuntimeWarning)

    def make_epoch(self, epoch: int) -> _SimEpoch:
        if self.buffer_policy not in ("warm", "cold"):
            raise ValueError(f"SimBackend: buffer_policy must be 'warm' or "
                             f"'cold', got {self.buffer_policy!r}")
        if self.epoch_isolation == "none":
            # the launch-epoch anti-pattern: every "epoch" shares one
            # cluster, so AR(1) state, epoch bias and clock drift carry
            # over (meaningful serially; workers each rebuild their own)
            if self._shared_epoch is None:
                self._shared_epoch = _SimEpoch(self, 0)
            return self._shared_epoch
        if self.epoch_isolation != "process":
            raise ValueError(f"SimBackend: epoch_isolation must be 'process' "
                             f"or 'none', got {self.epoch_isolation!r}")
        return _SimEpoch(self, epoch)

    def measure(self, ctx: _SimEpoch, case: TestCase, nrep: int) -> np.ndarray:
        op = ctx.op(case.op)
        runs = [run_windowed(ctx.net, ctx.sync, op, case.msize, nrep,
                             win_size=self.win_size, engine=ctx.engine)]
        # top up the window discards (bounded: at most 2 extra chunks)
        for _ in range(2):
            missing = nrep - sum(r.valid_times.size for r in runs)
            if missing <= 0:
                break
            runs.append(run_windowed(ctx.net, ctx.sync, op, case.msize,
                                     missing, win_size=self.win_size,
                                     engine=ctx.engine))
        wr = WindowRun.concat(runs)
        # Degenerate case (window far too small): nothing valid anywhere.
        # Return at most nrep raw observations rather than every top-up
        # draw, so adaptive stopping's sample-size accounting stays honest.
        return wr.valid_times if wr.valid_times.size else wr.times[:nrep]

    def record_meta(self, ctx: _SimEpoch, case: TestCase) -> dict:
        """Per-record provenance: the engine that *actually ran* (which can
        differ from the configured one — see :func:`resolve_engine`)."""
        meta = {"engine": ctx.engine}
        if ctx.engine_note is not None:
            meta["engine_fallback"] = ctx.engine_note
        return meta

    def measure_epochs(self, work: dict, design: ExperimentDesign):
        """Fused campaign execution (the optional backend capability
        :class:`~repro.campaign.Campaign` probes for).

        ``work`` maps ``epoch -> [TestCase, ...]`` in that epoch's shuffled
        case order. Epochs whose next pending case coincides are measured by
        ONE device program per cost-model term
        (:func:`repro.simjax.run_windowed_epochs_jax`); each epoch's own
        case order, host RNG stream, AR(1) carries and ``net.t`` writebacks
        are preserved exactly, so records match what sequential per-epoch
        measurement of the same pending work would produce (modulo the
        fused window's documented draw change). Window discards are topped
        up per epoch and adaptive nrep continues through the normal
        :func:`~repro.core.design.measure_adaptive` loop, both reusing the
        bucketed per-epoch traces.

        Returns ``{(op, msize, epoch): (times, meta)}`` covering every case
        in ``work``, or ``None`` when the fused path cannot run it (caller
        then measures per epoch as before): fusing disabled, shared-cluster
        epoch isolation, no jax, or an engine other than the jit one.
        """
        if not self.fuse_epochs or self.epoch_isolation != "process":
            return None
        # Only an explicit engine="jax" can resolve to the jit engine
        # (auto prefers the numpy batch path) — gate before building any
        # epoch context, so non-jax campaigns pay nothing for the probe.
        if self.engine != "jax":
            return None
        if not work or all(not cases for cases in work.values()):
            return None
        from repro.simjax import have_jax
        if not have_jax():
            return None
        ctxs = {e: self.make_epoch(e) for e in sorted(work)}
        if any(ctx.engine != "jax" for ctx in ctxs.values()):
            return None          # only the jit engine has a fused program
        from repro.core.design import measure_adaptive
        from repro.simjax import run_windowed_epochs_jax

        nrep0 = design.nrep_min if design.adaptive else design.nrep
        pos = {e: 0 for e in sorted(work)}
        out: dict = {}
        while True:
            by_case: dict = {}
            for e in sorted(work):
                if pos[e] < len(work[e]):
                    c = work[e][pos[e]]
                    by_case.setdefault((c.op, c.msize), []).append(e)
            if not by_case:
                return out
            # Most common next case first: maximal epoch fan-in per
            # dispatch without ever reordering within an epoch.
            (op_name, msize), epochs = max(
                by_case.items(), key=lambda kv: (len(kv[1]), kv[0]))
            ops = [ctxs[e].op(op_name) for e in epochs]
            runs = run_windowed_epochs_jax(
                [ctxs[e].net for e in epochs],
                [ctxs[e].sync for e in epochs],
                ops, msize, nrep0, self.win_size)
            for i, e in enumerate(epochs):
                ctx, case = ctxs[e], work[e][pos[e]]
                rs = [runs[i]]
                # top up the window discards (bounded, as measure() does)
                for _ in range(2):
                    miss = nrep0 - sum(r.valid_times.size for r in rs)
                    if miss <= 0:
                        break
                    rs.append(run_windowed(ctx.net, ctx.sync, ops[i],
                                           msize, miss,
                                           win_size=self.win_size,
                                           engine=ctx.engine))
                valid = np.concatenate([r.valid_times for r in rs])
                times = valid if valid.size else np.concatenate(
                    [r.times for r in rs])[:nrep0]
                if design.adaptive:
                    times, meta = measure_adaptive(self.measure, ctx, case,
                                                   design, initial=times)
                else:
                    meta = dict(nrep_used=int(times.size), converged=True)
                meta.update(self.record_meta(ctx, case))
                meta["fused"] = True
                out[(op_name, msize, e)] = (np.asarray(times, np.float64),
                                            meta)
                pos[e] += 1

    def factors(self, design: ExperimentDesign) -> FactorSet:
        return capture_factors(
            backend="sim",
            device_kind="simnet",
            measurement_backend=self.name,
            sync_method=self.sync_name,
            window_size_us=self.win_size * 1e6,
            epoch_isolation=self.epoch_isolation,
            buffer_policy=self.buffer_policy,
            dtype=self.dtype,
            extra=(("p", self.p), ("seed0", self.seed0),
                   ("op_kw", tuple(sorted(self.op_kw.items()))),
                   ("per_op_kw", tuple(sorted(
                       (op, tuple(sorted(kw.items())))
                       for op, kw in self.per_op_kw.items()))),
                   ("sync_kw", tuple(sorted(self.sync_kw.items()))),
                   ("clock_kw", tuple(sorted(self.clock_kw.items()))),
                   ("engine", self.engine)),
            **_design_factor_kw(design),
        )

    def default_cases(self) -> list[TestCase]:
        return [TestCase("allreduce", m) for m in (256, 4096)]


# ---------------------------------------------------------------------------
# Real-JAX collective backend
# ---------------------------------------------------------------------------

def ensure_host_devices(n: int) -> int:
    """Request ``n`` host CPU devices via
    ``--xla_force_host_platform_device_count`` and return the count JAX
    actually provides. Only effective if called before JAX initializes its
    backends; afterwards it just reports the live device count."""
    import os

    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
    import jax

    return jax.device_count()


@dataclass
class JaxBackend:
    """Real jitted JAX collectives on a host-device mesh.

    ``case.op`` is one of ``psum`` / ``all_gather`` / ``all_to_all`` —
    lowered through ``jax.pmap`` over ``n_devices`` devices so the timed
    executable contains a genuine cross-device collective even on a single
    host (``--xla_force_host_platform_device_count``). ``case.msize`` is
    the per-device payload in bytes. A launch epoch re-jits the collective
    (``epoch_isolation="clear_caches"``), the in-process analogue of a
    fresh mpirun.
    """

    ops: tuple = ("psum", "all_gather", "all_to_all")
    n_devices: int | None = None      # None = all available
    meter: MeterConfig = field(
        default_factory=lambda: MeterConfig(epoch_isolation="clear_caches"))
    dtype: str = "float32"
    name: str = "jax"

    def _ndev(self) -> int:
        import jax

        n = self.n_devices or jax.device_count()
        if n > jax.device_count():
            raise ValueError(
                f"JaxBackend: {n} devices requested, {jax.device_count()} "
                "available — set --xla_force_host_platform_device_count")
        return n

    def _build_collective(self, op: str, msize: int, n: int | None = None):
        import jax
        import jax.numpy as jnp
        from jax import lax

        n = self._ndev() if n is None else n
        itemsize = jnp.dtype(self.dtype).itemsize
        # per-device payload, padded so all_to_all's split axis divides
        count = max(n, int(np.ceil(msize / itemsize)))
        count = int(np.ceil(count / n)) * n
        devices = jax.devices()[:n]
        shape = (n, count)
        if op == "psum":
            f = jax.pmap(lambda x: lax.psum(x, "i"), axis_name="i",
                         devices=devices)
        elif op == "all_gather":
            f = jax.pmap(lambda x: lax.all_gather(x, "i"), axis_name="i",
                         devices=devices)
        elif op == "all_to_all":
            # split axis must equal the mesh size: (n, count//n) per device
            shape = (n, n, count // n)
            f = jax.pmap(lambda x: lax.all_to_all(x, "i", 0, 0),
                         axis_name="i", devices=devices)
        else:
            raise ValueError(f"JaxBackend: unknown collective {op!r}; "
                             f"one of {self.ops}")
        x = jnp.zeros(shape, self.dtype) + jnp.arange(n).reshape(
            (n,) + (1,) * (len(shape) - 1))
        return lambda: f(x)

    def _build_case(self, opexpr: str, msize: int):
        """Build the timed callable for a case — a single collective, or a
        composite mock-up expression sequencing several collectives inside
        one timed region (``"reduce+bcast"``-style guideline sides;
        ``@half`` runs a term over half the mesh, the split-robustness
        mock-up)."""
        terms = parse_opexpr(opexpr)
        n = self._ndev()
        fns = []
        for t in terms:
            if t.impl is not None:
                raise ValueError(f"JaxBackend: '#{t.impl}' implementation "
                                 f"tags are not supported (case {opexpr!r})")
            tn = max(2, n // 2) if t.procs == "half" else n
            fns.append(self._build_collective(t.op, t.msize(msize), n=tn))
        return _sequence_calls(fns)

    def make_epoch(self, epoch: int) -> JaxEpochContext:
        def build(_epoch: int) -> dict:
            return {}  # callables are built lazily, one per case

        ctx = JaxEpochContext(build, epoch, self.meter)
        return ctx

    def measure(self, ctx: JaxEpochContext, case: TestCase,
                nrep: int) -> np.ndarray:
        key = f"{case.op}@{case.msize}"
        if key not in ctx.callables:
            ctx.callables[key] = self._build_case(case.op, case.msize)
        return ctx.measure(key, nrep)

    def factors(self, design: ExperimentDesign) -> FactorSet:
        return capture_factors(
            measurement_backend=self.name,
            sync_method="block_until_ready",
            mesh_shape=(self._ndev(),),
            mesh_axes=("i",),
            epoch_isolation=self.meter.epoch_isolation,
            buffer_policy="cold" if self.meter.cold_buffers else "warm",
            dtype=self.dtype,
            extra=(("ops", tuple(self.ops)), ("warmup", self.meter.warmup)),
            **_design_factor_kw(design),
        )

    def default_cases(self) -> list[TestCase]:
        return [TestCase(op, m) for op in self.ops for m in (1 << 10, 1 << 16)]


# ---------------------------------------------------------------------------
# Pallas-kernel backend
# ---------------------------------------------------------------------------

@dataclass
class KernelBackend:
    """Pallas kernels vs. their jnp references as operations under test.

    ``case.op`` names the kernel (``flash_attention`` / ``ssd_scan``),
    ``case.msize`` is the sequence length. ``impl`` selects which side of
    the A/B comparison this backend measures — run one campaign with
    ``impl="pallas"`` and one with ``impl="ref"``, then
    :func:`~repro.core.compare.compare_tables` answers "is the kernel
    faster?" the statistically sound way.

    A case may also be an op *expression* (:mod:`repro.core.opexpr`): a
    ``#impl`` tag overrides the backend-level ``impl`` for that term, so
    the guideline ``"flash_attention#pallas" <= "flash_attention#ref"``
    (the kernel must not lose to its own jnp oracle) runs both sides in
    the *same* campaign, and ``+`` sequences kernels inside one timed
    region. ``@half`` has no meaning for single-device kernels and is
    rejected.
    """

    impl: str = "pallas"              # pallas | ref
    batch: int = 1
    heads: int = 4
    kv_heads: int | None = None
    head_dim: int = 32
    state_dim: int = 16
    interpret: bool | None = None     # None = auto (interpret off-TPU)
    seed0: int = 0
    meter: MeterConfig = field(
        default_factory=lambda: MeterConfig(epoch_isolation="clear_caches",
                                            warmup=1))
    name: str = "kernel"

    def make_epoch(self, epoch: int) -> JaxEpochContext:
        def build(_epoch: int) -> dict:
            return {}

        return JaxEpochContext(build, epoch, self.meter)

    def _build_case(self, opexpr: str, msize: int, epoch: int):
        from repro.kernels.ops import make_benchmark_op

        fns = []
        for t in parse_opexpr(opexpr):
            if t.procs == "half":
                raise ValueError("KernelBackend: '@half' has no meaning for "
                                 f"single-device kernels (case {opexpr!r})")
            fns.append(make_benchmark_op(
                t.op, t.impl or self.impl, seq=t.msize(msize),
                batch=self.batch, heads=self.heads, kv_heads=self.kv_heads,
                head_dim=self.head_dim, state_dim=self.state_dim,
                seed=self.seed0 + epoch, interpret=self.interpret))
        return _sequence_calls(fns)

    def measure(self, ctx: JaxEpochContext, case: TestCase,
                nrep: int) -> np.ndarray:
        key = f"{case.op}@{case.msize}"
        if key not in ctx.callables:
            ctx.callables[key] = self._build_case(case.op, case.msize,
                                                  ctx.epoch)
        return ctx.measure(key, nrep)

    def factors(self, design: ExperimentDesign) -> FactorSet:
        return capture_factors(
            measurement_backend=self.name,
            sync_method="block_until_ready",
            epoch_isolation=self.meter.epoch_isolation,
            extra=(("impl", self.impl), ("batch", self.batch),
                   ("heads", self.heads), ("kv_heads", self.kv_heads),
                   ("head_dim", self.head_dim),
                   ("state_dim", self.state_dim), ("seed0", self.seed0),
                   ("interpret", self.interpret)),
            **_design_factor_kw(design),
        )

    def default_cases(self) -> list[TestCase]:
        return [TestCase("flash_attention", s) for s in (64, 128)]


# ---------------------------------------------------------------------------
# Legacy-pair adapter
# ---------------------------------------------------------------------------

@dataclass
class FunctionBackend:
    """Lift a bare ``(epoch_factory, measure)`` pair into the
    :class:`MeasurementBackend` protocol.

    The migration path off the deprecated legacy form of
    :func:`~repro.core.design.run_design`: anything that could be
    expressed as the pair is expressible as this backend, and gains what
    the pair never had — a :class:`~repro.core.factors.FactorSet` (so
    results can live in stores, sweeps and audits) and a ``default_cases``
    hook. ``name`` lands in the factor set's ``measurement_backend``
    field: give two different measurement functions two different names,
    or their campaigns will collide on one fingerprint.
    """

    epoch_factory: Any                 # Callable[[int], Any]
    measure_fn: Any                    # Callable[[Any, TestCase, int], array]
    name: str = "function"
    cases: tuple = ()

    def make_epoch(self, epoch: int) -> Any:
        return self.epoch_factory(epoch)

    def measure(self, ctx: Any, case: TestCase, nrep: int) -> np.ndarray:
        return np.asarray(self.measure_fn(ctx, case, nrep), np.float64)

    def factors(self, design: ExperimentDesign) -> FactorSet:
        return capture_factors(
            measurement_backend=self.name,
            **_design_factor_kw(design),
        )

    def default_cases(self) -> list[TestCase]:
        return [TestCase(op, int(m)) for op, m in self.cases]
