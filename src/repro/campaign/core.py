"""The Campaign orchestrator: the paper's method end-to-end, resumable.

A :class:`CampaignSpec` (cases + :class:`~repro.core.design.ExperimentDesign`)
run by :class:`Campaign` against any
:class:`~repro.campaign.backends.MeasurementBackend` executes the full
pipeline —

  factor capture → launch-epoch replication → randomized case order →
  (adaptive-nrep) measurement → persistent store → Tukey + per-epoch
  averages (Alg. 6)

— and returns a :class:`CampaignResult`. With a
:class:`~repro.campaign.store.ResultStore` attached, every measured cell is
appended the moment it exists, and re-running the identical spec *resumes*:
cells already in the store are loaded instead of re-measured (the epoch
context is not even built unless a cell in that epoch is missing). Case
orders are drawn up front from the design seed exactly as
:func:`~repro.core.design.run_design` draws them, so a campaign resumed at
an epoch boundary yields records identical to an uninterrupted one. Inside
a partially measured epoch, the missing cells are measured fresh against a
rebuilt epoch context — valid observations of the same cell, but not
bit-identical to what the uninterrupted run would have drawn when the
backend's RNG state advances per measurement (the simulator's does).
"""

from __future__ import annotations

import platform
from dataclasses import dataclass, field

from repro.core.design import (NREP_SPENT, ExperimentDesign,
                               MeasurementRecord, ResultTable, TestCase,
                               analyze_records, case_orders, measure_case)
from repro.core.factors import FactorSet

from .backends import MeasurementBackend
from .store import ResultStore, StoreSnapshot

__all__ = ["CampaignSpec", "CampaignResult", "Campaign"]


def _engine_stats() -> dict:
    """Cumulative jit telemetry of the simulation engine (zeros when jax
    is absent — `engine_stats` itself never imports jax)."""
    from repro.simjax import engine_stats

    return engine_stats()


def _jit_delta(before: dict, after: dict) -> dict | None:
    """This campaign's share of the jit telemetry: dispatches issued and
    traces newly compiled while it ran, plus the trace-cache hit rate
    (dispatches served without a fresh compile). None when the campaign
    never touched the jit engine — meta stays clean for other backends."""
    nd = after["n_dispatches"] - before["n_dispatches"]
    if nd <= 0:
        return None
    nt = after["n_traces"] - before["n_traces"]
    return dict(n_traces=nt, n_dispatches=nd,
                cache_hit_rate=round(1.0 - nt / nd, 4))


@dataclass
class CampaignSpec:
    """What to measure, independent of how: the backend supplies the how."""

    cases: list[TestCase]
    design: ExperimentDesign
    name: str = "campaign"

    def meta(self) -> dict:
        d = self.design
        return dict(
            name=self.name,
            cases=[[c.op, int(c.msize)] for c in self.cases],
            n_launch_epochs=d.n_launch_epochs,
            nrep=d.nrep, nrep_min=d.nrep_min, nrep_max=d.nrep_max,
            rel_ci_target=d.rel_ci_target, shuffle=d.shuffle, seed=d.seed,
        )


@dataclass
class CampaignResult:
    records: list[MeasurementRecord]
    table: ResultTable
    factors: FactorSet
    fingerprint: str | None = None
    n_measured: int = 0               # cells executed this run
    n_resumed: int = 0                # cells loaded from the store
    meta: dict = field(default_factory=dict)


class Campaign:
    """Run a :class:`CampaignSpec` on a backend, optionally through a store.

    ``archive`` — a :class:`~repro.history.RunArchive` — auto-registers the
    store into the cross-run archive after the campaign finishes, so every
    persisted campaign is immediately addressable as an audit baseline or
    candidate; the registered run id lands in ``result.meta["archived_run"]``.
    """

    def __init__(self, spec: CampaignSpec, backend: MeasurementBackend,
                 store: ResultStore | None = None, archive=None):
        if archive is not None and store is None:
            raise ValueError("Campaign: an archive needs a store to "
                             "register (pass store= as well)")
        self.spec = spec
        self.backend = backend
        self.store = store
        self.archive = archive

    def run(self, snapshot: StoreSnapshot | None = None,
            on_record=None, epochs=None) -> CampaignResult:
        """Execute (or resume) the campaign. ``snapshot`` — a
        :meth:`~repro.campaign.ResultStore.snapshot` of the attached store
        — replaces the per-run full-file resume scan; a sweep runs many
        campaigns against one growing file and passes the one snapshot it
        took up front. ``on_record(record)`` fires after every *freshly
        measured* cell is (if a store is attached) durably appended — the
        progress heartbeat a fleet worker's lease is kept alive by.

        ``epochs`` — an iterable of launch-epoch indices — restricts the
        run to a *window* of the design's epochs (budgeted sweeps measure
        a cell round by round). The window must stay inside
        ``design.n_launch_epochs``: epoch count is part of the factor
        fingerprint, so widening the design itself would silently declare
        a different experiment. Case orders for *all* epochs are still
        drawn up front from the design seed, which is why measuring
        epochs ``[0,1)`` now and ``[1,3)`` later appends exactly the
        records an uninterrupted full run would have."""
        spec, backend, store = self.spec, self.backend, self.store
        design = spec.design
        cases = list(spec.cases) or backend.default_cases()
        factors = backend.factors(design)

        if epochs is None:
            epoch_window = None
        else:
            epoch_window = sorted({int(e) for e in epochs})
            bad = [e for e in epoch_window
                   if not 0 <= e < design.n_launch_epochs]
            if bad:
                raise ValueError(
                    f"Campaign: epochs {bad} outside the design's "
                    f"0..{design.n_launch_epochs - 1} range — the epoch "
                    "count is fingerprinted, so a wider window needs a "
                    "new design, not a bigger window")

        fingerprint = None
        done: dict[tuple[str, int, int], MeasurementRecord] = {}
        if store is not None:
            fingerprint = store.append_campaign(factors, spec.meta(),
                                                snapshot=snapshot)
            stored = (snapshot.records.get(fingerprint, [])
                      if snapshot is not None else store.records(fingerprint))
            done = {(r.case.op, r.case.msize, r.epoch): r for r in stored}

        records: list[MeasurementRecord] = []
        n_measured = n_resumed = 0
        orders = list(enumerate(case_orders(design, cases)))
        stats0 = _engine_stats()

        # Fused execution: a backend advertising `measure_epochs` gets the
        # whole window's pending work in one call and may batch epochs into
        # shared device programs. `None` (capability gated off for this
        # configuration) falls back to per-epoch measurement below.
        fused: dict = {}
        measure_epochs = getattr(backend, "measure_epochs", None)
        if measure_epochs is not None:
            work = {}
            for epoch, order in orders:
                if epoch_window is not None and epoch not in epoch_window:
                    continue
                pending = [c for c in order
                           if (c.op, c.msize, epoch) not in done]
                if pending:
                    work[epoch] = pending
            if work:
                fused = measure_epochs(work, design) or {}

        for epoch, order in orders:
            if epoch_window is not None and epoch not in epoch_window:
                continue
            missing = [c for c in order
                       if (c.op, c.msize, epoch) not in done
                       and (c.op, c.msize, epoch) not in fused]
            ctx = backend.make_epoch(epoch) if missing else None
            for case in order:
                key = (case.op, case.msize, epoch)
                if key in done:
                    records.append(done[key])
                    n_resumed += 1
                    continue
                if key in fused:
                    times, meta = fused.pop(key)
                    NREP_SPENT.add(times.size)
                else:
                    times, meta = measure_case(backend.measure, ctx, case,
                                               design)
                # `host` is deliberately NOT part of the fingerprint
                # (FactorSet excludes it), so a merged multi-host store
                # needs it stamped on every record to stay auditable.
                meta.setdefault("host", platform.node())
                # Backend-provided provenance (e.g. which window engine
                # actually ran after fallback resolution). Fused records
                # carry theirs already — their epoch context lives inside
                # the backend's fused call, not here.
                record_meta = getattr(backend, "record_meta", None)
                if record_meta is not None and ctx is not None:
                    for k, v in record_meta(ctx, case).items():
                        meta.setdefault(k, v)
                rec = MeasurementRecord(case=case, epoch=epoch, times=times,
                                        meta=meta)
                if store is not None:
                    store.append_record(fingerprint, rec)
                if on_record is not None:
                    on_record(rec)
                records.append(rec)
                n_measured += 1

        table = analyze_records(records, design.outlier_filter)
        meta = spec.meta()
        jit = _jit_delta(stats0, _engine_stats())
        if jit is not None:
            meta["jit"] = jit
        if self.archive is not None:
            entry = self.archive.register(store.path)
            meta["archived_run"] = entry.run_id
        return CampaignResult(records=records, table=table, factors=factors,
                              fingerprint=fingerprint, n_measured=n_measured,
                              n_resumed=n_resumed, meta=meta)
