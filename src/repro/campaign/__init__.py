"""repro.campaign — pluggable measurement backends, persistent result
stores, and a resumable end-to-end orchestrator for the paper's method.

The architectural spine of "one system, many scenarios": a
:class:`MeasurementBackend` abstracts *what is being measured* (simulated
collectives, real jitted JAX collectives, Pallas kernels) away from *how
the experiment is designed* (:mod:`repro.core.design`) and *where results
live* (:class:`ResultStore`). ::

    from repro.campaign import Campaign, CampaignSpec, SimBackend, ResultStore
    from repro.core import ExperimentDesign, TestCase, compare_tables

    spec = CampaignSpec([TestCase("allreduce", 4096)],
                        ExperimentDesign(n_launch_epochs=10,
                                         nrep_min=20, nrep_max=200))
    res = Campaign(spec, SimBackend(p=16), ResultStore("a.jsonl")).run()
    rows = compare_tables(ResultStore("a.jsonl"), ResultStore("b.jsonl"))
"""

from .backends import (FunctionBackend, JaxBackend, KernelBackend,
                       MeasurementBackend, SimBackend, ensure_host_devices)
from .core import Campaign, CampaignResult, CampaignSpec
from .store import SCHEMA_VERSION, ResultStore, StoreSnapshot
from .sweep import CellResult, SweepResult, SweepScheduler, SweepSpec

__all__ = [
    "MeasurementBackend",
    "FunctionBackend",
    "SimBackend",
    "JaxBackend",
    "KernelBackend",
    "ensure_host_devices",
    "Campaign",
    "CampaignResult",
    "CampaignSpec",
    "ResultStore",
    "StoreSnapshot",
    "SCHEMA_VERSION",
    "SweepSpec",
    "SweepScheduler",
    "SweepResult",
    "CellResult",
]
