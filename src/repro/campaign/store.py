"""Append-only persistent result store (JSONL), keyed by factor fingerprint.

PGMPI-style self-consistency checking (Hunold et al.) needs durable,
factor-annotated results that survive the process and can be compared
across runs, machines and backends. The store is a single append-only
JSONL file holding two kinds of lines:

  ``{"kind": "campaign", "fingerprint": ..., "factors": {...}, "spec": ...}``
      declares a campaign: the full :class:`~repro.core.factors.FactorSet`
      and the spec metadata, written once per fingerprint;

  ``{"kind": "record", "fingerprint": ..., "op": ..., "msize": ...,
     "epoch": ..., "times": [...], "meta": {...}}``
      one measured cell (case x launch epoch), appended the moment it is
      measured — so a killed campaign loses at most one cell.

Appending is atomic at line granularity, times round-trip exactly
(``json`` emits shortest-repr doubles), and a truncated final line (crash
mid-write) is skipped on load. The fingerprint key means one file can hold
many campaigns; :meth:`ResultStore.to_table` makes a store directly
consumable by :func:`~repro.core.compare.compare_tables`.
"""

from __future__ import annotations

import json
import os
import warnings
from pathlib import Path
from typing import Iterable

import numpy as np

from repro.core.design import (MeasurementRecord, ResultTable, TestCase,
                               analyze_records)
from repro.core.factors import FactorSet

__all__ = ["ResultStore"]


class ResultStore:
    """Append-only JSONL store of campaign measurement records."""

    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)

    # -- writing ----------------------------------------------------------

    def _append(self, obj: dict) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(obj, sort_keys=True) + "\n")
            f.flush()

    def append_campaign(self, factors: FactorSet, spec: dict | None = None) -> str:
        """Declare a campaign; returns its fingerprint.

        Campaign identity is the *factor* fingerprint, deliberately not the
        spec's case list: growing a campaign with new cases or message
        sizes under unchanged experimental conditions is a resume of the
        same experiment, not a new one (cells are keyed per case x epoch).
        A fingerprint already declared with the same spec is not
        re-declared — which is what makes re-running a *resume* — but a
        changed spec appends a fresh declaration so the file's last
        declaration always describes the data actually in it.
        """
        fp = factors.fingerprint()
        spec = spec or {}
        last_spec = None
        for obj in self._lines():
            if obj.get("kind") == "campaign" and obj["fingerprint"] == fp:
                last_spec = obj.get("spec", {})
        if last_spec != spec:
            self._append(dict(kind="campaign", fingerprint=fp,
                              factors=factors.to_dict(), spec=spec))
        return fp

    def append_record(self, fingerprint: str, rec: MeasurementRecord) -> None:
        self._append(dict(
            kind="record", fingerprint=fingerprint,
            op=rec.case.op, msize=int(rec.case.msize), epoch=int(rec.epoch),
            times=[float(t) for t in np.asarray(rec.times, np.float64)],
            invalid_fraction=float(rec.invalid_fraction),
            meta=_jsonable(rec.meta),
        ))

    # -- reading ----------------------------------------------------------

    def _lines(self) -> Iterable[dict]:
        if not self.path.exists():
            return
        with open(self.path) as f:
            for lineno, line in enumerate(f, start=1):
                line = line.strip()
                if not line:
                    continue
                try:
                    yield json.loads(line)
                except json.JSONDecodeError:
                    # A truncated tail line (crashed writer) is expected and
                    # safe to drop — the cell was never fully measured — but
                    # dropping it *silently* hides that a campaign was
                    # killed mid-write; a bad line before the tail means
                    # real corruption and deserves the louder wording.
                    warnings.warn(
                        f"{self.path}:{lineno}: dropping undecodable JSONL "
                        "line (truncated write from a killed campaign, or "
                        "file corruption); the cell it held will be "
                        "re-measured on resume", RuntimeWarning,
                        stacklevel=3)
                    continue

    def fingerprints(self) -> list[str]:
        """Campaign fingerprints in file (declaration) order."""
        seen: list[str] = []
        for obj in self._lines():
            if obj.get("kind") == "campaign" and obj["fingerprint"] not in seen:
                seen.append(obj["fingerprint"])
        return seen

    def factors(self, fingerprint: str | None = None) -> dict:
        """The declared factor dict of a campaign (default: the last one)."""
        out: dict | None = None
        for obj in self._lines():
            if obj.get("kind") != "campaign":
                continue
            if fingerprint is None or obj["fingerprint"] == fingerprint:
                out = obj["factors"]
        if out is None:
            raise KeyError(f"no campaign {fingerprint!r} in {self.path}")
        return out

    def completed(self, fingerprint: str) -> set[tuple[str, int, int]]:
        """``(op, msize, epoch)`` keys of every cell already measured."""
        return {(o["op"], int(o["msize"]), int(o["epoch"]))
                for o in self._lines()
                if o.get("kind") == "record"
                and o["fingerprint"] == fingerprint}

    def records(self, fingerprint: str | None = None) -> list[MeasurementRecord]:
        """Measurement records of one campaign (default: the last declared
        fingerprint), in append order."""
        if fingerprint is None:
            fps = self.fingerprints()
            if not fps:
                return []
            fingerprint = fps[-1]
        out: list[MeasurementRecord] = []
        for o in self._lines():
            if o.get("kind") != "record" or o["fingerprint"] != fingerprint:
                continue
            out.append(MeasurementRecord(
                case=TestCase(o["op"], int(o["msize"])),
                epoch=int(o["epoch"]),
                times=np.asarray(o["times"], np.float64),
                invalid_fraction=float(o.get("invalid_fraction", 0.0)),
                meta=o.get("meta", {}),
            ))
        return out

    def to_table(self, fingerprint: str | None = None,
                 outlier_filter: bool = True) -> ResultTable:
        """Algorithm-6 reduction of a stored campaign — the adapter that
        lets ``compare_tables(store_a, store_b)`` work directly."""
        return analyze_records(self.records(fingerprint), outlier_filter)


def _jsonable(meta: dict) -> dict:
    out = {}
    for k, v in (meta or {}).items():
        if isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        elif isinstance(v, np.ndarray):
            v = v.tolist()
        try:
            json.dumps(v)
        except TypeError:
            v = repr(v)
        out[k] = v
    return out
