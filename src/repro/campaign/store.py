"""Append-only persistent result store (JSONL), keyed by factor fingerprint.

PGMPI-style self-consistency checking (Hunold et al.) needs durable,
factor-annotated results that survive the process and can be compared
across runs, machines and backends. The store is a single append-only
JSONL file holding two kinds of lines:

  ``{"kind": "campaign", "fingerprint": ..., "factors": {...}, "spec": ...}``
      declares a campaign: the full :class:`~repro.core.factors.FactorSet`
      and the spec metadata, written once per fingerprint;

  ``{"kind": "record", "fingerprint": ..., "op": ..., "msize": ...,
     "epoch": ..., "times": [...], "meta": {...}}``
      one measured cell (case x launch epoch), appended the moment it is
      measured — so a killed campaign loses at most one cell.

Appending is atomic at line granularity, times round-trip exactly
(``json`` emits shortest-repr doubles), and a truncated final line (crash
mid-write) is skipped on load. The fingerprint key means one file can hold
many campaigns; :meth:`ResultStore.to_table` makes a store directly
consumable by :func:`~repro.core.compare.compare_tables`.

Two bookkeeping line kinds make stores safe to *archive* across time
(:mod:`repro.history`):

  ``{"kind": "schema", "version": N}``
      stamped as the first line of every new store. Unknown *within*-version
      line kinds stay forward-compatible (readers filter by kind), but a
      file declaring a future schema version refuses to load — silently
      warn-and-dropping its lines would corrupt a resume, the worst failure
      mode for an append-only format;

  ``{"kind": "meta", ...}``
      free-form metadata (archive registration stamps: run id, tag,
      registration time), excluded from the store's content identity.

Fleet execution (:mod:`repro.fleet`) adds ``{"kind": "sweep-cell-failed",
...}`` — a *quarantine* record written when a sweep cell exhausted its
retry budget, carrying the factor fingerprint and last error so partial
results stay honest about what is missing. Budgeted sweeps
(:mod:`repro.sweeps.alloc`) add ``{"kind": "sweep-alloc", ...}`` — one
line per allocation *round*, recording which cells received budget, the
epoch window measured, and the axis verdicts the policy reached on the
data available at that look. Persisting the decisions (not just the
measurements) is what makes a racing sweep kill/resume deterministic:
a resumed run replays the recorded verdicts instead of re-deciding on a
possibly-larger record set. Calibration fits (:mod:`repro.calibrate`)
reuse the same idea with ``{"kind": "calib", ...}`` (the fit manifest:
parameter space bounds, target fingerprint, design) and ``{"kind":
"calib-round", ...}`` (one line per completed search round: the
incumbent parameter vector, its objective, and every evaluation the
round made) — a killed fit replays its persisted rounds and resumes the
search mid-trajectory. Loading skips undecodable
lines with a warning naming the line number and (best-effort) kind, and
counts them in :attr:`ResultStore.n_corrupt`: a torn *tail* is the
ordinary residue of a killed writer, a torn line *mid-file* is the
louder signal of a crashed merge.
"""

from __future__ import annotations

import hashlib
import json
import os
import platform
import re
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable

import numpy as np

from repro.core.design import (MeasurementRecord, ResultTable, TestCase,
                               analyze_records)
from repro.core.factors import FactorSet

__all__ = ["ResultStore", "StoreSnapshot", "SCHEMA_VERSION"]

#: Version of the JSONL line schema this build writes (and the newest it
#: reads). Bump when a line kind changes incompatibly.
SCHEMA_VERSION = 1


def _line_kind(line: str) -> str:
    """Best-effort ``kind`` of an undecodable line: a torn write usually
    keeps its head, so the kind tag often survives the truncation — and a
    warning that says *which* kind of line was lost tells the operator
    whether a measurement, a marker, or mere bookkeeping is gone."""
    m = re.search(r'"kind"\s*:\s*"([a-zA-Z0-9_-]+)"', line)
    return f'"{m.group(1)}"' if m else "unknown-kind"


def _record_from(o: dict) -> MeasurementRecord:
    return MeasurementRecord(
        case=TestCase(o["op"], int(o["msize"])),
        epoch=int(o["epoch"]),
        times=np.asarray(o["times"], np.float64),
        invalid_fraction=float(o.get("invalid_fraction", 0.0)),
        meta=o.get("meta", {}),
    )


@dataclass
class StoreSnapshot:
    """A one-pass index of a store file, for write paths that would
    otherwise re-scan the whole JSONL per operation.

    A sweep touching N cells consults the store ~3 times per cell
    (campaign dedup, resume lookup, completion markers); against a
    growing file that is O(N^2) parsing. ``ResultStore.snapshot()`` reads
    the file once; the snapshot-aware append methods keep it coherent for
    everything *this* process appends. Single-writer only — a snapshot
    does not see lines appended by anyone else after it was taken.
    """

    campaign_specs: dict = field(default_factory=dict)   # fp -> last spec
    campaign_factors: dict = field(default_factory=dict)  # fp -> factor dict
    records: dict = field(default_factory=dict)          # fp -> [records]
    sweeps: list = field(default_factory=list)           # ids, file order
    manifests: dict = field(default_factory=dict)        # id -> manifest
    sweep_cells_by_id: dict = field(default_factory=dict)  # id -> {cell: fp}
    sweep_failed_by_id: dict = field(default_factory=dict)  # id -> {cell: info}
    sweep_alloc_by_id: dict = field(default_factory=dict)  # id -> [rounds]
    calibs: list = field(default_factory=list)           # ids, file order
    calib_manifests: dict = field(default_factory=dict)  # id -> manifest
    calib_rounds_by_id: dict = field(default_factory=dict)  # id -> [rounds]
    n_corrupt: int = 0             # undecodable lines skipped in this pass

    def completed(self, fingerprint: str) -> set:
        return {(r.case.op, r.case.msize, r.epoch)
                for r in self.records.get(fingerprint, [])}


class ResultStore:
    """Append-only JSONL store of campaign measurement records."""

    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        #: Undecodable lines skipped during the most recent full parse —
        #: the visible residue of torn writes (crashed writer, killed
        #: merge). Zero on a healthy file; a nonzero count after loading
        #: is the signal an audit should not silently absorb.
        self.n_corrupt = 0

    # -- writing ----------------------------------------------------------

    def _append(self, obj: dict) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        header = None
        heal = False
        if not self.path.exists() or self.path.stat().st_size == 0:
            if obj.get("kind") != "schema":
                header = dict(kind="schema", version=SCHEMA_VERSION)
        else:
            # a killed writer can leave the file without a trailing
            # newline (torn tail); appending straight onto it would glue
            # the new line into the garbage and silently lose *this*
            # append on the next load — terminate the torn line first so
            # it is skipped alone
            with open(self.path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                heal = f.read(1) != b"\n"
        with open(self.path, "a") as f:
            if heal:
                f.write("\n")
            if header is not None:
                f.write(json.dumps(header, sort_keys=True) + "\n")
            f.write(json.dumps(obj, sort_keys=True) + "\n")
            f.flush()

    def append_meta(self, **fields) -> None:
        """Append a free-form metadata line (``kind="meta"``) — e.g. the
        archive-registration stamp. Meta lines are bookkeeping, not data:
        they are excluded from the store's content identity
        (:meth:`~repro.history.RunArchive.register` hashes around them),
        so stamping a store does not turn it into a different run."""
        self._append(dict(kind="meta", **_jsonable(fields)))

    def meta(self) -> dict:
        """All metadata lines merged in file order (later stamps win)."""
        out: dict = {}
        for obj in self._lines():
            if obj.get("kind") == "meta":
                out.update({k: v for k, v in obj.items() if k != "kind"})
        return out

    def schema_version(self) -> int:
        """The file's declared schema version (0 = legacy, pre-header)."""
        if not self.path.exists():
            return SCHEMA_VERSION
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                except json.JSONDecodeError:
                    return 0
                if isinstance(obj, dict) and obj.get("kind") == "schema":
                    v = obj.get("version")
                    return v if isinstance(v, int) else 0
                return 0
        return 0

    def append_campaign(self, factors: FactorSet, spec: dict | None = None,
                        snapshot: StoreSnapshot | None = None) -> str:
        """Declare a campaign; returns its fingerprint.

        Campaign identity is the *factor* fingerprint, deliberately not the
        spec's case list: growing a campaign with new cases or message
        sizes under unchanged experimental conditions is a resume of the
        same experiment, not a new one (cells are keyed per case x epoch).
        A fingerprint already declared with the same spec is not
        re-declared — which is what makes re-running a *resume* — but a
        changed spec appends a fresh declaration so the file's last
        declaration always describes the data actually in it.

        With a ``snapshot``, the already-declared check consults it
        instead of re-scanning the file (and updates it on append).
        """
        fp = factors.fingerprint()
        spec = spec or {}
        if snapshot is not None:
            last_spec = snapshot.campaign_specs.get(fp)
        else:
            last_spec = None
            for obj in self._lines():
                if obj.get("kind") == "campaign" and obj["fingerprint"] == fp:
                    last_spec = obj.get("spec", {})
        if last_spec != spec:
            self._append(dict(kind="campaign", fingerprint=fp,
                              factors=factors.to_dict(), spec=spec))
            if snapshot is not None:
                snapshot.campaign_specs[fp] = spec
                snapshot.campaign_factors[fp] = factors.to_dict()
        return fp

    def append_record(self, fingerprint: str, rec: MeasurementRecord) -> None:
        meta = _jsonable(rec.meta)
        # host is excluded from the fingerprint by design; without it in the
        # record meta a merged multi-host store cannot attribute its cells
        meta.setdefault("host", platform.node())
        self._append(dict(
            kind="record", fingerprint=fingerprint,
            op=rec.case.op, msize=int(rec.case.msize), epoch=int(rec.epoch),
            times=[float(t) for t in np.asarray(rec.times, np.float64)],
            invalid_fraction=float(rec.invalid_fraction),
            meta=meta,
        ))

    # -- sweep manifests ---------------------------------------------------

    def append_sweep(self, manifest: dict,
                     snapshot: StoreSnapshot | None = None) -> str:
        """Declare a factor sweep; returns its deterministic sweep id.

        The manifest (grid axes, per-cell levels and fingerprints, spec
        meta) is the map that lets one JSONL file hold a whole sweep: the
        campaign/record lines carry the measurements, the sweep line says
        which fingerprints form the grid, and :meth:`append_sweep_cell`
        markers make resume *cell*-granular. The id is a hash of the
        manifest content, so re-declaring the same sweep is a no-op and a
        re-run finds its own markers.
        """
        blob = json.dumps(manifest, sort_keys=True, default=str)
        sweep_id = hashlib.sha256(blob.encode()).hexdigest()[:16]
        if snapshot is not None:
            if sweep_id in snapshot.sweeps:
                return sweep_id
        else:
            for obj in self._lines():
                if obj.get("kind") == "sweep" and obj["sweep"] == sweep_id:
                    return sweep_id
        self._append(dict(kind="sweep", sweep=sweep_id, manifest=manifest))
        if snapshot is not None:
            snapshot.sweeps.append(sweep_id)
        return sweep_id

    def append_sweep_cell(self, sweep_id: str, index: int,
                          fingerprint: str) -> None:
        """Mark one grid cell as completely measured (its campaign records
        are already in the file). Written *after* the cell's last record,
        so a killed sweep never marks a half-measured cell."""
        self._append(dict(kind="sweep-cell", sweep=sweep_id,
                          cell=int(index), fingerprint=fingerprint))

    def append_sweep_cell_failed(self, sweep_id: str, index: int,
                                 fingerprint: str, attempts: int,
                                 error: str) -> None:
        """Quarantine one grid cell: every retry failed, and the sweep is
        degrading to partial-but-honest results instead of wedging. The
        record carries the factor fingerprint and the last error, so the
        analysis layer can say exactly *which* experiment is missing and
        why — a silently absent cell would bias which cells get measured,
        the §5.2 failure mode a fleet must not have."""
        self._append(dict(kind="sweep-cell-failed", sweep=sweep_id,
                          cell=int(index), fingerprint=fingerprint,
                          attempts=int(attempts), error=str(error)[:500]))

    def append_sweep_alloc(self, sweep_id: str, round: int, cells: list[int],
                           epochs: tuple[int, int], decisions: dict,
                           spent_nrep: int, policy: str) -> None:
        """Record one completed allocation round of a budgeted sweep: the
        cells that received budget, the launch-epoch window ``[lo, hi)``
        measured, and the per-axis verdicts the policy reached at this
        look. Written *after* the round's last record, so a killed sweep
        either replays the persisted verdicts (line present) or
        re-derives them from exactly the records the round produced (line
        absent, measurements record-granular resumable) — both paths land
        on the same allocation sequence."""
        self._append(dict(
            kind="sweep-alloc", sweep=sweep_id, round=int(round),
            cells=[int(c) for c in cells],
            epochs=[int(epochs[0]), int(epochs[1])],
            decisions=_jsonable(decisions), spent_nrep=int(spent_nrep),
            policy=str(policy)))

    def sweep_allocs(self, sweep_id: str) -> list[dict]:
        """Allocation-round lines of a sweep, ordered by round index.

        Duplicate round indices keep the *first* occurrence: a resumed
        run that re-appended an identical line (crash between append and
        the next read) must not shadow the decision the original run
        acted on."""
        rounds: dict[int, dict] = {}
        for o in self._lines():
            if o.get("kind") == "sweep-alloc" and o["sweep"] == sweep_id:
                rounds.setdefault(int(o["round"]), o)
        return [rounds[k] for k in sorted(rounds)]

    # -- calibration manifests --------------------------------------------

    def append_calib(self, manifest: dict,
                     snapshot: StoreSnapshot | None = None) -> str:
        """Declare a calibration fit; returns its deterministic calib id.

        The manifest (parameter space bounds, target fingerprint, case
        list, design meta) plays the role :meth:`append_sweep`'s does for
        sweeps: the id is a hash of the manifest content, so re-running
        the same fit finds its own ``calib-round`` lines and resumes the
        search instead of restarting it."""
        blob = json.dumps(manifest, sort_keys=True, default=str)
        calib_id = hashlib.sha256(blob.encode()).hexdigest()[:16]
        if snapshot is not None:
            if calib_id in snapshot.calibs:
                return calib_id
        else:
            for obj in self._lines():
                if obj.get("kind") == "calib" and obj["calib"] == calib_id:
                    return calib_id
        self._append(dict(kind="calib", calib=calib_id, manifest=manifest))
        if snapshot is not None:
            snapshot.calibs.append(calib_id)
            snapshot.calib_manifests[calib_id] = manifest
        return calib_id

    def append_calib_round(self, calib_id: str, round: int, params: dict,
                           objective: float, step: float, evals: list,
                           spent_nrep: int) -> None:
        """Record one completed search round of a calibration fit: the
        incumbent parameter vector and objective after the round, the
        step size the next round starts from, and every (params,
        objective) evaluation the round made. Written *after* the round's
        last measurement, so a killed fit either replays the persisted
        round (line present) or re-evaluates through store-resumed
        campaigns (line absent) — both paths land on the same search
        trajectory."""
        self._append(dict(
            kind="calib-round", calib=calib_id, round=int(round),
            params=_jsonable(params), objective=float(objective),
            step=float(step), evals=_jsonable_value(evals),
            spent_nrep=int(spent_nrep)))

    def calib_rounds(self, calib_id: str) -> list[dict]:
        """Round lines of a calibration fit, ordered by round index.
        Duplicate round indices keep the *first* occurrence (same
        rationale as :meth:`sweep_allocs`)."""
        rounds: dict[int, dict] = {}
        for o in self._lines():
            if o.get("kind") == "calib-round" and o["calib"] == calib_id:
                rounds.setdefault(int(o["round"]), o)
        return [rounds[k] for k in sorted(rounds)]

    def calib_manifest(self, calib_id: str | None = None) -> dict:
        """The declared manifest of a calibration fit (default: last)."""
        out: dict | None = None
        for obj in self._lines():
            if obj.get("kind") != "calib":
                continue
            if calib_id is None or obj["calib"] == calib_id:
                out = obj["manifest"]
        if out is None:
            raise KeyError(f"no calib {calib_id!r} in {self.path}")
        return out

    def sweep_cells_failed(self, sweep_id: str) -> dict[int, dict]:
        """``cell index -> quarantine info`` of every quarantined cell.

        A cell later marked complete (a resumed fleet re-attempted it and
        succeeded) is *removed*: completion supersedes quarantine."""
        out: dict[int, dict] = {}
        for o in self._lines():
            if o.get("kind") == "sweep-cell-failed" and o["sweep"] == sweep_id:
                out[int(o["cell"])] = dict(
                    fingerprint=o["fingerprint"],
                    attempts=int(o.get("attempts", 0)),
                    error=o.get("error", ""))
            elif o.get("kind") == "sweep-cell" and o["sweep"] == sweep_id:
                out.pop(int(o["cell"]), None)
        return out

    def sweeps(self) -> list[str]:
        """Sweep ids in declaration order."""
        out: list[str] = []
        for obj in self._lines():
            if obj.get("kind") == "sweep" and obj["sweep"] not in out:
                out.append(obj["sweep"])
        return out

    def sweep_manifest(self, sweep_id: str | None = None) -> dict:
        """The declared manifest of a sweep (default: the last one)."""
        out: dict | None = None
        for obj in self._lines():
            if obj.get("kind") != "sweep":
                continue
            if sweep_id is None or obj["sweep"] == sweep_id:
                out = obj["manifest"]
        if out is None:
            raise KeyError(f"no sweep {sweep_id!r} in {self.path}")
        return out

    def sweep_cells(self, sweep_id: str) -> dict[int, str]:
        """``cell index -> fingerprint`` of every *completed* cell."""
        return {int(o["cell"]): o["fingerprint"]
                for o in self._lines()
                if o.get("kind") == "sweep-cell" and o["sweep"] == sweep_id}

    def snapshot(self) -> StoreSnapshot:
        """Index the whole file in one pass (see :class:`StoreSnapshot`)."""
        snap = StoreSnapshot()
        for o in self._lines():
            kind = o.get("kind")
            if kind == "campaign":
                snap.campaign_specs[o["fingerprint"]] = o.get("spec", {})
                snap.campaign_factors[o["fingerprint"]] = o.get("factors", {})
            elif kind == "record":
                snap.records.setdefault(o["fingerprint"],
                                        []).append(_record_from(o))
            elif kind == "sweep":
                if o["sweep"] not in snap.sweeps:
                    snap.sweeps.append(o["sweep"])
                snap.manifests[o["sweep"]] = o.get("manifest", {})
            elif kind == "sweep-cell":
                snap.sweep_cells_by_id.setdefault(
                    o["sweep"], {})[int(o["cell"])] = o["fingerprint"]
                # completion supersedes an earlier quarantine of the cell
                snap.sweep_failed_by_id.get(o["sweep"], {}).pop(
                    int(o["cell"]), None)
            elif kind == "sweep-cell-failed":
                snap.sweep_failed_by_id.setdefault(o["sweep"], {})[
                    int(o["cell"])] = dict(
                        fingerprint=o["fingerprint"],
                        attempts=int(o.get("attempts", 0)),
                        error=o.get("error", ""))
            elif kind == "sweep-alloc":
                rounds = snap.sweep_alloc_by_id.setdefault(o["sweep"], [])
                if not any(int(r["round"]) == int(o["round"])
                           for r in rounds):
                    rounds.append(o)
                    rounds.sort(key=lambda r: int(r["round"]))
            elif kind == "calib":
                if o["calib"] not in snap.calibs:
                    snap.calibs.append(o["calib"])
                snap.calib_manifests[o["calib"]] = o.get("manifest", {})
            elif kind == "calib-round":
                rounds = snap.calib_rounds_by_id.setdefault(o["calib"], [])
                if not any(int(r["round"]) == int(o["round"])
                           for r in rounds):
                    rounds.append(o)
                    rounds.sort(key=lambda r: int(r["round"]))
        snap.n_corrupt = self.n_corrupt
        return snap

    # -- reading ----------------------------------------------------------

    def _lines(self) -> Iterable[dict]:
        self.n_corrupt = 0
        if not self.path.exists():
            return
        with open(self.path) as f:
            raw = f.readlines()
        last_lineno = len(raw)
        for lineno, line in enumerate(raw, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                self.n_corrupt += 1
                kind = _line_kind(line)
                if lineno == last_lineno:
                    # A truncated tail line (crashed writer) is expected
                    # and safe to drop — the cell was never fully measured
                    # — but dropping it *silently* hides that a campaign
                    # was killed mid-write.
                    warnings.warn(
                        f"{self.path}:{lineno}: dropping undecodable "
                        f"{kind} tail line (truncated write from a killed "
                        "campaign); the cell it held will be re-measured "
                        "on resume", RuntimeWarning, stacklevel=3)
                else:
                    # Corruption *mid*-file cannot come from an ordinary
                    # kill (appends are line-atomic); it is the residue of
                    # a crash during a merge/compaction, or real file
                    # damage — louder wording, and the count survives in
                    # ``n_corrupt`` so federation and audits can report it.
                    warnings.warn(
                        f"{self.path}:{lineno}: dropping undecodable "
                        f"{kind} line mid-file (crash during a store "
                        "merge, or file corruption); "
                        f"{self.n_corrupt} corrupt line(s) so far — "
                        "counted in store.n_corrupt", RuntimeWarning,
                        stacklevel=3)
                continue
            if isinstance(obj, dict) and obj.get("kind") == "schema":
                # A *future* version is the one skew this reader must
                # not paper over: its line kinds may look like ours but
                # mean something else, and warn-and-drop would silently
                # re-measure (or worse, merge) a resumed campaign.
                version = obj.get("version")
                if not isinstance(version, int) \
                        or version > SCHEMA_VERSION:
                    raise ValueError(
                        f"{self.path}: store declares schema version "
                        f"{version!r}, but this build reads <= "
                        f"{SCHEMA_VERSION} — refusing to load (upgrade "
                        "the reader, or re-measure into a fresh store)")
                continue
            yield obj

    def fingerprints(self) -> list[str]:
        """Campaign fingerprints in file (declaration) order."""
        seen: list[str] = []
        for obj in self._lines():
            if obj.get("kind") == "campaign" and obj["fingerprint"] not in seen:
                seen.append(obj["fingerprint"])
        return seen

    def factors(self, fingerprint: str | None = None) -> dict:
        """The declared factor dict of a campaign (default: the last one)."""
        out: dict | None = None
        for obj in self._lines():
            if obj.get("kind") != "campaign":
                continue
            if fingerprint is None or obj["fingerprint"] == fingerprint:
                out = obj["factors"]
        if out is None:
            raise KeyError(f"no campaign {fingerprint!r} in {self.path}")
        return out

    def completed(self, fingerprint: str) -> set[tuple[str, int, int]]:
        """``(op, msize, epoch)`` keys of every cell already measured."""
        return {(o["op"], int(o["msize"]), int(o["epoch"]))
                for o in self._lines()
                if o.get("kind") == "record"
                and o["fingerprint"] == fingerprint}

    def records(self, fingerprint: str | None = None) -> list[MeasurementRecord]:
        """Measurement records of one campaign (default: the last declared
        fingerprint), in append order."""
        if fingerprint is None:
            fps = self.fingerprints()
            if not fps:
                return []
            fingerprint = fps[-1]
        return [_record_from(o) for o in self._lines()
                if o.get("kind") == "record"
                and o["fingerprint"] == fingerprint]

    def to_table(self, fingerprint: str | None = None,
                 outlier_filter: bool = True) -> ResultTable:
        """Algorithm-6 reduction of a stored campaign — the adapter that
        lets ``compare_tables(store_a, store_b)`` work directly."""
        return analyze_records(self.records(fingerprint), outlier_filter)


def _jsonable_value(v):
    """One value made JSON-serializable, *recursively*: numpy scalars and
    arrays convert losslessly at any nesting depth (a ``meta["jit"]``
    telemetry dict or a calibration fit report full of ``np.float64`` must
    round-trip as numbers, not ``repr()`` strings), containers convert
    element-wise, and only a leaf that still defies ``json.dumps`` after
    all that degrades to its ``repr``."""
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {str(k): _jsonable_value(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable_value(x) for x in v]
    try:
        json.dumps(v)
    except (TypeError, ValueError):
        return repr(v)
    return v


def _jsonable(meta: dict) -> dict:
    return {str(k): _jsonable_value(v) for k, v in (meta or {}).items()}
