"""Sharded, resumable factor-sweep campaigns.

The paper's headline result is *which experimental factors matter*; to
answer that for a new system the factor space has to be executable, not
just recorded. A :class:`SweepSpec` pairs a
:class:`~repro.core.factors.FactorGrid` (enumerable factor axes) with a
case list and a base :class:`~repro.core.design.ExperimentDesign`; the
:class:`SweepScheduler` compiles every grid cell into an ordinary
:class:`~repro.campaign.Campaign` (cell levels applied by dataclass
replacement, so each cell's :class:`~repro.core.factors.FactorSet` comes
from the backend's own ``factors()`` plumbing) and runs them all —
serially, or sharded over a process pool through the same
:func:`~repro.core.design.map_parallel` machinery that fans out launch
epochs.

Persistence lives in one JSONL :class:`~repro.campaign.ResultStore` for
the whole sweep: a ``sweep`` manifest line declares the grid (axes, per-
cell levels and fingerprints), the cells' campaign/record lines carry the
measurements, and a ``sweep-cell`` marker is appended only after a cell's
last record — so a killed sweep resumes at *cell* granularity (marked
cells load instead of re-measuring), and in the serial path a cell that
was itself killed mid-campaign additionally resumes at *record*
granularity through the normal campaign resume. Sharded workers measure
whole cells and the parent persists each cell the moment it completes.

With an :class:`~repro.sweeps.alloc.AllocationPolicy` attached
(``policy=``), the scheduler runs *budgeted*: the policy plans rounds —
a launch-epoch window over the currently surviving cells — and after
each round decides, on the accumulated data, which factor axes are
resolved (MATTERS or null) and can stop receiving budget. Rounds execute
through the same ``_execute_pending`` hook as everything else (so the
fleet's lease queue gets rounds of leased work for free), each round's
verdicts are persisted as a ``sweep-alloc`` line, and a cell's
``sweep-cell`` marker is written only when the *allocation* finishes —
for a budgeted sweep the marker means "the policy is done with this
cell", which may be well short of the design's full epoch count.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.design import (ExperimentDesign, ResultTable, TestCase,
                               analyze_records, map_parallel)
from repro.core.factors import FactorGrid, FactorSet, GridCell

from .backends import fallback_warning_scope
from .core import Campaign, CampaignResult, CampaignSpec
from .store import ResultStore

__all__ = ["SweepSpec", "CellResult", "SweepResult", "SweepScheduler"]


@dataclass
class SweepSpec:
    """What to sweep: the factor grid, the cases measured in every cell,
    and the base design each cell derives its own design from."""

    grid: FactorGrid
    cases: list[TestCase]
    design: ExperimentDesign
    name: str = "sweep"

    def cell_spec(self, cell: GridCell, design: ExperimentDesign) -> CampaignSpec:
        return CampaignSpec(cases=list(self.cases), design=design,
                            name=f"{self.name}/cell{cell.index:03d}")


@dataclass
class CellResult:
    """One measured (or resumed) grid cell."""

    cell: GridCell
    factors: FactorSet
    fingerprint: str
    table: ResultTable
    n_measured: int = 0            # record cells executed this run
    n_resumed: int = 0             # record cells loaded from the store

    def levels(self) -> dict[str, str]:
        return self.cell.levels()


@dataclass
class SweepResult:
    cells: list[CellResult]
    sweep_id: str | None = None
    n_cells_measured: int = 0      # grid cells with fresh measurements
    n_cells_resumed: int = 0       # grid cells loaded entirely from store
    meta: dict = field(default_factory=dict)


def _run_cell(backend, cases, design, name, epochs=None) -> CampaignResult:
    """Measure one grid cell in a worker process. No store attached — the
    parent persists each finished cell (one writer per JSONL file)."""
    return Campaign(CampaignSpec(list(cases), design, name=name),
                    backend).run(epochs=epochs)


class SweepScheduler:
    """Compile a grid x case list into per-cell campaigns and run them.

    ``n_workers > 1`` shards whole cells over a process pool (each worker
    runs its cell's launch epochs serially); the parent appends finished
    cells to the store as they complete, so even a killed sharded sweep
    keeps every completed cell.

    ``policy`` — an :class:`~repro.sweeps.alloc.AllocationPolicy`
    instance or registry name (``"uniform"``, ``"racing"``,
    ``"successive_halving"``) — switches :meth:`run` to the budgeted
    round loop. A store is then required: the round decisions must
    persist for kill/resume to replay them.
    """

    def __init__(self, spec: SweepSpec, backend,
                 store: ResultStore | None = None, n_workers: int = 1,
                 policy=None):
        if isinstance(policy, str):
            from repro.sweeps.alloc import make_policy
            policy = make_policy(policy)
        self.spec = spec
        self.backend = backend
        self.store = store
        self.n_workers = max(1, int(n_workers))
        self.policy = policy
        #: the launch-epoch window ``(lo, hi)`` of the budgeted round being
        #: executed, or ``None`` outside one. Execution paths consult it to
        #: window their campaigns and to *suppress* ``sweep-cell`` markers
        #: (a cell is not complete just because one round touched it).
        self._round_epochs: tuple[int, int] | None = None

    # -- compilation -------------------------------------------------------

    def compile(self) -> list[tuple[GridCell, object, ExperimentDesign,
                                    FactorSet, str]]:
        """Materialize every grid cell and verify fingerprint uniqueness.

        A collision means an axis varies something the backend does not
        surface in its ``factors()`` — running it would silently merge two
        different experiments under one store key, so it is an error here,
        before anything is measured.
        """
        out = []
        seen: dict[str, GridCell] = {}
        for cell in self.spec.grid.cells():
            backend, design = cell.materialize(self.backend, self.spec.design)
            factors = backend.factors(design)
            fp = factors.fingerprint()
            if fp in seen:
                raise ValueError(
                    f"factor grid cells {seen[fp].levels()} and "
                    f"{cell.levels()} share fingerprint {fp} — an axis "
                    "level is not reflected in the backend's FactorSet")
            seen[fp] = cell
            out.append((cell, backend, design, factors, fp))
        return out

    # -- execution ---------------------------------------------------------

    def run(self) -> SweepResult:
        # One engine-fallback warning per distinct reason per *sweep* —
        # the per-cell campaigns inside share a single dedup scope.
        with fallback_warning_scope():
            if self.policy is not None:
                return self._run_adaptive()
            return self._run_uniform()

    def _run_uniform(self) -> SweepResult:
        spec, store = self.spec, self.store
        compiled = self.compile()

        sweep_id = None
        done: dict[int, str] = {}
        # one full-file scan for the whole sweep: every per-cell store
        # consultation below (campaign dedup, resume lookup, completed-set)
        # goes through this snapshot instead of re-parsing the JSONL
        snapshot = store.snapshot() if store is not None else None
        if store is not None:
            manifest = dict(
                spec.grid.manifest(), name=spec.name,
                cases=[[c.op, int(c.msize)] for c in spec.cases],
                cells=[[cell.index, fp, cell.levels()]
                       for cell, _, _, _, fp in compiled],
            )
            sweep_id = store.append_sweep(manifest, snapshot=snapshot)
            done = snapshot.sweep_cells_by_id.get(sweep_id, {})

        results: dict[int, CellResult] = {}
        pending = []
        for entry in compiled:
            cell, backend, design, factors, fp = entry
            if store is not None and self._cell_complete(cell, design, fp,
                                                         sweep_id, done,
                                                         snapshot):
                records = snapshot.records.get(fp, [])
                results[cell.index] = CellResult(
                    cell=cell, factors=factors, fingerprint=fp,
                    table=analyze_records(records, design.outlier_filter),
                    n_resumed=len(records))
            else:
                pending.append(entry)

        results.update(self._execute_pending(pending, sweep_id, snapshot))

        cells = [results[i] for i in sorted(results)]
        return SweepResult(
            cells=cells, sweep_id=sweep_id,
            n_cells_measured=sum(1 for c in cells if c.n_measured),
            n_cells_resumed=sum(1 for c in cells if not c.n_measured),
            meta=dict(name=spec.name, n_cells=len(cells),
                      axes=[ax.name for ax in spec.grid.axes],
                      n_workers=self.n_workers),
        )

    def _run_adaptive(self) -> SweepResult:
        """The budgeted round loop: plan → execute → look → persist.

        Every round executes through :meth:`_execute_pending` (the same
        hook the fleet overrides), restricted to the plan's cells and
        epoch window; measurement resume is *record*-granular, so a round
        interrupted anywhere picks up exactly where it died. After each
        round the policy looks at a fresh store snapshot and its verdicts
        are appended as a ``sweep-alloc`` line — unless that round's line
        already exists (a killed run being resumed), in which case the
        persisted verdicts are replayed instead of re-deciding on what
        might by now be a larger record set. Since policies are pure
        functions of the observed records, both paths produce the same
        allocation sequence — which is what keeps fleet == serial
        bit-identity and the kill/resume property intact under racing.
        """
        from dataclasses import asdict

        from repro.sweeps.alloc import build_state

        spec, store, policy = self.spec, self.store, self.policy
        if store is None:
            raise ValueError(
                "budgeted sweeps need a store: allocation rounds persist "
                "their decisions as sweep-alloc lines (pass store=)")
        if not spec.cases:
            raise ValueError(
                "budgeted sweeps need an explicit case list — round "
                "completeness is undecidable without it")
        compiled = self.compile()
        by_index = {entry[0].index: entry for entry in compiled}
        n_epochs_max = spec.design.n_launch_epochs

        snapshot = store.snapshot()
        manifest = dict(
            spec.grid.manifest(), name=spec.name,
            cases=[[c.op, int(c.msize)] for c in spec.cases],
            cells=[[cell.index, fp, cell.levels()]
                   for cell, _, _, _, fp in compiled],
            policy=policy.manifest(),
        )
        sweep_id = store.append_sweep(manifest, snapshot=snapshot)

        fresh: set[int] = set()        # cells with new records this run
        rounds: list[dict] = []
        while True:
            state = build_state(manifest, snapshot, sweep_id, n_epochs_max,
                                spec.design.outlier_filter)
            plan = policy.plan_round(state)
            if plan is None:
                break
            lo, hi = plan.epochs
            quarantined = snapshot.sweep_failed_by_id.get(sweep_id, {})
            window = {(c.op, int(c.msize), e)
                      for c in spec.cases for e in range(lo, hi)}
            pending = [by_index[i] for i in plan.cells
                       if i in by_index and i not in quarantined
                       and not window <= snapshot.completed(by_index[i][4])]
            if pending:
                self._round_epochs = (lo, hi)
                try:
                    measured = self._execute_pending(pending, sweep_id,
                                                     snapshot)
                finally:
                    self._round_epochs = None
                fresh.update(i for i, r in measured.items() if r.n_measured)
            # decide on a *fresh* snapshot: round execution (serial
            # campaigns, fleet shard merges) appends records the in-memory
            # snapshot does not fully track
            snapshot = store.snapshot()
            persisted = snapshot.sweep_alloc_by_id.get(sweep_id, [])
            if plan.round >= len(persisted):
                state = build_state(manifest, snapshot, sweep_id,
                                    n_epochs_max,
                                    spec.design.outlier_filter)
                decisions = policy.decide(state)
                store.append_sweep_alloc(
                    sweep_id, plan.round, list(plan.cells), (lo, hi),
                    {a: asdict(d) for a, d in decisions.items()},
                    state.spent_nrep, policy.name)
                snapshot.sweep_alloc_by_id.setdefault(sweep_id, []).append(
                    dict(kind="sweep-alloc", sweep=sweep_id,
                         round=plan.round, cells=list(plan.cells),
                         epochs=[lo, hi],
                         decisions={a: asdict(d)
                                    for a, d in decisions.items()},
                         spent_nrep=state.spent_nrep, policy=policy.name))
            rounds.append(dict(round=plan.round, epochs=[lo, hi],
                               n_cells=len(plan.cells)))

        # `state` is the snapshot-fresh view the loop broke on
        failed = snapshot.sweep_failed_by_id.get(sweep_id, {})
        marked = snapshot.sweep_cells_by_id.get(sweep_id, {})
        cells_out: list[CellResult] = []
        for cell, backend, design, factors, fp in compiled:
            records = snapshot.records.get(fp, [])
            if not records or cell.index in failed:
                continue               # quarantined (or never measured)
            if cell.index not in marked:
                # allocation finished with this cell — marker written now,
                # not per round, so a killed budgeted sweep never claims a
                # cell the policy might still have extended
                store.append_sweep_cell(sweep_id, cell.index, fp)
                marked[cell.index] = fp
            cells_out.append(CellResult(
                cell=cell, factors=factors, fingerprint=fp,
                table=analyze_records(records, design.outlier_filter),
                n_measured=len(records) if cell.index in fresh else 0,
                n_resumed=0 if cell.index in fresh else len(records)))

        design = spec.design
        uniform_nrep = None
        if not design.adaptive:
            uniform_nrep = (len(compiled) * len(spec.cases)
                            * n_epochs_max * design.nrep)
        savings = (uniform_nrep / state.spent_nrep
                   if uniform_nrep and state.spent_nrep else None)
        alloc = dict(
            policy=policy.name, policy_params=policy.manifest(),
            n_rounds=state.round, rounds=rounds,
            spent_nrep=state.spent_nrep, uniform_nrep=uniform_nrep,
            savings=savings, decisions=dict(state.decided),
            undecided=state.undecided())
        return SweepResult(
            cells=cells_out, sweep_id=sweep_id,
            n_cells_measured=sum(1 for c in cells_out if c.n_measured),
            n_cells_resumed=sum(1 for c in cells_out if not c.n_measured),
            meta=dict(name=spec.name, n_cells=len(cells_out),
                      axes=[ax.name for ax in spec.grid.axes],
                      n_workers=self.n_workers, alloc=alloc),
        )

    def _epoch_window(self):
        """The epoch iterable campaigns should run under — the budgeted
        round's window, or ``None`` (all epochs) outside one."""
        if self._round_epochs is None:
            return None
        return range(self._round_epochs[0], self._round_epochs[1])

    def _execute_pending(self, pending, sweep_id,
                         snapshot) -> dict[int, CellResult]:
        """How the not-yet-complete cells actually get measured — the one
        hook a different execution strategy overrides (the fault-tolerant
        lease-queue fleet in :mod:`repro.fleet` replaces exactly this).
        Everything around it — compilation, manifests, cell-granular
        resume, result assembly — is shared."""
        measured = self._run_parallel(pending, sweep_id, snapshot) \
            if self.n_workers > 1 and len(pending) > 1 else None
        if measured is None:
            measured = self._run_serial(pending, sweep_id, snapshot)
        return measured

    def _cell_complete(self, cell, design, fp, sweep_id, done,
                       snapshot) -> bool:
        """A cell resumes without running when its ``sweep-cell`` marker is
        in the store — or when the store already holds its full
        case x epoch record set under another sweep id (a fractional grid
        whose ``fraction`` was raised re-declares a new manifest, but the
        nested cells' measurements are the same experiment and must not be
        re-measured). In the latter case the marker is added under the new
        sweep id so the next resume is a plain lookup."""
        if cell.index in done:
            return True
        if not self.spec.cases:       # completeness undecidable without the
            return False              # explicit case list
        expected = {(c.op, int(c.msize), e) for c in self.spec.cases
                    for e in range(design.n_launch_epochs)}
        if not expected <= snapshot.completed(fp):
            return False
        self.store.append_sweep_cell(sweep_id, cell.index, fp)
        snapshot.sweep_cells_by_id.setdefault(sweep_id, {})[cell.index] = fp
        return True

    def _run_serial(self, pending, sweep_id, snapshot) -> dict[int, CellResult]:
        """One cell after another, each through the ordinary (record-
        granular, store-resuming) campaign path."""
        out: dict[int, CellResult] = {}
        for cell, backend, design, factors, fp in pending:
            # a partially-successful parallel attempt (pool died mid-sweep)
            # already persisted some of these cells and recorded them in
            # the snapshot — load, don't re-measure
            marked = (snapshot.sweep_cells_by_id.get(sweep_id, {})
                      if snapshot is not None else {})
            if cell.index in marked:
                records = snapshot.records.get(fp, [])
                out[cell.index] = CellResult(
                    cell=cell, factors=factors, fingerprint=fp,
                    table=analyze_records(records, design.outlier_filter),
                    n_resumed=len(records))
                continue
            res = Campaign(self.spec.cell_spec(cell, design), backend,
                           self.store).run(snapshot=snapshot,
                                           epochs=self._epoch_window())
            if self.store is not None and self._round_epochs is None:
                self.store.append_sweep_cell(sweep_id, cell.index, fp)
            out[cell.index] = CellResult(
                cell=cell, factors=factors, fingerprint=fp, table=res.table,
                n_measured=res.n_measured, n_resumed=res.n_resumed)
        return out

    def _run_parallel(self, pending, sweep_id,
                      snapshot) -> dict[int, CellResult] | None:
        """Shard whole cells over a process pool; the parent persists each
        cell as it completes. ``None`` falls back to the serial path."""
        spec, store = self.spec, self.store

        def persist(i: int, res: CampaignResult) -> None:
            if store is None:
                return
            cell, _, design, factors, fp = pending[i]
            # a previous (killed) serial run may have left partial records
            # for this fingerprint — the worker re-measured the whole cell,
            # so only append what the store does not already hold
            have = snapshot.completed(fp)
            store.append_campaign(factors, spec.cell_spec(cell, design).meta(),
                                  snapshot=snapshot)
            for rec in res.records:
                if (rec.case.op, rec.case.msize, rec.epoch) not in have:
                    store.append_record(fp, rec)
                    # keep the snapshot coherent: if the pool dies later,
                    # the serial fallback must see these cells as done
                    # rather than re-measure and duplicate their records
                    snapshot.records.setdefault(fp, []).append(rec)
            if self._round_epochs is None:
                store.append_sweep_cell(sweep_id, cell.index, fp)
                snapshot.sweep_cells_by_id.setdefault(sweep_id,
                                                      {})[cell.index] = fp

        window = self._epoch_window()
        rets = map_parallel(
            _run_cell,
            [(backend, spec.cases, design,
              spec.cell_spec(cell, design).name, window)
             for cell, backend, design, _, _ in pending],
            self.n_workers, what="sweep cells", on_result=persist)
        if rets is None:
            return None
        out: dict[int, CellResult] = {}
        for (cell, _, _, factors, fp), res in zip(pending, rets):
            out[cell.index] = CellResult(
                cell=cell, factors=factors, fingerprint=fp, table=res.table,
                n_measured=res.n_measured, n_resumed=res.n_resumed)
        return out
