"""Distribution: sharding rules for DP/TP/EP/FSDP/SP over the production mesh."""

from .sharding import (
    ShardingConfig,
    batch_specs,
    cache_specs,
    data_axes,
    named,
    param_specs,
)

__all__ = [
    "ShardingConfig", "param_specs", "batch_specs", "cache_specs",
    "named", "data_axes",
]
