"""Sharding rules: parameter/activation PartitionSpecs for the production mesh.

Parallelism dimensions supported (DESIGN.md §5):

  * **DP**  — batch over ``("pod", "data")`` (the pod axis is pure data
    parallel across pods; gradient reduction crosses the DCN-like hop).
  * **TP**  — attention heads / FFN hidden / expert dim over ``"model"``
    (Megatron layout: column-parallel in, row-parallel out).
  * **EP**  — MoE experts over ``"model"``.
  * **FSDP** — optionally shard the non-TP weight axis over ``"data"``
    (ZeRO-3-like; XLA inserts all-gather on use / reduce-scatter on grads).
  * **SP**  — long-context activations: sequence dim constrained over
    ``"model"`` between blocks (opt-in; used by long-context hillclimbs).

Rules are keyed on parameter-tree paths; anything unmatched is replicated.
All stacked-layer leading axes are never sharded (they are scanned over).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingConfig", "param_specs", "batch_specs", "cache_specs",
           "named", "data_axes", "sanitize"]


@dataclass(frozen=True)
class ShardingConfig:
    mode: str = "fsdp_tp"     # "tp" | "fsdp_tp" | "dp"
    tp_axis: str = "model"
    fsdp_axis: str = "data"   # weights' non-TP dim sharded here in fsdp_tp
    shard_kv: bool = True     # shard KV projections when heads divide tp size


def data_axes(mesh: Mesh) -> tuple:
    """Batch axes: ('pod', 'data') on the multi-pod mesh, ('data',) single."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh axes do not divide (jit
    in_shardings require exact divisibility; e.g. mamba2's vocab 50280 is
    not divisible by 16 — its embedding falls back to model-sharding the
    d_model dim via the rules, or replication here)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        out.append(entry if dim % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def _divides(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def param_specs(params_shapes, cfg, mesh: Mesh,
                sharding: ShardingConfig | None = None):
    """Map a (shape-only) parameter pytree to PartitionSpecs.

    ``params_shapes`` is the pytree of ShapeDtypeStructs from
    ``jax.eval_shape(init_params, ...)`` (never materialized for full
    configs). ``cfg`` is the ModelConfig (for head counts etc.).
    """
    sh = sharding or ShardingConfig()
    tp = sh.tp_axis if sh.tp_axis in mesh.axis_names else None
    fsdp = (sh.fsdp_axis if sh.mode == "fsdp_tp"
            and sh.fsdp_axis in mesh.axis_names else None)
    if sh.mode == "dp":
        tp = fsdp = None
    tp_size = mesh.shape[tp] if tp else 1

    def spec_of(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        is_expert = "moe" in keys and "shared" not in keys
        ep_ok = cfg.n_experts % tp_size == 0 if cfg.n_experts else False
        kv_ok = sh.shard_kv and cfg.n_kv_heads % tp_size == 0

        def tail_for():
            # trailing-dims rule; leading stacked axes (scan layers,
            # hybrid super x inner) are padded with None below.
            if name in ("embed", "unembed"):
                return (tp, None)
            if name == "wq":
                return (fsdp, tp)
            if name in ("wk", "wv"):
                return (fsdp, tp if kv_ok else None)
            if name == "wo":
                return (tp, fsdp)
            if name in ("w_uk", "w_uv", "w_uq"):
                return (None, tp)
            if name in ("w_dkv", "w_dq"):
                return (fsdp, None)
            if is_expert and name in ("w_gate", "w_up"):
                # EP over "model" when E divides; else TP the hidden dim so
                # the model axis is never wasted (mixtral: E=8 < 16).
                return (tp, fsdp, None) if ep_ok else (None, fsdp, tp)
            if is_expert and name == "w_down":
                return (tp, None, fsdp) if ep_ok else (None, tp, fsdp)
            if name in ("w_gate", "w_up"):
                return (fsdp, tp)
            if name == "w_down":
                return (tp, fsdp)
            if name == "router":
                return (None, None)
            if name in ("w_z", "w_x"):
                return (fsdp, tp)
            if name in ("w_b", "w_c", "w_dt"):
                return (fsdp, None)
            if name == "out_proj":
                return (tp, fsdp)
            if name == "conv_x":
                return (None, tp)
            if name in ("conv_b", "conv_c"):
                return (None, None)
            return None

        tail = tail_for()
        if tail is None or len(shape) < len(tail):
            return P(*([None] * len(shape)))
        lead = len(shape) - len(tail)
        spec = P(*([None] * lead + list(tail)))
        # vocab not divisible by tp (mamba2/seamless): shard d_model instead
        if name in ("embed", "unembed") and shape[0] % max(1, tp_size) != 0:
            spec = P(None, tp)
        return sanitize(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_of, params_shapes)


def batch_specs(mesh: Mesh, batch_shapes):
    """Input batch: leading batch dim over the data axes, rest replicated.

    Batches too small to split over the data axes (long-context decode at
    global_batch=1) stay replicated."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec_of(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        b = leaf.shape[0]
        lead = dp if dp and b % dp_size == 0 else None
        return P(lead, *([None] * (nd - 1)))

    return jax.tree.map(spec_of, batch_shapes)


def cache_specs(cfg, mesh: Mesh, cache_shapes,
                sharding: ShardingConfig | None = None):
    """Decode-cache sharding: batch over data axes, heads over model.

    Cache leaves: stacked (L, B, T, Hkv, Dh) or MLA (L, B, T, r) or SSM
    (L, B, nh, hd, n) / conv (L, B, w, dim); ``pos`` scalar replicated.
    """
    sh = sharding or ShardingConfig()
    tp = sh.tp_axis if sh.tp_axis in mesh.axis_names else None
    tp_size = mesh.shape[tp] if tp else 1
    dp = data_axes(mesh)

    def spec_of(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        if name == "pos" or len(shape) == 0:
            return P()
        kv_ok = sh.shard_kv and cfg.n_kv_heads % tp_size == 0
        nh = cfg.ssm_heads or (cfg.ssm_expand * cfg.d_model // max(1, cfg.ssm_head_dim))
        nh_ok = cfg.ssm_head_dim and nh % tp_size == 0
        # batch dim position depends on the tail rank; check divisibility.
        dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        tails = {
            # (B, T, Hkv, Dh)
            "k": (dp, None, tp if kv_ok else None, None),
            "v": (dp, None, tp if kv_ok else None, None),
            "attn_k": (dp, None, tp if kv_ok else None, None),
            "attn_v": (dp, None, tp if kv_ok else None, None),
            # (B, T, r)
            "ckv": (dp, None, None),
            "krope": (dp, None, None),
            # (B, nh, hd, n)
            "state": (dp, tp if nh_ok else None, None, None),
            # (B, w, dim)
            "conv_x": (dp, None, tp if nh_ok else None),
            "conv_b": (dp, None, None),
            "conv_c": (dp, None, None),
        }
        tail = tails.get(name)
        if tail is None or len(shape) < len(tail):
            b = shape[0]
            lead0 = dp if dp and b % dp_size == 0 else None
            return sanitize(P(*([lead0] + [None] * (len(shape) - 1))),
                            shape, mesh)
        lead = len(shape) - len(tail)
        b = shape[lead]
        tail = list(tail)
        if not (dp and b % dp_size == 0):
            tail[0] = None
            # long-context single-sequence decode: shard the KV time axis
            # over the data axes instead (context-parallel cache).
            if name in ("k", "v", "attn_k", "attn_v", "ckv", "krope") \
                    and len(tail) >= 2 and shape[lead + 1] % max(1, dp_size) == 0:
                tail[1] = dp
        return sanitize(P(*([None] * lead + list(tail))), shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_of, cache_shapes)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
