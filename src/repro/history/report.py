"""Drift-report formatting: the audit verdict table, paper style.

One row per audited (op, msize) cell — both sides' per-epoch-median
averages, the median ratio with its bootstrap CI, both Holm-adjusted
p-values, and the verdict — plus the factor-diff note that tells a reader
*what changed between the runs* before they interpret any drift.
"""

from __future__ import annotations

from .audit import EQUIVALENT, AuditReport

__all__ = ["format_audit_report", "format_drift"]


def format_audit_report(report: AuditReport, title: str = "") -> str:
    """The full audit table; reads like the guideline verdict tables."""
    lines = []
    if title:
        lines.append(f"# {title}")
    runs = ""
    if report.candidate is not None and report.baseline is not None:
        runs = (f" candidate={report.candidate.run_id}"
                f" baseline={report.baseline.run_id}"
                + (f"[{report.baseline.tag}]" if report.baseline.tag else ""))
    lines.append(
        f"# reproducibility audit{runs} margin=±{report.margin:.0%} "
        f"alpha={report.alpha} statistic={report.statistic} "
        f"cells={len(report.cells)} computed={report.n_computed} "
        f"resumed={report.n_resumed}")
    if report.factor_diffs:
        diffs = ", ".join(f"{k}: {a!r} -> {b!r}"
                          for k, (a, b) in sorted(report.factor_diffs.items()))
        lines.append(f"# factors changed between runs — {diffs}")
    lines.append(
        f"{'op':<14} {'msize':>7} {'ref[us]':>10} {'cand[us]':>10} "
        f"{'ratio':>7} {'CI(ratio)':>17} {'p_tost':>9} {'p_diff':>9} "
        f"{'verdict':>12}")
    for c in report.cells:
        ci = f"[{c.ci_lo:6.3f},{c.ci_hi:6.3f}]"
        lines.append(
            f"{c.op:<14} {c.msize:>7} {c.ref_us:>10.2f} {c.cand_us:>10.2f} "
            f"{c.ratio:>7.3f} {ci:>17} {c.p_tost_holm:>9.2e} "
            f"{c.p_diff_holm:>9.2e} {c.verdict:>12}")
    n = len(report.cells)
    n_eq = sum(1 for c in report.cells if c.verdict == EQUIVALENT)
    n_dr = len(report.drifted())
    lines.append(f"# {n_eq}/{n} EQUIVALENT, {n_dr} DRIFTED, "
                 f"{n - n_eq - n_dr} INCONCLUSIVE "
                 f"(family-wise alpha={report.alpha})")
    return "\n".join(lines)


def format_drift(report: AuditReport) -> str:
    """Compact drifted-cell list for CI logs — empty when nothing drifted."""
    bad = report.drifted()
    if not bad:
        return ""
    lines = [f"drift detected ({len(bad)} cell"
             f"{'s' if len(bad) != 1 else ''}):"]
    for c in bad:
        direction = "slower" if c.ratio > 1.0 else "faster"
        lines.append(
            f"  {c.op} @ msize={c.msize}: candidate {direction} x{c.ratio:.3f}"
            f" (CI [{c.ci_lo:.3f}, {c.ci_hi:.3f}], "
            f"p_holm={c.p_diff_holm:.2e}) vs reference {c.ref_us:.2f}us")
    return "\n".join(lines)
