"""The run archive: many result stores, one manifest, cheap lookups.

A reproducibility audit compares *runs separated by time* — yesterday's
calibration against today's, last month's reference against a fresh
re-measurement on the same (or a changed) machine. That needs a durable
index over many :class:`~repro.campaign.ResultStore` JSONLs: which factor
fingerprints each file holds, on which host it was measured, when it was
registered, and under what human-facing tag ("reference", "post-upgrade").

:class:`RunArchive` is a directory of stores plus one append-only
``manifest.jsonl``. Registration parses a store *once* and appends a
:class:`RunEntry` line; every later lookup (``runs``, ``baseline_for``)
reads only the manifest — an archive of a thousand runs answers "what is
the latest reference for this fingerprint?" without re-parsing a thousand
JSONL files. Registration also stamps the store itself with a ``meta``
line (run id, tag, registration time), so a store file carried away from
its archive still says where it came from.

Run identity is content-based: ``run_id = sha256(relative path + the
store's non-meta lines)``. Re-registering an unchanged store is a no-op;
a store that *grew* (a resumed campaign) gets a fresh entry superseding
the old one at the same path; and the meta stamp itself is excluded from
the hash, so stamping does not change what it stamps.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
import time
import warnings
from dataclasses import dataclass, field
from pathlib import Path

from repro.campaign import ResultStore

__all__ = ["RunEntry", "RunArchive", "CONTROL_TAG"]

MANIFEST_NAME = "manifest.jsonl"

#: Runs tagged with this are *controls* (e.g. the CLI's seeded ``--mistune``
#: drift run): they stay in the archive for the record, but default
#: baseline resolution never picks one — a deliberately-bad run must not
#: become the yardstick a later run "passes" against.
CONTROL_TAG = "control"


@dataclass(frozen=True)
class RunEntry:
    """One archived run: the manifest's one-pass index of a store file."""

    run_id: str                      # sha256(relpath + non-meta content)[:16]
    store: str                       # store path relative to the archive root
    timestamp: float                 # registration time (unix seconds)
    host: str = ""
    tag: str | None = None
    fingerprints: tuple = ()         # campaign fingerprints, file order
    names: tuple = ()                # campaign spec names, same order
    n_records: int = 0
    schema_version: int = 0
    factors: dict = field(default_factory=dict)  # last campaign's factor dict
    n_corrupt: int = 0               # undecodable store lines at registration

    def to_dict(self) -> dict:
        return dict(kind="run", run_id=self.run_id, store=self.store,
                    timestamp=self.timestamp, host=self.host, tag=self.tag,
                    fingerprints=list(self.fingerprints),
                    names=list(self.names), n_records=self.n_records,
                    schema_version=self.schema_version, factors=self.factors,
                    n_corrupt=self.n_corrupt)

    @classmethod
    def from_dict(cls, o: dict) -> "RunEntry":
        return cls(run_id=o["run_id"], store=o["store"],
                   timestamp=float(o["timestamp"]), host=o.get("host", ""),
                   tag=o.get("tag"),
                   fingerprints=tuple(o.get("fingerprints", ())),
                   names=tuple(o.get("names", ())),
                   n_records=int(o.get("n_records", 0)),
                   schema_version=int(o.get("schema_version", 0)),
                   factors=o.get("factors", {}),
                   n_corrupt=int(o.get("n_corrupt", 0)))


def _content_hash(relpath: str, store_path: Path) -> str:
    """Identity of a store's *measurements*: path + every non-meta line.

    Meta lines (the archive's own registration stamps) are skipped so that
    stamping a store does not change its identity; the relative path is
    included so two bit-identical runs (a deterministic simulator re-run)
    still register as two distinct runs — which is exactly the pair an
    audit wants to compare.
    """
    h = hashlib.sha256(relpath.encode())
    with open(store_path, "rb") as f:
        for raw in f:
            line = raw.strip()
            if not line:
                continue
            try:
                kind = json.loads(line).get("kind")
            except (json.JSONDecodeError, AttributeError):
                kind = None      # torn tail line; identity ignores it too
            if kind in ("meta", None):
                continue
            h.update(line)
            h.update(b"\n")
    return h.hexdigest()[:16]


class RunArchive:
    """A directory of result stores indexed by an append-only manifest."""

    def __init__(self, root):
        self.root = Path(root)

    @property
    def manifest_path(self) -> Path:
        return self.root / MANIFEST_NAME

    # -- registration ------------------------------------------------------

    def register(self, store_path, tag: str | None = None,
                 stamp: bool = True) -> RunEntry:
        """Index one store into the manifest; returns its :class:`RunEntry`.

        Idempotent on content: an unchanged store (same relative path, same
        non-meta lines) returns its existing entry without re-indexing —
        unless a *different* ``tag`` is requested, in which case a
        re-tagged entry (same run id, original timestamp) supersedes the
        old one, so tagging an already-registered run (say, one that
        ``Campaign(archive=...)`` auto-registered untagged) works. A grown
        store appends a fresh entry that supersedes the old one at the
        same path (``entries()`` keeps both for history; ``runs()``
        returns the latest per path). With ``stamp``, the store itself
        receives a ``meta`` line recording the registration.
        """
        store_path = Path(store_path)
        if not store_path.exists():
            raise FileNotFoundError(f"RunArchive.register: no store at "
                                    f"{store_path}")
        try:
            rel = str(store_path.resolve().relative_to(self.root.resolve()))
        except ValueError:
            # outside the archive root: index it by absolute path (the
            # manifest stays usable, the file just isn't archive-managed)
            rel = str(store_path.resolve())
        run_id = _content_hash(rel, store_path)
        existing = None
        for entry in self.entries():
            if entry.run_id == run_id and entry.store == rel:
                existing = entry            # last registration wins
        if existing is not None:
            if tag is None or existing.tag == tag:
                return existing
            entry = dataclasses.replace(existing, tag=tag)
        else:
            store = ResultStore(store_path)
            # one parsing pass: the snapshot carries everything the entry
            # needs (fingerprints in declaration order, spec names, factor
            # dicts, record counts/hosts)
            snap = store.snapshot()
            fingerprints = tuple(snap.campaign_specs)
            names = tuple(snap.campaign_specs[fp].get("name", "")
                          for fp in fingerprints)
            hosts = {r.meta.get("host", "") for recs in snap.records.values()
                     for r in recs} - {""}
            entry = RunEntry(
                run_id=run_id, store=rel, timestamp=time.time(),
                host=min(hosts) if hosts else platform.node(), tag=tag,
                fingerprints=fingerprints, names=names,
                n_records=sum(len(r) for r in snap.records.values()),
                schema_version=store.schema_version(),
                factors=(snap.campaign_factors.get(fingerprints[-1], {})
                         if fingerprints else {}),
                n_corrupt=snap.n_corrupt,
            )
            if snap.n_corrupt:
                # a store carrying torn-write residue is still archivable
                # (the loader skipped the damage), but an audit baseline
                # with silent holes is worse than a loud one
                warnings.warn(
                    f"RunArchive.register: {store_path} had "
                    f"{snap.n_corrupt} undecodable line(s) skipped at "
                    "registration; recorded in the manifest entry's "
                    "n_corrupt", RuntimeWarning, stacklevel=2)
        self.root.mkdir(parents=True, exist_ok=True)
        with open(self.manifest_path, "a") as f:
            f.write(json.dumps(entry.to_dict(), sort_keys=True) + "\n")
            f.flush()
        if stamp:
            ResultStore(store_path).append_meta(
                archived=dict(run_id=run_id, tag=tag,
                              timestamp=entry.timestamp))
        return entry

    def new_store_path(self, stem: str = "run") -> Path:
        """A fresh ``<stem>-NNN.jsonl`` path inside the archive (NNN past
        the highest existing index, so killed runs never collide)."""
        self.root.mkdir(parents=True, exist_ok=True)
        taken = [p.name for p in self.root.glob(f"{stem}-*.jsonl")]
        n = 0
        for name in taken:
            try:
                n = max(n, int(name[len(stem) + 1:-len(".jsonl")]) + 1)
            except ValueError:
                continue
        return self.root / f"{stem}-{n:03d}.jsonl"

    def log_calibration(self, entry: RunEntry, report: dict) -> None:
        """Append a calibration fit report to the manifest, keyed by the
        registered run — the archive-level record of *how* a run earned
        its ``calibrated`` tag (fitted params, objective trace, per-cell
        verdicts). A separate line kind, not a :class:`RunEntry` field:
        :meth:`entries` filters by ``kind == "run"``, so older readers
        skip these lines untouched (the store schema's forward-compat
        rule applied to the manifest)."""
        self.root.mkdir(parents=True, exist_ok=True)
        with open(self.manifest_path, "a") as f:
            f.write(json.dumps(dict(kind="calibration",
                                    run_id=entry.run_id, report=report),
                               sort_keys=True) + "\n")
            f.flush()

    def calibrations(self, run_id: str | None = None) -> list[dict]:
        """Calibration reports in log order, optionally for one run."""
        if not self.manifest_path.exists():
            return []
        out: list[dict] = []
        with open(self.manifest_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    o = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if o.get("kind") == "calibration" and \
                        (run_id is None or o.get("run_id") == run_id):
                    out.append(o)
        return out

    # -- lookups (manifest only — stores are never re-parsed here) --------

    def entries(self) -> list[RunEntry]:
        """Every manifest line in registration order (including superseded
        registrations of grown stores)."""
        if not self.manifest_path.exists():
            return []
        out: list[RunEntry] = []
        with open(self.manifest_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    o = json.loads(line)
                except json.JSONDecodeError:   # torn tail: registration lost,
                    continue                   # store is still on disk
                if o.get("kind") == "run":
                    out.append(RunEntry.from_dict(o))
        return out

    def runs(self, fingerprint: str | None = None, tag: str | None = None,
             name: str | None = None) -> list[RunEntry]:
        """Current runs (latest registration per store path), filtered."""
        latest: dict[str, RunEntry] = {}
        for e in self.entries():
            latest[e.store] = e
        out = sorted(latest.values(), key=lambda e: e.timestamp)
        if fingerprint is not None:
            out = [e for e in out if fingerprint in e.fingerprints]
        if tag is not None:
            out = [e for e in out if e.tag == tag]
        if name is not None:
            out = [e for e in out if name in e.names]
        return out

    def entry(self, run_id: str) -> RunEntry:
        """The *latest* registration of a run id — a re-tagged run's
        superseding entry, not its stale original."""
        for e in reversed(self.entries()):
            if e.run_id == run_id:
                return e
        raise KeyError(f"no run {run_id!r} in {self.manifest_path}")

    def open_store(self, entry: RunEntry) -> ResultStore:
        path = Path(entry.store)
        if not path.is_absolute():
            path = self.root / path
        return ResultStore(path)

    def baseline_for(self, candidate: RunEntry,
                     tag: str | None = None) -> RunEntry | None:
        """The run a fresh ``candidate`` should be audited against.

        With a ``tag``: the latest run so tagged (a pinned reference);
        raises if the tag names nothing. Without: the latest *earlier* run
        sharing a factor fingerprint with the candidate — the same declared
        experiment, re-run; failing that, the latest earlier run of the
        same campaign name (comparable up to the factor drift the audit
        report surfaces); ``None`` when the candidate is the first run.
        Runs tagged :data:`CONTROL_TAG` are never picked by the default
        resolution — only an explicit ``tag=CONTROL_TAG`` can select one.
        """
        if tag is not None:
            tagged = [e for e in self.runs(tag=tag)
                      if e.run_id != candidate.run_id]
            if not tagged:
                raise KeyError(f"no archived run tagged {tag!r} in "
                               f"{self.manifest_path}")
            return tagged[-1]
        earlier = [e for e in self.runs()
                   if e.run_id != candidate.run_id
                   and e.timestamp <= candidate.timestamp
                   and e.store != candidate.store
                   and e.tag != CONTROL_TAG]
        shared = [e for e in earlier
                  if set(e.fingerprints) & set(candidate.fingerprints)]
        if shared:
            return shared[-1]
        named = [e for e in earlier
                 if set(e.names) & set(candidate.names)]
        return named[-1] if named else None
