"""The reproducibility verdict engine: TOST equivalence across runs.

``compare_tables`` answers "are these two runs *different*?" — but the
paper's headline property is the opposite claim, and absence of a
significant difference is not evidence of sameness (it gets *easier* to
"pass" by measuring less). The audit therefore inverts the burden of
proof per (op, msize) cell, on the distributions of per-epoch medians:

  * **TOST equivalence** (:func:`~repro.core.stats.tost_wilcoxon`): the
    null is non-equivalence; rejecting it certifies the candidate within
    ``±margin`` of the reference on the ratio scale — ``EQUIVALENT``;
  * **difference test** (two-sided Wilcoxon): rejecting *its* null without
    equivalence evidence is positive evidence of drift — ``DRIFTED``;
  * neither rejected: the data cannot decide — ``INCONCLUSIVE`` (small
    samples land here instead of silently "passing").

Both p-value families carry Holm step-down correction across the cell
family, so the *report's* false-``EQUIVALENT`` and false-``DRIFTED``
rates are each bounded by ``alpha`` (the soundness test tier pins the
empirical rates). Each cell also gets a percentile-bootstrap CI on the
median ratio — the effect-size the verdict is about, readable even when
the verdict is INCONCLUSIVE.

:func:`audit_runs` is the archive-level entry point: it resolves the
baseline through the :class:`~repro.history.RunArchive` manifest, logs
every computed cell to an append-only ``audits.jsonl``, and *resumes* a
killed audit — already-logged cells are loaded, only missing cells are
recomputed, and the family-wise correction is re-applied over the
complete family at report time.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field

import numpy as np

from repro.core.design import ResultTable
from repro.core.stats import (bootstrap_ci, holm_bonferroni, tost_wilcoxon,
                              wilcoxon_rank_sum)

from .archive import RunArchive, RunEntry

__all__ = ["CellVerdict", "AuditReport", "audit_tables", "audit_runs",
           "DEFAULT_MARGIN"]

#: Default relative equivalence margin: a re-run within ±10% of the
#: reference median is "the same experiment" for drift-gating purposes.
DEFAULT_MARGIN = 0.10

EQUIVALENT = "EQUIVALENT"
DRIFTED = "DRIFTED"
INCONCLUSIVE = "INCONCLUSIVE"


@dataclass(frozen=True)
class CellVerdict:
    """One audited (op, msize) cell: candidate vs reference."""

    op: str
    msize: int
    ref_us: float              # mean of per-epoch medians, reference [us]
    cand_us: float             # …candidate [us]
    ratio: float               # median(cand medians) / median(ref medians)
    ci_lo: float               # bootstrap percentile CI on that ratio
    ci_hi: float
    p_tost: float              # raw TOST equivalence p (margin-relative)
    p_tost_holm: float         # Holm-adjusted over the cell family
    p_diff: float              # raw two-sided difference p
    p_diff_holm: float
    n_ref: int                 # launch epochs per side
    n_cand: int
    margin: float
    alpha: float

    @property
    def equivalent(self) -> bool:
        return self.p_tost_holm <= self.alpha

    @property
    def drifted(self) -> bool:
        """Positive evidence of drift: the difference test rejects and
        equivalence was not demonstrated. When both reject (a tiny but
        real difference inside the margin), the margin wins by design —
        that is what "practically equivalent" means."""
        return not self.equivalent and self.p_diff_holm <= self.alpha

    @property
    def verdict(self) -> str:
        if self.equivalent:
            return EQUIVALENT
        if self.drifted:
            return DRIFTED
        return INCONCLUSIVE


@dataclass
class AuditReport:
    """Everything a drift gate needs from one candidate-vs-baseline audit."""

    cells: list[CellVerdict]
    margin: float
    alpha: float
    statistic: str = "median"
    candidate: RunEntry | None = None
    baseline: RunEntry | None = None
    factor_diffs: dict = field(default_factory=dict)
    n_computed: int = 0            # cells computed this run
    n_resumed: int = 0             # cells loaded from the audit log
    audit_id: str | None = None

    def drifted(self) -> list[CellVerdict]:
        return [c for c in self.cells if c.verdict == DRIFTED]

    def inconclusive(self) -> list[CellVerdict]:
        return [c for c in self.cells if c.verdict == INCONCLUSIVE]

    @property
    def ok(self) -> bool:
        """No cell with positive drift evidence (the gate's criterion —
        INCONCLUSIVE does not fail a gate, but is visibly reported)."""
        return not self.drifted()

    @property
    def all_equivalent(self) -> bool:
        return all(c.verdict == EQUIVALENT for c in self.cells)


def _cell_seed(seed: int, op: str, msize: int) -> int:
    """Deterministic per-cell bootstrap seed, stable across resume order."""
    h = hashlib.sha256(f"{seed}:{op}:{msize}".encode()).hexdigest()
    return int(h[:8], 16)


def _audit_cell(ref: np.ndarray, cand: np.ndarray, margin: float,
                n_boot: int, seed: int) -> dict:
    """Raw per-cell statistics (no family correction, no verdict) — the
    unit of audit work, logged one line per cell so a killed audit
    resumes at cell granularity."""
    tost = tost_wilcoxon(cand, ref, margin)
    diff = wilcoxon_rank_sum(cand, ref, "two-sided")
    ci_lo, ci_hi = bootstrap_ci(
        lambda c, r: float(np.median(c) / np.median(r)), (cand, ref),
        n_boot=n_boot, seed=seed)
    return dict(
        ref_us=float(np.mean(ref) * 1e6),
        cand_us=float(np.mean(cand) * 1e6),
        ratio=float(np.median(cand) / np.median(ref)),
        ci_lo=ci_lo, ci_hi=ci_hi,
        p_tost=tost.p_value, p_diff=diff.p_value,
        n_ref=int(ref.size), n_cand=int(cand.size),
    )


def _verdicts(raw: dict, margin: float, alpha: float) -> list[CellVerdict]:
    """Family-wise correction + verdict assembly over the *complete* cell
    family — re-run in full after a resume, so cached raw p-values feed
    the same Holm adjustment an uninterrupted audit would apply."""
    keys = sorted(raw)
    tost_holm = holm_bonferroni([raw[k]["p_tost"] for k in keys])
    diff_holm = holm_bonferroni([raw[k]["p_diff"] for k in keys])
    return [
        CellVerdict(op=op, msize=msize, margin=margin, alpha=alpha,
                    p_tost_holm=float(pt), p_diff_holm=float(pd),
                    **raw[(op, msize)])
        for (op, msize), pt, pd in zip(keys, tost_holm, diff_holm)
    ]


def _cell_samples(table: ResultTable, statistic: str):
    get = table.medians if statistic == "median" else table.means
    return {c.key(): get(c) for c in table.cases()}


def _common_cells(reference, candidate, statistic: str, what: str):
    """``(ref_cells, cand_cells, common keys)`` of two tables (or stores —
    anything with ``to_table``); raises when the runs share no populated
    (op, msize) cell, because an empty audit would read as a clean one."""
    if hasattr(reference, "to_table"):
        reference = reference.to_table()
    if hasattr(candidate, "to_table"):
        candidate = candidate.to_table()
    ref_cells = _cell_samples(reference, statistic)
    cand_cells = _cell_samples(candidate, statistic)
    common = sorted(k for k in ref_cells
                    if k in cand_cells
                    and ref_cells[k].size and cand_cells[k].size)
    if not common:
        raise ValueError(
            f"{what}: no common (op, msize) cells with data on both sides "
            f"— reference has {sorted(ref_cells) or 'no cases'}, candidate "
            f"has {sorted(cand_cells) or 'no cases'}. Check that the right "
            "runs were paired.")
    return ref_cells, cand_cells, common


def audit_tables(reference, candidate, margin: float = DEFAULT_MARGIN,
                 alpha: float = 0.05, statistic: str = "median",
                 n_boot: int = 1000, seed: int = 0) -> AuditReport:
    """Audit two result tables (or stores — anything with ``to_table``)
    in memory: the non-persistent core of :func:`audit_runs`, and the
    engine the soundness meta-tests drive directly."""
    ref_cells, cand_cells, common = _common_cells(reference, candidate,
                                                  statistic, "audit_tables")
    raw = {
        (op, msize): _audit_cell(ref_cells[(op, msize)],
                                 cand_cells[(op, msize)], margin, n_boot,
                                 _cell_seed(seed, op, msize))
        for op, msize in common
    }
    return AuditReport(cells=_verdicts(raw, margin, alpha), margin=margin,
                       alpha=alpha, statistic=statistic,
                       n_computed=len(common))


_CELL_FIELDS = ("ref_us", "cand_us", "ratio", "ci_lo", "ci_hi",
                "p_tost", "p_diff", "n_ref", "n_cand")


def _diff_factors(a: dict, b: dict) -> dict:
    """Factor-dict differences, with the ``extra`` key-value tuple diffed
    per entry so the report names ``extra.per_op_kw`` instead of dumping
    two whole tuples. ``host`` is not a factor (§5.9) and is skipped."""
    def pairs(v):
        return {p[0]: p[1] for p in (v or ())
                if isinstance(p, (list, tuple)) and len(p) == 2}

    out: dict = {}
    for k in set(a) | set(b):
        if k == "host" or a.get(k) == b.get(k):
            continue
        if k == "extra":
            da, db = pairs(a.get(k)), pairs(b.get(k))
            for ek in set(da) | set(db):
                if da.get(ek) != db.get(ek):
                    out[f"extra.{ek}"] = (da.get(ek), db.get(ek))
        else:
            out[k] = (a.get(k), b.get(k))
    return out


def audit_runs(archive: RunArchive, candidate, baseline=None,
               baseline_tag: str | None = None,
               margin: float = DEFAULT_MARGIN, alpha: float = 0.05,
               statistic: str = "median", n_boot: int = 1000,
               seed: int = 0, log: bool = True) -> AuditReport:
    """Audit an archived candidate run against its baseline, resumably.

    ``candidate``/``baseline`` are :class:`RunEntry`\\ s or run ids;
    ``baseline=None`` resolves through
    :meth:`~repro.history.RunArchive.baseline_for` (optionally pinned by
    ``baseline_tag``). Raises when no baseline exists — the caller decides
    whether "first run ever" is fine.

    With ``log`` (default), every computed cell is appended to the
    archive's ``audits.jsonl`` keyed by a deterministic audit id (runs +
    parameters), so a killed audit re-reads its finished cells and
    recomputes only the missing ones; Holm correction is always re-applied
    over the complete family.
    """
    if isinstance(candidate, str):
        candidate = archive.entry(candidate)
    if isinstance(baseline, str):
        baseline = archive.entry(baseline)
    if baseline is None:
        baseline = archive.baseline_for(candidate, tag=baseline_tag)
        if baseline is None:
            raise LookupError(
                f"audit_runs: no baseline in {archive.manifest_path} for "
                f"candidate {candidate.run_id} — register a reference run "
                "first")

    audit_id = hashlib.sha256(json.dumps(
        [baseline.run_id, candidate.run_id, margin, alpha, statistic,
         n_boot, seed], sort_keys=True).encode()).hexdigest()[:16]

    factor_diffs = _diff_factors(baseline.factors, candidate.factors)

    ref_cells, cand_cells, common = _common_cells(
        archive.open_store(baseline), archive.open_store(candidate),
        statistic,
        f"audit_runs [{baseline.run_id} vs {candidate.run_id}]")

    log_path = archive.root / "audits.jsonl"
    raw: dict[tuple[str, int], dict] = {}
    if log and log_path.exists():
        with open(log_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    o = json.loads(line)
                except json.JSONDecodeError:    # torn tail: cell recomputes
                    continue
                if o.get("kind") == "audit-cell" and o.get("audit") == audit_id:
                    key = (o["op"], int(o["msize"]))
                    raw[key] = {k: o[k] for k in _CELL_FIELDS}
    raw = {k: v for k, v in raw.items() if k in common}
    n_resumed = len(raw)

    n_computed = 0
    for op, msize in common:
        if (op, msize) in raw:
            continue
        cell = _audit_cell(ref_cells[(op, msize)], cand_cells[(op, msize)],
                           margin, n_boot, _cell_seed(seed, op, msize))
        raw[(op, msize)] = cell
        n_computed += 1
        if log:
            archive.root.mkdir(parents=True, exist_ok=True)
            with open(log_path, "a") as f:
                f.write(json.dumps(dict(kind="audit-cell", audit=audit_id,
                                        op=op, msize=int(msize), **cell),
                                   sort_keys=True) + "\n")
                f.flush()

    return AuditReport(cells=_verdicts(raw, margin, alpha), margin=margin,
                       alpha=alpha, statistic=statistic,
                       candidate=candidate, baseline=baseline,
                       factor_diffs=factor_diffs, n_computed=n_computed,
                       n_resumed=n_resumed, audit_id=audit_id)
