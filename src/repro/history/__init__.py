"""repro.history — the cross-run reproducibility-audit layer.

Everything below this package measures and compares *within* a run; this
package is about runs separated by time. A :class:`RunArchive` indexes
many :class:`~repro.campaign.ResultStore` JSONLs (factor fingerprint +
host + timestamp, one manifest so lookups never re-parse the stores), and
:func:`audit_runs` issues per-cell ``EQUIVALENT`` / ``DRIFTED`` /
``INCONCLUSIVE`` verdicts — TOST equivalence with a relative margin,
two-sided drift evidence, bootstrap CIs on the median ratio, Holm across
the family — resumably, through an append-only audit log. ::

    from repro.history import RunArchive, audit_runs, format_audit_report

    archive = RunArchive("runs/")
    ref = archive.register("runs/run-000.jsonl", tag="reference")
    cand = archive.register("runs/run-001.jsonl")
    report = audit_runs(archive, cand, baseline_tag="reference")
    print(format_audit_report(report))
    assert report.ok, "performance drifted vs the archived reference"

Every measurement backend — simulated today, real hardware tomorrow —
reports through this layer: a campaign store registered into an archive
becomes tomorrow's baseline.
"""

from .archive import CONTROL_TAG, RunArchive, RunEntry
from .audit import (DEFAULT_MARGIN, AuditReport, CellVerdict, audit_runs,
                    audit_tables)
from .report import format_audit_report, format_drift

__all__ = [
    "RunArchive",
    "RunEntry",
    "CONTROL_TAG",
    "AuditReport",
    "CellVerdict",
    "audit_tables",
    "audit_runs",
    "DEFAULT_MARGIN",
    "format_audit_report",
    "format_drift",
]
