"""Pure-jnp oracle for the fused duration-sampling scan.

The exact math of :meth:`repro.core.mpi_ops.SimCollective.sample_durations`
on pre-drawn noise: the AR(1) recurrence ``s_i = coeff * s_{i-1} + eps_i``
expressed as a prefix composition of affine maps ``s -> a*s + b`` — the
composition rule ``(a1, b1) . (a2, b2) = (a1*a2, b1*a2 + b2)`` is
associative, so ``lax.associative_scan`` evaluates the whole chain in
O(log n) depth — followed by the lognormal/bimodal-tail/spike mixture.

Uniform draws replace the numpy engine's sequential coin flips: a tail
fires when ``u_tail < tail_prob`` with magnitude ``1 + tail_shift *
uniform(0.7, 1.3)`` (``u_mag`` rescaled), a spike when ``u_spike <
spike_prob`` — the same marginals, order-free.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

__all__ = ["sim_durations_ref"]


def sim_durations_ref(eps, u_tail, u_mag, u_spike, *, coeff, state, t0,
                      tail_prob, tail_shift, spike_prob, spike_scale):
    """Returns ``(durations, s)`` — the sampled common durations and the
    full AR(1) state sequence (the caller carries ``s[-1]`` across calls)."""
    a = jnp.full_like(eps, coeff)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    A, B = lax.associative_scan(combine, (a, eps))
    s = A * state + B
    t = t0 * jnp.exp(s)
    mag = 1.0 + tail_shift * (0.7 + 0.6 * u_mag)
    t = jnp.where(u_tail < tail_prob, t * mag, t)
    t = jnp.where(u_spike < spike_prob, t * spike_scale, t)
    return t, s
