"""Pallas kernel: fused AR(1) scan + mixture for duration sampling.

Same shape as the SSD kernel's TPU adaptation: a 1D sequence is cut into
chunks, the grid iterates chunks *sequentially*, and the inter-chunk AR(1)
carry lives in scratch across iterations. Within a chunk the recurrence is
the exponential-decay closed form ``s_j = a^j * cumsum(eps_j / a^j) +
carry * a^{j+1}`` (no ``associative_scan`` inside Pallas), and the
tail/spike mixture is applied in the same pass, so noise never round-trips
through HBM between the scan and the mixture.

The chunk length bounds the ``a^{-j}`` rescaling: with ``l = 128``,
``|coeff| >= 0.005`` stays far from float64 overflow. Below that the AR
memory is negligible and the kernel switches to the first-order form
``s_i ~= eps_i + coeff * s_{i-1}`` (exact for ``coeff == 0``). Operating
range ``|coeff| < 1`` — every stock op qualifies (default 0.35).

Validated against ``ref.sim_durations_ref`` in interpret mode
(tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM

    def _compiler_params(dims):
        try:
            return pltpu.CompilerParams(dimension_semantics=dims)
        except Exception:
            return pltpu.TPUCompilerParams(dimension_semantics=dims)
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

__all__ = ["sim_durations_scan"]

_CHUNK = 128
_A_MIN = 0.005  # below this, a^-(l-1) would overflow; use first-order form


def _kernel(eps_ref, ut_ref, um_ref, us_ref, prm_ref, t_ref, s_ref, carry,
            *, l):
    ic = pl.program_id(0)
    prm = prm_ref[0]            # [state, t0, coeff, tail_p, tail_s, spk_p, spk_s]
    a = prm[2]

    @pl.when(ic == 0)
    def _init():
        carry[0, 0] = prm[0]

    c = carry[0, 0]
    eps = eps_ref[...]                                   # (1, l)
    small = jnp.abs(a) < _A_MIN
    a_div = jnp.where(small, 1.0, a)
    j = lax.broadcasted_iota(jnp.int32, (1, l), 1).astype(eps.dtype)
    decay = a_div ** j
    s_cf = decay * jnp.cumsum(eps / decay, axis=1) + c * a_div * decay
    prev = jnp.concatenate([jnp.full((1, 1), c, eps.dtype), eps[:, :-1]],
                           axis=1)
    s = jnp.where(small, eps + a * prev, s_cf)
    carry[0, 0] = s[0, l - 1]

    t = prm[1] * jnp.exp(s)
    mag = 1.0 + prm[4] * (0.7 + 0.6 * um_ref[...])
    t = jnp.where(ut_ref[...] < prm[3], t * mag, t)
    t = jnp.where(us_ref[...] < prm[5], t * prm[6], t)
    t_ref[...] = t
    s_ref[...] = s


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def sim_durations_scan(eps, u_tail, u_mag, u_spike, *, coeff, state, t0,
                       tail_prob, tail_shift, spike_prob, spike_scale,
                       interpret=None):
    """Drop-in for :func:`ref.sim_durations_ref`; returns ``(durations, s)``.

    1D inputs of any length — padded to a chunk multiple internally (end
    padding, so the leading ``n`` states are unaffected by it).
    """
    interpret = _auto_interpret(interpret)
    if _VMEM is None:  # no pallas scratch support: fall back to the oracle
        from .ref import sim_durations_ref
        return sim_durations_ref(
            eps, u_tail, u_mag, u_spike, coeff=coeff, state=state, t0=t0,
            tail_prob=tail_prob, tail_shift=tail_shift,
            spike_prob=spike_prob, spike_scale=spike_scale)

    n = eps.shape[0]
    l = min(_CHUNK, max(8, n))
    nc = -(-n // l)
    pad = nc * l - n
    dt = eps.dtype

    def _blk(x, fill):
        x = jnp.pad(x, (0, pad), constant_values=fill)
        return x.reshape(nc, l)

    prm = jnp.stack([jnp.asarray(v, dt) for v in
                     (state, t0, coeff, tail_prob, tail_shift, spike_prob,
                      spike_scale, jnp.zeros((), dt))]).reshape(1, 8)

    kernel = functools.partial(_kernel, l=l)
    kwargs = {"scratch_shapes": [_VMEM((1, 1), dt)]}
    if not interpret:
        kwargs["compiler_params"] = _compiler_params(("arbitrary",))

    row = pl.BlockSpec((1, l), lambda ic: (ic, 0))
    t, s = pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[row, row, row,
                  row, pl.BlockSpec((1, 8), lambda ic: (0, 0))],
        out_specs=[row, row],
        out_shape=[jax.ShapeDtypeStruct((nc, l), dt),
                   jax.ShapeDtypeStruct((nc, l), dt)],
        interpret=interpret,
        **kwargs,
    )(_blk(eps, 0.0), _blk(u_tail, 1.0), _blk(u_mag, 0.0),
      _blk(u_spike, 1.0), prm)
    return t.reshape(-1)[:n], s.reshape(-1)[:n]
