"""Pallas TPU kernels for the compute hot spots, with jnp oracles.

  * ``flash_attention`` — online-softmax attention; removes the O(S*T)
    score traffic that makes the reference path memory-bound (§Roofline).
  * ``ssd_scan``        — Mamba-2 chunked SSD with VMEM-resident
    inter-chunk state.
  * ``sim_scan``        — fused AR(1) scan + bimodal-tail/spike mixture
    for the simulator's duration sampling (``repro.simjax``); the carry
    rides VMEM scratch across sequential chunks.

Kernels target TPU (``pl.pallas_call`` + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode against ``<kernel>/ref.py``.
"""

from . import ops
from .ops import flash_attention, ssd_scan

__all__ = ["ops", "flash_attention", "ssd_scan"]
