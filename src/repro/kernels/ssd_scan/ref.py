"""Pure-jnp oracle for the Mamba-2 SSD chunked-scan kernel.

Re-exports the model-side implementation (:func:`repro.models.ssm.ssd_chunked`)
— the kernel must match the exact math the models lower.
"""

from repro.models.ssm import ssd_chunked as ssd_chunked_ref

__all__ = ["ssd_chunked_ref"]
