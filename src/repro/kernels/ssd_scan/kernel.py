"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation of the SSD algorithm [arXiv:2405.21060]:

  * Grid ``(batch, head_groups, num_chunks)`` — chunks innermost and
    sequential; the inter-chunk recurrent state ``(hg, p, n)`` lives in f32
    VMEM scratch carried across chunk iterations (the GPU version
    materializes per-chunk states in HBM and runs a separate scan kernel;
    on TPU the sequential grid + persistent scratch fuses both passes).
  * Within a chunk everything is dense matmul work for the MXU:
    ``G = C B^T`` (l x l), the decay-masked intra-chunk product, and the
    state outer products — block sizes chosen so the f32 ``(l, l)``
    decay/score tile fits VMEM alongside x/B/C blocks
    (l=256, hg=8, p=64, n=64..128 → ~1.5 MiB working set).
  * Heads are grouped (``head_group``) to bound the ``(l, l, hg)`` masked
    tile; B/C are shared across heads (single SSD group, as in mamba2).

Validated against ``ref.ssd_chunked_ref`` in interpret mode
(tests/test_kernels/test_ssd_scan.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM

    def _compiler_params(dims):
        try:
            return pltpu.CompilerParams(dimension_semantics=dims)
        except Exception:
            return pltpu.TPUCompilerParams(dimension_semantics=dims)
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

__all__ = ["ssd_scan_fwd"]


def _kernel(x_ref, dta_ref, b_ref, c_ref, y_ref, state_scr, *, l, hg, p, n, nc):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0]                                  # (l, hg, p)
    dta = dta_ref[0, 0].astype(jnp.float32)          # (l, hg)
    B = b_ref[0, 0]                                  # (l, n)
    C = c_ref[0, 0]                                  # (l, n)

    cs = jnp.cumsum(dta, axis=0)                     # (l, hg)
    last = cs[-1:, :]                                # (1, hg)

    # ---- intra-chunk ----------------------------------------------------
    dec = cs[:, None, :] - cs[None, :, :]            # (l, l, hg)
    tri = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    dec = jnp.where(tri[:, :, None], dec, -jnp.inf)
    dec = jnp.exp(dec)
    g = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (l, l)
    m = (g[:, :, None] * dec).astype(x.dtype)        # (l, l, hg)
    y_intra = jnp.einsum("tsh,shp->thp", m, x)

    # ---- inter-chunk ----------------------------------------------------
    state = state_scr[...]                           # (hg, p, n) f32
    y_inter = jnp.einsum("tn,hpn,th->thp", C.astype(jnp.float32), state,
                         jnp.exp(cs)).astype(x.dtype)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state update ----------------------------------------------------
    w = jnp.exp(last - cs).astype(x.dtype)           # (l, hg)
    new_contrib = jnp.einsum("th,tn,thp->hpn", w, B, x).astype(jnp.float32)
    chunk_decay = jnp.exp(last[0]).astype(jnp.float32)  # (hg,)
    state_scr[...] = state * chunk_decay[:, None, None] + new_contrib


@functools.partial(jax.jit, static_argnames=("chunk", "head_group", "interpret"))
def ssd_scan_fwd(x, dta, B, C, *, chunk=256, head_group=8, interpret=True):
    """x: (b, s, h, p); dta: (b, s, h); B/C: (b, s, n). Returns y like x.

    Requirements: s % chunk == 0, h % head_group == 0 (``ops.py`` pads).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    l = min(chunk, s)
    nc = s // l
    hg = min(head_group, h)
    ng = h // hg

    xr = x.reshape(b, nc, l, ng, hg, p).transpose(0, 3, 1, 2, 4, 5) \
        .reshape(b * ng, nc, l, hg, p)
    dr = dta.reshape(b, nc, l, ng, hg).transpose(0, 3, 1, 2, 4) \
        .reshape(b * ng, nc, l, hg)
    Br = jnp.broadcast_to(B.reshape(b, 1, nc, l, n), (b, ng, nc, l, n)) \
        .reshape(b * ng, nc, l, n)
    Cr = jnp.broadcast_to(C.reshape(b, 1, nc, l, n), (b, ng, nc, l, n)) \
        .reshape(b * ng, nc, l, n)

    kernel = functools.partial(_kernel, l=l, hg=hg, p=p, n=n, nc=nc)
    kwargs = {}
    if _VMEM is not None:
        kwargs["scratch_shapes"] = [_VMEM((hg, p, n), jnp.float32)]
        if not interpret:
            kwargs["compiler_params"] = _compiler_params(
                ("parallel", "arbitrary"))

    y = pl.pallas_call(
        kernel,
        grid=(b * ng, nc),
        in_specs=[
            pl.BlockSpec((1, 1, l, hg, p), lambda ib, ic: (ib, ic, 0, 0, 0)),
            pl.BlockSpec((1, 1, l, hg), lambda ib, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda ib, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda ib, ic: (ib, ic, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, hg, p), lambda ib, ic: (ib, ic, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * ng, nc, l, hg, p), x.dtype),
        interpret=interpret,
        **kwargs,
    )(xr, dr, Br, Cr)

    y = y.reshape(b, ng, nc, l, hg, p).transpose(0, 2, 3, 1, 4, 5) \
        .reshape(b, s, h, p)
    return y
