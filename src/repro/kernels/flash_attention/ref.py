"""Pure-jnp oracle for the flash-attention kernel.

Semantics: grouped-query causal attention with optional sliding window,
logit soft-capping, query-position offset (decode) and KV-length masking —
the exact feature set the assigned architectures need (gemma-2/3 local:global
+ softcap, mixtral SWA, granite MQA).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["flash_attention_ref"]


def flash_attention_ref(q, k, v, *, causal=True, window=None, logit_cap=0.0,
                        q_offset=0, kv_len=None):
    """q: (B, S, H, D); k/v: (B, T, Hkv, D). Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    if logit_cap and logit_cap > 0:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(q.dtype), v)
    return out.reshape(b, s, h, d)
