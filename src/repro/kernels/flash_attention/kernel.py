"""Pallas TPU flash-attention kernel (forward).

Design (TPU-native, not a CUDA port):

  * Grid ``(batch, q_heads, num_q_blocks, num_kv_blocks)`` with the KV-block
    dimension innermost and *arbitrary* (sequential) — the online-softmax
    running state (row max ``m``, normalizer ``l``, accumulator ``acc``)
    lives in VMEM scratch that persists across KV iterations, so the
    ``S x T`` score matrix never exists in HBM (this is the whole point:
    the dry-run shows the jnp reference path is memory-bound on score
    traffic; see EXPERIMENTS.md §Perf).
  * Block shapes ``(block_q, head_dim)`` / ``(block_k, head_dim)`` are
    MXU-aligned (multiples of 128 by default) and sized so the working set
    (q, k, v blocks + f32 accumulator) fits VMEM:
    ``(bq + 2*bk) * d * 2B + bq * d * 4B + bq * bk * 4B`` ≈ 1.3 MiB at
    the default 512/512/128.
  * GQA folds into the index map: the KV block for query head ``h`` is
    ``h // group``; MQA (gemma-2b, granite) is ``group == n_heads``.
  * Sliding window / logit soft-capping / decode offset / KV-length mask
    are supported; the window is passed as a scalar *input* (VMEM) so one
    compiled kernel serves both local and global layers of gemma-2/3 under
    a scanned layer stack.

Validated against ``ref.flash_attention_ref`` in interpret mode on CPU
(tests/test_kernels/test_flash_attention.py) across shapes, dtypes, GQA
ratios, windows and soft-caps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-specific niceties are optional in interpret mode
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM

    def _compiler_params(dims):
        try:
            return pltpu.CompilerParams(dimension_semantics=dims)
        except Exception:  # older name
            return pltpu.TPUCompilerParams(dimension_semantics=dims)
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30

__all__ = ["flash_attention_fwd"]


def _kernel(win_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, logit_cap, q_offset, kv_len, bq, bk, nk, use_window):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                    # (bq, d)
    k = k_ref[0, 0]                                    # (bk, d)
    v = v_ref[0, 0]                                    # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # (bq, bk) f32
    if logit_cap and logit_cap > 0:
        s = logit_cap * jnp.tanh(s / logit_cap)

    qpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + iq * bq + q_offset
    kpos = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + ik * bk
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if use_window:
        w = win_ref[0, 0]
        mask &= (qpos - kpos) < w
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # (bq, 1) f32
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # (bq, bk) f32
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "logit_cap", "q_offset", "kv_len",
                     "block_q", "block_k", "interpret", "use_window"))
def flash_attention_fwd(q, k, v, window=None, *, causal=True, logit_cap=0.0,
                        q_offset=0, kv_len=None, block_q=512, block_k=512,
                        interpret=True, use_window=False):
    """q: (B, H, S, D); k/v: (B, Hkv, T, D); window: () int32 or None.

    Returns (B, H, S, D). Static shape requirements: S % block_q == 0,
    T % block_k == 0 (``ops.py`` pads).
    """
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    bq = min(block_q, s)
    bk = min(block_k, t)
    nq = s // bq
    nk = t // bk
    if window is None:
        window = jnp.full((1, 1), jnp.iinfo(jnp.int32).max, jnp.int32)
    else:
        window = jnp.asarray(window, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(d), causal=causal, logit_cap=logit_cap,
        q_offset=q_offset, kv_len=kv_len, bq=bq, bk=bk, nk=nk,
        use_window=use_window)

    kwargs = {}
    if _VMEM is not None:
        kwargs["scratch_shapes"] = [
            _VMEM((bq, 1), jnp.float32),
            _VMEM((bq, 1), jnp.float32),
            _VMEM((bq, d), jnp.float32),
        ]
        if not interpret:
            kwargs["compiler_params"] = _compiler_params(
                ("parallel", "parallel", "parallel", "arbitrary"))

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ih, iq, ik: (0, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
        **kwargs,
    )(window, q, k, v)
