"""Jitted public wrappers for the Pallas kernels.

``flash_attention`` / ``ssd_scan`` accept model-layout tensors, handle
padding to block multiples, choose interpret mode off-TPU, and fall back to
the jnp reference for cases the kernels do not cover (traced windows under
a scanned layer stack are supported via the window-as-input design; traced
``q_offset``/``kv_len`` during decode fall back).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention.kernel import flash_attention_fwd
from .flash_attention.ref import flash_attention_ref
from .ssd_scan.kernel import ssd_scan_fwd
from .ssd_scan.ref import ssd_chunked_ref

__all__ = ["flash_attention", "ssd_scan"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q, k, v, *, causal=True, window=None, logit_cap=0.0,
                    q_offset=0, kv_len=None, block_q=512, block_k=512,
                    interpret=None):
    """q: (B, S, H, D); k/v: (B, T, Hkv, D) — model layout. Returns like q."""
    if not isinstance(q_offset, int) or (kv_len is not None and not isinstance(kv_len, int)):
        # decode path with traced position: reference fallback
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_cap=logit_cap, q_offset=q_offset,
                                   kv_len=kv_len)
    b, s, h, d = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    if s % bq or t % bk:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_cap=logit_cap, q_offset=q_offset,
                                   kv_len=kv_len)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    use_window = window is not None
    win = None if window is None else jnp.asarray(window, jnp.int32)
    out = flash_attention_fwd(
        qt, kt, vt, win, causal=causal, logit_cap=logit_cap,
        q_offset=q_offset, kv_len=kv_len, block_q=bq, block_k=bk,
        interpret=_auto_interpret(interpret), use_window=use_window)
    return jnp.transpose(out, (0, 2, 1, 3))


def ssd_scan(x, dta, B, C, *, chunk=256, head_group=8, interpret=None):
    """Chunked SSD scan; x: (b, s, h, p), dta: (b, s, h), B/C: (b, s, n)."""
    b, s, h, p = x.shape
    if s % min(chunk, s) or h % min(head_group, h):
        y, _ = ssd_chunked_ref(x, dta, B, C, min(chunk, s))
        return y
    return ssd_scan_fwd(x, dta, B, C, chunk=chunk, head_group=head_group,
                        interpret=_auto_interpret(interpret))
