"""Jitted public wrappers for the Pallas kernels.

``flash_attention`` / ``ssd_scan`` accept model-layout tensors, handle
padding to block multiples, choose interpret mode off-TPU, and fall back to
the jnp reference for cases the kernels do not cover (traced windows under
a scanned layer stack are supported via the window-as-input design; traced
``q_offset``/``kv_len`` during decode fall back).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .flash_attention.kernel import flash_attention_fwd
from .flash_attention.ref import flash_attention_ref
from .ssd_scan.kernel import ssd_scan_fwd
from .ssd_scan.ref import ssd_chunked_ref

__all__ = ["flash_attention", "ssd_scan", "make_benchmark_op", "BENCHMARK_OPS"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q, k, v, *, causal=True, window=None, logit_cap=0.0,
                    q_offset=0, kv_len=None, block_q=512, block_k=512,
                    interpret=None):
    """q: (B, S, H, D); k/v: (B, T, Hkv, D) — model layout. Returns like q."""
    if not isinstance(q_offset, int) or (kv_len is not None and not isinstance(kv_len, int)):
        # decode path with traced position: reference fallback
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_cap=logit_cap, q_offset=q_offset,
                                   kv_len=kv_len)
    b, s, h, d = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    if s % bq or t % bk:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_cap=logit_cap, q_offset=q_offset,
                                   kv_len=kv_len)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    use_window = window is not None
    win = None if window is None else jnp.asarray(window, jnp.int32)
    out = flash_attention_fwd(
        qt, kt, vt, win, causal=causal, logit_cap=logit_cap,
        q_offset=q_offset, kv_len=kv_len, block_q=bq, block_k=bk,
        interpret=_auto_interpret(interpret), use_window=use_window)
    return jnp.transpose(out, (0, 2, 1, 3))


def ssd_scan(x, dta, B, C, *, chunk=256, head_group=8, interpret=None):
    """Chunked SSD scan; x: (b, s, h, p), dta: (b, s, h), B/C: (b, s, n)."""
    b, s, h, p = x.shape
    if s % min(chunk, s) or h % min(head_group, h):
        y, _ = ssd_chunked_ref(x, dta, B, C, min(chunk, s))
        return y
    return ssd_scan_fwd(x, dta, B, C, chunk=chunk, head_group=head_group,
                        interpret=_auto_interpret(interpret))


# ---------------------------------------------------------------------------
# Operations-under-test for the measurement campaign (repro.campaign)
# ---------------------------------------------------------------------------

BENCHMARK_OPS = ("flash_attention", "ssd_scan")


def make_benchmark_op(op: str, impl: str = "pallas", *, seq: int,
                      batch: int = 1, heads: int = 4, kv_heads: int | None = None,
                      head_dim: int = 32, state_dim: int = 16,
                      dtype=jnp.float32, seed: int = 0,
                      interpret=None):
    """Build a nullary jitted callable running one forward of ``op`` at
    sequence length ``seq`` — the operation-under-test factory for
    :class:`repro.campaign.KernelBackend`.

    ``impl="pallas"`` times the Pallas kernel (interpret mode off-TPU);
    ``impl="ref"`` times the pure-jnp oracle. Block/chunk sizes are clamped
    to divide ``seq`` so the Pallas path never silently falls back to the
    reference — a fallback would make the A-vs-B comparison measure the
    same code twice.
    """
    if op not in BENCHMARK_OPS:
        raise ValueError(f"unknown benchmark op {op!r}; one of {BENCHMARK_OPS}")
    if impl not in ("pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r}; use 'pallas' or 'ref'")
    rng = np.random.default_rng(seed + 7919 * seq)
    kv_heads = heads if kv_heads is None else kv_heads

    def _t(*shape, scale=1.0):
        return jnp.asarray(rng.normal(0.0, scale, shape), dtype)

    if op == "flash_attention":
        block = seq if seq <= 128 else 128
        if seq % block:
            raise ValueError(f"seq={seq} must be a multiple of {block} for "
                             "the Pallas flash-attention grid")
        q = _t(batch, seq, heads, head_dim)
        k = _t(batch, seq, kv_heads, head_dim)
        v = _t(batch, seq, kv_heads, head_dim)
        if impl == "pallas":
            fn = jax.jit(lambda q, k, v: flash_attention(
                q, k, v, causal=True, block_q=block, block_k=block,
                interpret=_auto_interpret(interpret)))
        else:
            fn = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v,
                                                             causal=True))
        return lambda: fn(q, k, v)

    chunk = seq if seq <= 64 else 64
    if seq % chunk:
        raise ValueError(f"seq={seq} must be a multiple of {chunk} for the "
                         "chunked SSD scan")
    hg = heads if heads <= 8 else 8
    x = _t(batch, seq, heads, head_dim)
    dta = -jnp.abs(_t(batch, seq, heads, scale=0.5)) - 0.05
    B = _t(batch, seq, state_dim)
    C = _t(batch, seq, state_dim)
    if impl == "pallas":
        fn = jax.jit(lambda x, dta, B, C: ssd_scan(
            x, dta, B, C, chunk=chunk, head_group=hg,
            interpret=_auto_interpret(interpret)))
    else:
        fn = jax.jit(lambda x, dta, B, C: ssd_chunked_ref(x, dta, B, C,
                                                          chunk)[0])
    return lambda: fn(x, dta, B, C)
