"""The calibration parameter surface: SimNet's noise model as a bounded,
declarative search space.

The simulator's stochastic behavior is controlled by the
:class:`~repro.core.mpi_ops.SimCollective` noise model (AR(1) coefficient,
bimodal-tail / spike / rank-imbalance mixture weights, per-op base
latencies) and the :class:`~repro.core.simnet.ClockParams` drift model
(``rw_sigma`` et al.). A :class:`CalibrationSpace` names a subset of those
knobs with bounds, and :meth:`CalibrationSpace.materialize` turns any
point of the space into a concrete :class:`~repro.campaign.SimBackend` —
through the same dataclass-replacement route (``op_kw`` / ``per_op_kw`` /
``clock_kw`` overrides) a :class:`~repro.core.factors.FactorGrid` cell
uses, so every candidate carries its parameters in its factor fingerprint
and its campaigns resume from a store like any other experiment.

Parameter names are dotted paths:

  ``op.<field>``              a :class:`SimCollective` field applied to
                              every collective (``op_kw``);
  ``per_op.<name>.<field>``   the same field for one named collective only
                              (``per_op_kw`` — per-op base latencies);
  ``clock.<field>``           a :class:`ClockParams` field (``clock_kw``).

Unknown fields are rejected at space-construction time: a typo'd knob
would otherwise "fit" by never changing anything.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.campaign.backends import SimBackend
from repro.core.mpi_ops import SimCollective
from repro.core.simnet import ClockParams

__all__ = ["CalibrationParam", "CalibrationSpace", "default_space"]

_OP_FIELDS = {f.name for f in dataclasses.fields(SimCollective)
              if not f.name.startswith("_")}
_CLOCK_FIELDS = {f.name for f in dataclasses.fields(ClockParams)}


@dataclass(frozen=True)
class CalibrationParam:
    """One bounded knob of the noise model.

    ``init`` is the fit's starting value (defaults to the bounds'
    midpoint); fits never step outside ``[lo, hi]``. ``resolution`` is the
    granularity values are rounded to before materialization — it makes
    parameter vectors hashable-by-value, so a resumed fit re-requests
    bit-identical backend configs (and therefore identical factor
    fingerprints) for the evaluations it replays.
    """

    name: str
    lo: float
    hi: float
    init: float | None = None
    resolution: float = 1e-9

    def __post_init__(self):
        if not np.isfinite(self.lo) or not np.isfinite(self.hi) \
                or self.lo >= self.hi:
            raise ValueError(f"CalibrationParam {self.name!r}: need finite "
                             f"lo < hi, got [{self.lo}, {self.hi}]")
        parts = self.name.split(".")
        if parts[0] == "op" and len(parts) == 2:
            fields, kind = _OP_FIELDS, "SimCollective"
        elif parts[0] == "per_op" and len(parts) == 3:
            fields, kind = _OP_FIELDS, "SimCollective"
        elif parts[0] == "clock" and len(parts) == 2:
            fields, kind = _CLOCK_FIELDS, "ClockParams"
        else:
            raise ValueError(
                f"CalibrationParam {self.name!r}: name must be "
                "'op.<field>', 'per_op.<opname>.<field>' or "
                "'clock.<field>'")
        if parts[-1] not in fields:
            raise ValueError(
                f"CalibrationParam {self.name!r}: {parts[-1]!r} is not a "
                f"{kind} field (a typo'd knob would silently never move)")
        if self.init is not None and not self.lo <= self.init <= self.hi:
            raise ValueError(f"CalibrationParam {self.name!r}: init "
                             f"{self.init} outside [{self.lo}, {self.hi}]")

    @property
    def start(self) -> float:
        return self.init if self.init is not None \
            else 0.5 * (self.lo + self.hi)

    def clip(self, value: float) -> float:
        v = float(np.clip(value, self.lo, self.hi))
        if self.resolution > 0:
            v = round(round(v / self.resolution) * self.resolution, 12)
        return float(np.clip(v, self.lo, self.hi))


@dataclass
class CalibrationSpace:
    """A named, bounded subset of SimNet's noise-model knobs, plus the
    base :class:`~repro.campaign.SimBackend` every candidate derives from
    (cluster size, sync method, window size, engine — everything that is
    *not* being fitted)."""

    params: tuple
    base: SimBackend = field(default_factory=SimBackend)

    def __post_init__(self):
        self.params = tuple(self.params)
        if not self.params:
            raise ValueError("CalibrationSpace: no parameters to fit")
        names = [p.name for p in self.params]
        if len(set(names)) != len(names):
            raise ValueError(f"CalibrationSpace: duplicate params {names}")

    def names(self) -> list[str]:
        return [p.name for p in self.params]

    def start(self) -> dict[str, float]:
        """The fit's starting point."""
        return {p.name: p.clip(p.start) for p in self.params}

    def clip(self, values: dict) -> dict:
        """``values`` clamped into bounds and snapped to resolution, in
        parameter-declaration order."""
        by_name = {p.name: p for p in self.params}
        unknown = sorted(set(values) - set(by_name))
        if unknown:
            raise KeyError(f"CalibrationSpace.clip: unknown params "
                           f"{unknown}; space has {self.names()}")
        return {p.name: p.clip(values[p.name]) for p in self.params}

    def materialize(self, values: dict) -> SimBackend:
        """A concrete backend at one point of the space — the base
        backend with ``op_kw`` / ``per_op_kw`` / ``clock_kw`` overridden
        by dataclass replacement, exactly as a factor-grid cell would.
        The overrides land in the backend's factor ``extra`` tuples, so
        two candidates never share a fingerprint."""
        values = self.clip(values)
        op_kw = dict(self.base.op_kw)
        per_op_kw = {op: dict(kw) for op, kw in self.base.per_op_kw.items()}
        clock_kw = dict(self.base.clock_kw)
        for name, v in values.items():
            parts = name.split(".")
            if parts[0] == "op":
                op_kw[parts[1]] = v
            elif parts[0] == "per_op":
                per_op_kw.setdefault(parts[1], {})[parts[2]] = v
            else:
                clock_kw[parts[1]] = v
        return dataclasses.replace(self.base, op_kw=op_kw,
                                   per_op_kw=per_op_kw, clock_kw=clock_kw)

    def manifest(self) -> dict:
        """The declarative form persisted in the store's ``calib`` line —
        enough for a resumed fit to verify it is continuing the same
        search."""
        return dict(
            params=[dict(name=p.name, lo=p.lo, hi=p.hi, init=p.start,
                         resolution=p.resolution) for p in self.params],
            base=dict(p=self.base.p, seed0=self.base.seed0,
                      sync_name=self.base.sync_name,
                      win_size=self.base.win_size, engine=self.base.engine,
                      op_kw=dict(self.base.op_kw),
                      per_op_kw={op: dict(kw) for op, kw
                                 in self.base.per_op_kw.items()},
                      clock_kw=dict(self.base.clock_kw)),
        )


def default_space(base: SimBackend | None = None,
                  names: list[str] | None = None,
                  latency_scale: float = 1.0) -> CalibrationSpace:
    """The stock noise-model surface: the knobs the paper's variability
    phenomenology actually exercises — common-duration noise, the bimodal
    tail (Fig. 14), OS-noise spikes, rank imbalance, the AR(1)
    autocorrelation between consecutive calls, the per-op latency terms,
    and the clock's random-walk drift. ``names`` restricts to a subset
    (CI smoke fits 2-3 knobs, the nightly fit takes the lot).

    ``latency_scale`` widens the absolute-latency bounds (``alpha`` /
    ``gamma``) by that factor. The stock bounds are sized for simulator-
    scale collectives (tens of µs); a real target measured through a
    dispatch-heavy runtime (``JaxBackend`` pmap on CPU runs hundreds of
    µs per call) sits far outside them, and a fit against it would
    silently rail at the upper bound instead of fitting."""
    if latency_scale <= 0:
        raise ValueError(f"default_space: latency_scale must be positive, "
                         f"got {latency_scale}")
    ls = float(latency_scale)
    stock = {
        "op.alpha": CalibrationParam("op.alpha", 0.5e-6, 12e-6 * ls),
        "op.gamma": CalibrationParam("op.gamma", 0.2e-6, 8e-6 * ls),
        "op.noise_sigma": CalibrationParam("op.noise_sigma", 0.005, 0.20),
        "op.tail_prob": CalibrationParam("op.tail_prob", 0.0, 0.30),
        "op.tail_shift": CalibrationParam("op.tail_shift", 0.05, 1.0),
        "op.spike_prob": CalibrationParam("op.spike_prob", 0.0, 0.02),
        "op.rank_imbalance": CalibrationParam("op.rank_imbalance", 0.0, 0.25),
        "op.autocorr": CalibrationParam("op.autocorr", 0.0, 0.9),
        "clock.rw_sigma": CalibrationParam("clock.rw_sigma", 0.0, 1e-6),
    }
    if names is not None:
        unknown = sorted(set(names) - set(stock))
        if unknown:
            raise ValueError(f"default_space: unknown params {unknown}; "
                             f"stock params are {sorted(stock)}")
        params = tuple(stock[n] for n in names)
    else:
        params = tuple(stock.values())
    return CalibrationSpace(params=params, base=base or SimBackend())
