"""repro.calibrate — fit SimNet's noise model to measured runs, then
certify the fit with the TOST audit engine.

The bridge between every simulated result in this repo and real
hardware: a :class:`CalibrationSpace` declares which noise-model knobs
(AR(1) coefficient, bimodal-tail / spike / imbalance mixture weights,
per-op latencies, clock ``rw_sigma``) may move and within what bounds;
:func:`calibrate` measures a target backend, fits the space by
deterministic coordinate descent on a per-cell quantile-distance
objective (every candidate an ordinary store-resumed
:class:`~repro.campaign.Campaign`), and certifies the fitted simulator
EQUIVALENT / DRIFTED / INCONCLUSIVE on held-out launch epochs via
:func:`~repro.history.audit_tables`. ::

    from repro.calibrate import calibrate, default_space
    from repro.campaign import JaxBackend, ResultStore, SimBackend
    from repro.history import RunArchive

    space = default_space(base=SimBackend(p=8, seed0=0))
    result = calibrate(space, JaxBackend(),
                       store=ResultStore("runs/calib-000.jsonl"),
                       archive=RunArchive("runs/"))
    assert result.ok, f"certification: {result.verdict}"

Fits are resumable: search state persists as ``calib-round`` store lines
(the ``sweep-alloc`` pattern), measurements resume at record granularity.
"""

from .fit import CALIBRATED_TAG, CalibrationResult, calibrate, certify_heldout
from .space import CalibrationParam, CalibrationSpace, default_space

__all__ = [
    "CalibrationParam",
    "CalibrationSpace",
    "default_space",
    "calibrate",
    "certify_heldout",
    "CalibrationResult",
    "CALIBRATED_TAG",
]
