"""The sim↔real calibration loop: fit, then certify.

Cornebize & Legrand (arXiv:2102.07674) make the case that a simulator
predicts real MPI behavior only when its *variability* model is
calibrated against measurements — matching means is not enough. This
module closes ROADMAP item 1 with exactly that loop, built entirely out
of the repo's existing experimental machinery:

  1. **measure** the target backend (real ``JaxBackend`` collectives, or
     a sim "truth" for CI) through an ordinary
     :class:`~repro.campaign.Campaign` into a
     :class:`~repro.campaign.ResultStore` — launch-epoch replication,
     adaptive nrep, store resume all inherited;
  2. **fit** a :class:`~repro.calibrate.CalibrationSpace` of SimNet noise
     parameters by deterministic coordinate descent: every candidate is
     materialized as a :class:`~repro.campaign.SimBackend`, measured
     through its own (store-resumed, fingerprint-keyed) campaign over the
     *fit* launch epochs, and scored with the per-cell
     :func:`~repro.sweeps.quantile_distance` between per-epoch-median
     distributions. The search is RNG-free, so a given (space, target,
     design, seed) always walks the same trajectory; each completed pass
     over the parameters persists a ``calib-round`` store line, and a
     killed fit replays those lines on resume — the ``sweep-alloc``
     pattern applied to search state;
  3. **certify** on *held-out* launch epochs the fit never saw:
     :func:`~repro.history.audit_tables` (TOST ±margin, Holm-corrected)
     between the fitted simulator and the target, the same engine the
     drift gate uses. The store is registered into the
     :class:`~repro.history.RunArchive` under the ``calibrated`` tag with
     the full fit report (fitted params, objective trace, per-cell
     verdicts) logged to the archive manifest.

A fit is only as trustworthy as its certification: ``CalibrationResult.ok``
is False exactly when a held-out cell shows positive drift evidence —
the CLI exits nonzero on that, and only that.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.campaign import Campaign, CampaignSpec, ResultStore
from repro.core.design import (NREP_SPENT, ExperimentDesign,
                               MeasurementRecord, ResultTable, TestCase,
                               analyze_records)
from repro.history import DEFAULT_MARGIN, AuditReport, audit_tables
from repro.sweeps import DEFAULT_QUANTILES, quantile_distance

from .space import CalibrationSpace

__all__ = ["CalibrationResult", "calibrate", "certify_heldout",
           "CALIBRATED_TAG"]

#: Archive tag a certified calibration run is registered under.
CALIBRATED_TAG = "calibrated"

#: Objective improvements below this are noise, not progress.
_IMPROVE_EPS = 1e-12


@dataclass
class CalibrationResult:
    """Everything the calibration loop decided, measured and certified."""

    params: dict                     # fitted parameter vector
    objective: float                 # its fit-window objective
    rounds: list = field(default_factory=list)   # objective trace per round
    report: AuditReport | None = None            # held-out certification
    target_fingerprint: str | None = None
    fitted_fingerprint: str | None = None
    calib_id: str | None = None
    run_entry: object = None         # RunEntry when an archive was attached
    n_fit_epochs: int = 0
    n_heldout_epochs: int = 0
    spent_nrep: int = 0
    n_rounds_resumed: int = 0        # rounds replayed from calib-round lines

    @property
    def ok(self) -> bool:
        """The gate criterion: no held-out cell with positive drift
        evidence (INCONCLUSIVE cells report visibly but do not fail)."""
        return self.report is not None and self.report.ok

    @property
    def verdict(self) -> str:
        if self.report is None:
            return "UNCERTIFIED"
        if self.report.all_equivalent:
            return "EQUIVALENT"
        return "DRIFTED" if not self.report.ok else "INCONCLUSIVE"

    def report_dict(self) -> dict:
        """The fit report persisted to the archive manifest and the
        store's meta stamp: fitted params, objective trace, per-cell
        verdicts — the provenance a later reader needs to trust (or
        re-run) this calibration."""
        cells = []
        if self.report is not None:
            cells = [dict(op=c.op, msize=c.msize, verdict=c.verdict,
                          ratio=round(c.ratio, 6),
                          ci=[round(c.ci_lo, 6), round(c.ci_hi, 6)],
                          p_tost_holm=c.p_tost_holm,
                          p_diff_holm=c.p_diff_holm)
                     for c in self.report.cells]
        return dict(
            calib=self.calib_id, verdict=self.verdict,
            params={k: float(v) for k, v in self.params.items()},
            objective=float(self.objective),
            trace=[dict(round=r["round"], objective=r["objective"],
                        step=r["step"], n_evals=len(r.get("evals", ())))
                   for r in self.rounds],
            n_fit_epochs=self.n_fit_epochs,
            n_heldout_epochs=self.n_heldout_epochs,
            spent_nrep=int(self.spent_nrep),
            target_fingerprint=self.target_fingerprint,
            fitted_fingerprint=self.fitted_fingerprint,
            cells=cells,
        )


def _epoch_table(records: list[MeasurementRecord], lo: int, hi: int,
                 outlier_filter: bool) -> ResultTable:
    """Algorithm-6 reduction of the records inside epoch window
    ``[lo, hi)`` — how one full-design campaign yields separate fit and
    held-out views without re-measuring anything."""
    return analyze_records([r for r in records if lo <= r.epoch < hi],
                           outlier_filter)


def _objective(ref: ResultTable, cand: ResultTable, cases: list[TestCase],
               quantiles: tuple) -> float:
    """Sum of per-cell quantile distances between per-epoch-median
    distributions — the log-ratio scale makes cells of different
    magnitude commensurable (see :func:`~repro.sweeps.quantile_distance`)."""
    total = 0.0
    for case in cases:
        r, c = ref.medians(case), cand.medians(case)
        if r.size == 0 or c.size == 0:
            raise ValueError(f"calibrate: no per-epoch medians for "
                             f"{case.key()} on one side — target and "
                             "candidate campaigns must share the case list")
        total += quantile_distance(r, c, quantiles)
    return total


def _merge_into_snapshot(snap, fingerprint: str, records) -> None:
    """Keep the one up-front snapshot coherent with what this process
    appended, so a later campaign on the *same* fingerprint (the fitted
    backend's full-epoch run after its fit-window evals) resumes instead
    of re-measuring — the same bookkeeping the sweep scheduler does."""
    if snap is None:
        return
    have = {(r.case.op, r.case.msize, r.epoch)
            for r in snap.records.get(fingerprint, [])}
    for r in records:
        key = (r.case.op, r.case.msize, r.epoch)
        if key not in have:
            snap.records.setdefault(fingerprint, []).append(r)
            have.add(key)


def certify_heldout(target_records, fitted_records, n_fit_epochs: int,
                    design: ExperimentDesign,
                    margin: float = DEFAULT_MARGIN, alpha: float = 0.05,
                    seed: int = 0) -> AuditReport:
    """TOST-certify a fitted simulator against the target on the held-out
    launch epochs only (``epoch >= n_fit_epochs``) — the fit never saw
    them, so equivalence here is out-of-sample evidence, not an echo of
    the objective. Exposed separately so a *frozen* candidate (the
    positive-control mis-fit in the soundness tests) can be certified
    without running a fit."""
    n = design.n_launch_epochs
    ref = _epoch_table(target_records, n_fit_epochs, n,
                       design.outlier_filter)
    cand = _epoch_table(fitted_records, n_fit_epochs, n,
                        design.outlier_filter)
    return audit_tables(ref, cand, margin=margin, alpha=alpha, seed=seed)


def calibrate(space: CalibrationSpace, target, cases=None,
              design: ExperimentDesign | None = None,
              store: ResultStore | None = None, archive=None,
              seed: int = 0, n_fit_epochs: int | None = None,
              budget: int | None = None, max_rounds: int = 8,
              step0: float = 0.25, step_tol: float = 0.02,
              margin: float = DEFAULT_MARGIN, alpha: float = 0.05,
              quantiles: tuple = DEFAULT_QUANTILES,
              name: str = "calib") -> CalibrationResult:
    """Fit ``space`` so the simulator reproduces ``target``, then certify.

    ``target`` is any :class:`~repro.campaign.MeasurementBackend`; its
    campaign runs the full ``design``, of which the first
    ``n_fit_epochs`` launch epochs (default: two thirds) feed the
    objective and the rest are held out for certification. ``budget``
    caps total repetitions spent (a stop criterion, checked at round
    boundaries); ``max_rounds``/``step_tol`` bound the coordinate
    descent. All campaigns — target, every candidate, the fitted final —
    share ``store``, so a killed fit resumes: measurements at record
    granularity, search state by replaying ``calib-round`` lines.

    With ``archive``, the store is registered under
    :data:`CALIBRATED_TAG` and the fit report is logged to the archive
    manifest regardless of verdict — a DRIFTED calibration is a result
    to keep, not to hide; the caller gates on ``result.ok``.
    """
    if store is None:
        raise ValueError("calibrate: a store is required — candidate "
                         "campaigns and calib-round search state persist "
                         "there (pass store=)")
    design = design or ExperimentDesign(n_launch_epochs=18, nrep=30,
                                        seed=seed)
    cases = list(cases) if cases else list(target.default_cases())
    n = design.n_launch_epochs
    n_fit = n_fit_epochs if n_fit_epochs is not None else max(1, (2 * n) // 3)
    if not 1 <= n_fit <= n - 2:
        raise ValueError(
            f"calibrate: need 1 <= n_fit_epochs <= n_launch_epochs-2 "
            f"(got n_fit={n_fit}, n={n}) — certification needs at least "
            "two held-out epochs")
    if isinstance(getattr(target, "seed0", None), int) \
            and target.seed0 == space.base.seed0 \
            and type(target) is type(space.base):
        raise ValueError(
            "calibrate: target and candidate simulators share seed0 — the "
            "fit would match one noise realization instead of the "
            "distribution; give the target a different seed0")

    snap = store.snapshot()

    # -- 1. the target campaign (full design, all epochs) ------------------
    nrep_mark = NREP_SPENT.read()
    spent = 0
    target_spec = CampaignSpec(cases, design, name=f"{name}/target")
    target_res = Campaign(target_spec, target, store).run(snapshot=snap)
    _merge_into_snapshot(snap, target_res.fingerprint, target_res.records)
    ref_fit = _epoch_table(target_res.records, 0, n_fit,
                           design.outlier_filter)

    # -- 2. the fit --------------------------------------------------------
    manifest = dict(
        name=name, space=space.manifest(),
        target_fingerprint=target_res.fingerprint,
        cases=[[c.op, int(c.msize)] for c in cases],
        design=target_spec.meta(), n_fit_epochs=int(n_fit), seed=int(seed),
        objective="quantile_distance", quantiles=list(quantiles),
        max_rounds=int(max_rounds), step0=float(step0),
        step_tol=float(step_tol), budget=budget,
    )
    calib_id = store.append_calib(manifest, snapshot=snap)
    persisted = {int(r["round"]): r
                 for r in snap.calib_rounds_by_id.get(calib_id, [])}

    cache: dict[tuple, float] = {}

    def key_of(values: dict) -> tuple:
        return tuple((p.name, values[p.name]) for p in space.params)

    def evaluate(values: dict) -> float:
        k = key_of(values)
        if k in cache:
            return cache[k]
        backend = space.materialize(values)
        res = Campaign(CampaignSpec(cases, design, name=f"{name}/eval"),
                       backend, store).run(snapshot=snap,
                                           epochs=range(n_fit))
        _merge_into_snapshot(snap, res.fingerprint, res.records)
        obj = _objective(ref_fit, res.table, cases, quantiles)
        cache[k] = obj
        return obj

    x = space.start()
    best = evaluate(x)
    step = float(step0)
    rounds: list[dict] = []
    n_resumed = 0
    for r in range(max_rounds):
        line = persisted.get(r)
        if line is not None:
            # replay: the persisted decision is authoritative — re-deciding
            # on what might now be a larger record set would fork the
            # trajectory (same rule as sweep-alloc replay)
            x = space.clip({k: float(v) for k, v in line["params"].items()})
            best = float(line["objective"])
            step = float(line["step"])
            spent = int(line["spent_nrep"])
            for vals, obj in line.get("evals", ()):
                cache.setdefault(
                    key_of(space.clip(
                        {k: float(v) for k, v in vals.items()})),
                    float(obj))
            cache[key_of(x)] = best
            rounds.append(dict(line))
            n_resumed += 1
            if step < step_tol or (budget is not None and spent >= budget):
                break
            continue
        evals: list = []
        improved = False
        for p in space.params:
            for direction in (1.0, -1.0):
                cand = dict(x)
                cand[p.name] = p.clip(x[p.name]
                                      + direction * step * (p.hi - p.lo))
                cand = space.clip(cand)
                if cand == x:
                    continue
                obj = evaluate(cand)
                evals.append([dict(cand), float(obj)])
                if obj < best - _IMPROVE_EPS:
                    x, best = cand, obj
                    improved = True
        if not improved:
            step *= 0.5
        spent += NREP_SPENT.read() - nrep_mark
        nrep_mark = NREP_SPENT.read()
        store.append_calib_round(calib_id, r, x, best, step, evals, spent)
        rounds.append(dict(kind="calib-round", calib=calib_id, round=r,
                           params=dict(x), objective=float(best),
                           step=float(step), evals=evals,
                           spent_nrep=int(spent)))
        if step < step_tol or (budget is not None and spent >= budget):
            break

    # -- 3. certification on the held-out epochs ---------------------------
    fitted_backend = space.materialize(x)
    fitted_res = Campaign(CampaignSpec(cases, design, name=f"{name}/fitted"),
                          fitted_backend, store).run(snapshot=snap)
    _merge_into_snapshot(snap, fitted_res.fingerprint, fitted_res.records)
    report = certify_heldout(target_res.records, fitted_res.records, n_fit,
                             design, margin=margin, alpha=alpha, seed=seed)

    result = CalibrationResult(
        params=dict(x), objective=float(best), rounds=rounds, report=report,
        target_fingerprint=target_res.fingerprint,
        fitted_fingerprint=fitted_res.fingerprint, calib_id=calib_id,
        n_fit_epochs=n_fit, n_heldout_epochs=n - n_fit,
        spent_nrep=int(spent), n_rounds_resumed=n_resumed)

    if archive is not None:
        store.append_meta(calibration=result.report_dict())
        entry = archive.register(store.path, tag=CALIBRATED_TAG)
        archive.log_calibration(entry, result.report_dict())
        result.run_entry = entry
    return result
