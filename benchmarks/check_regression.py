"""CI perf gate: compare a fresh ``benchmarks.run run --json`` report
against the committed baseline and fail on wall-clock regressions.

Usage::

    python -m benchmarks.run run --only micro --json fresh.json
    python benchmarks/check_regression.py benchmarks/baseline.json fresh.json \
        --tolerance 2.0

Two gates, both with the same configurable tolerance:

  * per-bench wall-clock (the ``seconds`` field): ``fresh <= tolerance *
    baseline``. Wall-clock across runner generations is noisy, so the
    default tolerance is a deliberately loose 2x — this catches
    order-of-magnitude blowups, not 10% drift;
  * *speedup rows* (row name containing ``speedup``, whose value is a
    within-run ratio like batch-vs-scalar): ``fresh >= baseline /
    tolerance``. A within-run ratio cancels machine speed entirely, so
    this is the robust detector for the "vectorized engine silently fell
    back to the scalar loop" class of regression even on a runner much
    slower or faster than the one that recorded the baseline.

A bench present in the baseline but missing (or erroring) in the fresh
report fails the gate; *new* benches in the fresh report pass with a note,
so adding a benchmark does not require touching the baseline in the same
commit.

Each report also carries the total repetitions spent per bench
(``nrep_total``) — the machine-independent experiment cost. It is printed
for the record whenever both reports carry it, but never gated: nrep
changes are deliberate design changes (adaptive stopping, budgeted
allocation), not environmental noise, so they belong in review diffs of
the baseline, not in a tolerance band.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys


def missing_trajectory_artifacts(changes_path: str,
                                 bench_dir: str) -> list[str]:
    """``BENCH_PR*.json`` artifacts referenced by the perf-trajectory log
    (``CHANGES.md``) but absent from ``bench_dir``.

    The trajectory is the sequence of per-PR reports the log claims were
    committed; a referenced-but-missing file means the trajectory has a
    hole that a plain baseline-vs-fresh gate would never notice. Reported
    as a warning, not a failure: the hole is a provenance problem in an
    *old* commit, and failing every future CI run cannot repair it."""
    if not os.path.exists(changes_path):
        return []
    with open(changes_path) as f:
        referenced = sorted(set(re.findall(r"BENCH_PR\d+\.json", f.read())))
    return [name for name in referenced
            if not os.path.exists(os.path.join(bench_dir, name))]


def load_benches(path: str) -> dict[str, dict]:
    with open(path) as f:
        report = json.load(f)
    return {b["name"]: b for b in report.get("benches", [])}


def _speedup_rows(benches: dict[str, dict]) -> dict[str, float]:
    return {r["name"]: float(r["us_per_call"])
            for b in benches.values() for r in b.get("rows", [])
            if "speedup" in r["name"]}


def check(baseline: dict[str, dict], fresh: dict[str, dict],
          tolerance: float) -> int:
    failures = 0
    print(f"{'bench':<36} {'base[s]':>9} {'fresh[s]':>9} {'ratio':>7}  gate")
    for name, base in sorted(baseline.items()):
        base_s = float(base["seconds"])
        fb = fresh.get(name)
        if fb is None:
            print(f"{name:<36} {base_s:>9.3f} {'-':>9} {'-':>7}  FAIL (missing)")
            failures += 1
            continue
        if fb.get("error"):
            print(f"{name:<36} {base_s:>9.3f} {'-':>9} {'-':>7}  "
                  f"FAIL ({fb['error']})")
            failures += 1
            continue
        fresh_s = float(fb["seconds"])
        ratio = fresh_s / base_s if base_s > 0 else float("inf")
        ok = fresh_s <= tolerance * base_s
        print(f"{name:<36} {base_s:>9.3f} {fresh_s:>9.3f} {ratio:>7.2f}  "
              f"{'ok' if ok else f'FAIL (> {tolerance:g}x)'}")
        if not ok:
            failures += 1
    for name in sorted(set(fresh) - set(baseline)):
        print(f"{name:<36} {'-':>9} {float(fresh[name]['seconds']):>9.3f} "
              f"{'-':>7}  ok (new bench, no baseline)")
    # machine-independent gate: within-run speedup ratios must not collapse
    base_sp, fresh_sp = _speedup_rows(baseline), _speedup_rows(fresh)
    for name, base_x in sorted(base_sp.items()):
        fresh_x = fresh_sp.get(name)
        if fresh_x is None:
            print(f"{name:<36} {base_x:>8.1f}x {'-':>9} {'-':>7}  "
                  "FAIL (speedup row missing)")
            failures += 1
            continue
        ok = fresh_x >= base_x / tolerance
        print(f"{name:<36} {base_x:>8.1f}x {fresh_x:>8.1f}x "
              f"{fresh_x / base_x:>7.2f}  "
              f"{'ok' if ok else f'FAIL (< 1/{tolerance:g} of baseline)'}")
        if not ok:
            failures += 1
    # informational: repetitions spent (exact counts, not gated — see
    # module docstring)
    nrep_pairs = [(n, b.get("nrep_total"), fresh.get(n, {}).get("nrep_total"))
                  for n, b in sorted(baseline.items())]
    nrep_pairs = [(n, b, f) for n, b, f in nrep_pairs
                  if b is not None and f is not None]
    if nrep_pairs:
        print(f"{'bench (nrep spent)':<36} {'base':>9} {'fresh':>9}")
        for name, base_n, fresh_n in nrep_pairs:
            drift = "" if base_n == fresh_n else "  (changed)"
            print(f"{name:<36} {base_n:>9} {fresh_n:>9}{drift}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON "
                                     "(benchmarks/baseline.json)")
    ap.add_argument("fresh", help="fresh --json report to gate")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="fail when fresh > tolerance * baseline wall-clock "
                         "(default 2.0)")
    args = ap.parse_args()
    if args.tolerance <= 0:
        ap.error("--tolerance must be positive")
    failures = check(load_benches(args.baseline), load_benches(args.fresh),
                     args.tolerance)
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    for name in missing_trajectory_artifacts(
            os.path.join(os.path.dirname(bench_dir), "CHANGES.md"),
            bench_dir):
        print(f"warning: trajectory artifact benchmarks/{name} is "
              "referenced by CHANGES.md but does not exist — the perf "
              "trajectory has a hole", file=sys.stderr)
    if failures:
        print(f"perf gate: {failures} regression(s) beyond "
              f"{args.tolerance:g}x baseline", file=sys.stderr)
        raise SystemExit(1)
    print("perf gate: ok", file=sys.stderr)


if __name__ == "__main__":
    main()
