"""Benchmark harness entry point: ``python -m benchmarks.run [options]``.

One function per paper table/figure (see ``benchmarks.suite``). Prints
``name,us_per_call,derived`` CSV; per-bench wall-clock goes to stderr.

Options:
  --only SUBSTR   substring filter on benchmark function names
                  (e.g. ``--only fig`` for the simulation-backed figures,
                  ``--only micro`` for the engine microbenchmark)
  --list          print the available benchmark names and exit
  --seed N        offset every simulator seed by N (re-rolls the whole
                  suite under a different RNG universe; default 0)
  --workers N     processes for campaign launch epochs (default 1 =
                  serial; N > 1 gives bit-identical results and pays off
                  only when one epoch outweighs pool startup)
  --json PATH     also write machine-readable results: per-bench wall-clock
                  seconds + rows, for recording the perf trajectory in CI
  --store PATH    persist campaign results to an append-only JSONL
                  ResultStore (re-running against the same store resumes:
                  already-measured cells are loaded, not re-measured)
  --compare A B   compare two stores' campaigns per test case (Wilcoxon on
                  per-epoch medians, Fig. 28 style) and exit
  --guidelines    verify the PGMPI-style performance-guideline family
                  instead of running the suite; ``--only`` selects the
                  backend (``sim`` default, or ``kernel``), ``--store``
                  makes the verification campaign resumable, ``--seed``
                  re-rolls it. Exits non-zero when a guideline is VIOLATED
                  (family-wise Holm-corrected alpha = 0.05), so it can gate
                  CI directly.
  --sweep         run a factor sweep on the sim backend and print the
                  factor-impact report (Kruskal-Wallis + Holm main effects,
                  Cliff's-delta ranking, interaction screen). ``--axes``
                  picks the swept axes, ``--store`` makes the sweep
                  resumable at cell granularity, ``--workers`` shards grid
                  cells over a process pool, ``--seed`` re-rolls it.
  --axes NAMES    comma-separated subset of the stock factor axes for
                  ``--sweep`` (default: tuning,sync_method,window_us,dtype)
  --fleet N       run ``--sweep`` fault-tolerantly on N lease-queue worker
                  processes (``repro.fleet``): dead/stalled workers lose
                  their lease, cells retry under jittered backoff, and
                  repeated failures are quarantined into the store instead
                  of wedging the sweep. Requires ``--store``. Quarantined
                  cells are reported on stderr with exit 0 (degraded-but-
                  honest); exit 1 only when no cell completes at all.
  --faults SPEC   inject seeded, deterministic faults into a ``--fleet``
                  sweep (chaos mode), e.g. ``crash=0.4,straggle=0.2,seed=7``
                  — kinds: crash (worker killed mid-cell), straggle (stall
                  past the lease TTL), raise (transient exception), torn
                  (corrupt shard line)
  --archive DIR   run-archive directory (``repro.history.RunArchive``); the
                  audit campaign registers its store here
  --audit         reproducibility-audit mode: run the fixed sim audit
                  campaign, register it into ``--archive``, and issue TOST
                  equivalence verdicts against the baseline run (latest
                  archived run sharing the factor fingerprint, or the run
                  pinned by ``--baseline``). Prints the drift report; exits
                  1 when any cell is DRIFTED, so it gates CI directly. The
                  first run into an empty archive registers as the initial
                  reference and exits 0.
  --baseline TAG  audit against the archived run tagged TAG
  --tag TAG       register this run under TAG (e.g. ``reference``)
  --mistune OP    seed a drifted collective (4x latency, 3x overhead) into
                  the audit run — the positive control: exactly OP's cells
                  must come out DRIFTED
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def _compare_stores(ap, path_a: str, path_b: str) -> None:
    """Per-case Wilcoxon comparison (Fig. 28 style) of two stores' last
    campaigns; warns when the campaigns' factor fingerprints differ in more
    than the store identity (§5.9's comparability rule)."""
    import os

    from repro.campaign import ResultStore
    from repro.core import compare_tables, format_comparison

    for p in (path_a, path_b):
        if not os.path.exists(p):
            ap.error(f"--compare: store not found: {p}")
    store_a, store_b = ResultStore(path_a), ResultStore(path_b)
    fps_a, fps_b = store_a.fingerprints(), store_b.fingerprints()
    if not fps_a or not fps_b:
        ap.error("--compare: a store holds no campaigns")
    for path, fps in ((path_a, fps_a), (path_b, fps_b)):
        if len(fps) > 1:
            print(f"# note: {path} holds {len(fps)} campaigns; comparing "
                  f"the last one ({fps[-1]})", file=sys.stderr)
    fa, fb = store_a.factors(), store_b.factors()
    diffs = sorted(k for k in fa if k != "host" and fa.get(k) != fb.get(k))
    if diffs:
        print(f"# note: factor sets differ in {diffs} — treat these as the "
              "factors under test", file=sys.stderr)
    try:
        rows = compare_tables(store_a, store_b)
    except ValueError as e:   # no common (op, msize) cells
        ap.error(f"--compare: {e}")
    print(format_comparison(rows, name_a=os.path.basename(path_a),
                            name_b=os.path.basename(path_b)))


def _run_guidelines(ap, args) -> None:
    """Guideline-verification mode: the repo auditing an implementation
    (here: the simulated MPI library, or the Pallas kernels vs. their jnp
    oracles) instead of benchmarking itself."""
    from repro.campaign import KernelBackend, ResultStore, SimBackend
    from repro.core import ExperimentDesign
    from repro.guidelines import (default_guidelines, format_report,
                                  format_violations, verify_guidelines)

    backend_name = args.only or "sim"
    if backend_name == "sim":
        backend = SimBackend(p=8, seed0=args.seed)
        design = ExperimentDesign(n_launch_epochs=10, nrep_min=20,
                                  nrep_max=150, rel_ci_target=0.05,
                                  seed=args.seed)
    elif backend_name == "kernel":
        # interpret mode off-TPU: the "pallas <= ref" guideline is expected
        # to fail there — the verdict names the emulation factor, which is
        # the point of carrying factors on every result. Lighter design:
        # a kernel launch epoch pays a real re-jit, unlike a simulated one.
        backend = KernelBackend(seed0=args.seed)
        design = ExperimentDesign(n_launch_epochs=6, nrep_min=10,
                                  nrep_max=40, rel_ci_target=0.10,
                                  seed=args.seed)
    else:
        ap.error(f"--guidelines: unknown backend {backend_name!r} "
                 "(--only sim|kernel)")
    guidelines = default_guidelines(backend_name)
    store = ResultStore(args.store) if args.store else None
    report = verify_guidelines(guidelines, backend, design=design,
                               store=store)
    print(format_report(report,
                        title=f"performance guidelines [{backend_name}]"))
    if store is not None:
        print(f"# store: {args.store} (resumable; "
              f"{report.n_resumed} cells loaded, "
              f"{report.n_measured} measured this run)", file=sys.stderr)
    if not report.ok:
        print(format_violations(report), file=sys.stderr)
        raise SystemExit(1)


def _run_sweep(ap, args) -> None:
    """Factor-sweep mode: enumerate a factor grid, run every cell as its
    own campaign (resumable through the store), and print the paper-style
    "which factors matter" table."""
    from repro.campaign import ResultStore, SweepScheduler
    from repro.sweeps import (cells_from_result, default_sim_sweep,
                              format_factor_report, interaction_screen,
                              main_effects)

    axes = None
    if args.axes:
        axes = [a.strip() for a in args.axes.split(",") if a.strip()]
    try:
        spec, backend = default_sim_sweep(seed=args.seed, axes=axes)
    except ValueError as e:
        ap.error(f"--axes: {e}")
    store = ResultStore(args.store) if args.store else None
    if args.fleet is not None:
        res = _run_fleet_sweep(ap, args, spec, backend, store)
    else:
        res = SweepScheduler(spec, backend, store,
                             n_workers=args.workers or 1).run()
    cells = cells_from_result(res)
    axis_names = ", ".join(ax.name for ax in spec.grid.axes)
    try:
        effects = main_effects(cells)
    except ValueError as e:
        # a quarantine-degraded fleet run can lose every cell of an axis
        # level; partial-but-honest results still exit 0, just without
        # the factor table the missing cells would have fed
        if not (args.fleet is not None and getattr(res, "degraded",
                                                   lambda: False)()):
            raise
        print(f"# factor analysis skipped on the degraded grid: {e}",
              file=sys.stderr)
    else:
        print(format_factor_report(effects, interaction_screen(cells),
                                   title=f"factor impact [{axis_names}]"))
    if store is not None:
        print(f"# store: {args.store} (resumable; "
              f"{res.n_cells_resumed} cells resumed, "
              f"{res.n_cells_measured} cells measured this run)",
              file=sys.stderr)


def _run_fleet_sweep(ap, args, spec, backend, store):
    """Fault-tolerant sweep execution (``--fleet N``): lease-queue
    scheduling over N worker processes, optionally under an injected
    :class:`~repro.fleet.FaultPlan` (``--faults``). Degradation semantics:
    quarantined cells are reported and the run still exits 0 — partial-
    but-honest results beat a wedged campaign — but a fleet that completes
    *nothing* exits 1."""
    from repro.fleet import FaultPlan, FleetConfig, FleetScheduler

    if store is None:
        ap.error("--fleet needs --store PATH: lease recovery and shard "
                 "federation are meaningless without durable results")
    plan = None
    if args.faults:
        try:
            plan = FaultPlan.parse(args.faults)
        except ValueError as e:
            ap.error(f"--faults: {e}")
    cfg = FleetConfig(n_workers=max(1, args.fleet), faults=plan)
    res = FleetScheduler(spec, backend, store, cfg).run()
    fl = res.fleet
    print(f"# fleet: {fl.get('n_workers')} workers, "
          f"{fl.get('n_done', 0)}/{fl.get('n_cells', 0)} cells done, "
          f"{fl.get('n_failed_attempts', 0)} failed attempts recovered, "
          f"{fl.get('n_quarantined', 0)} quarantined"
          + (f", faults: {args.faults}" if args.faults else ""),
          file=sys.stderr)
    for index, info in sorted(res.quarantined.items()):
        print(f"# QUARANTINED cell {index} "
              f"(fingerprint {info['fingerprint'][:12]}) after "
              f"{info['attempts']} attempts: {info['error']}",
              file=sys.stderr)
    if not res.cells:
        print("# fleet completed no cells: every cell exhausted its retry "
              "budget", file=sys.stderr)
        raise SystemExit(1)
    return res


def _run_audit(ap, args) -> None:
    """Reproducibility-audit mode: measure the fixed audit campaign,
    archive it, and certify it EQUIVALENT to (or DRIFTED from) the
    archived baseline — the paper's "reproducible" claim made executable."""
    from repro.campaign import Campaign, CampaignSpec, ResultStore, SimBackend
    from repro.core import ExperimentDesign, TestCase
    from repro.history import (CONTROL_TAG, RunArchive, audit_runs,
                               format_audit_report, format_drift)

    audit_ops = ("allreduce", "bcast", "alltoall")
    per_op_kw = {}
    if args.mistune:
        if args.mistune not in audit_ops:
            # per_op_kw overrides are looked up by op name, so a typo (or
            # an op the audit campaign never measures) would inject nothing
            # and the "positive control" would silently pass
            ap.error(f"--mistune: {args.mistune!r} is not an audited op "
                     f"(one of {', '.join(audit_ops)})")
        if args.tag:
            ap.error("--tag cannot be combined with --mistune: seeded-drift "
                     "runs are always tagged 'control' so they can never "
                     "become a pinned baseline")
        # the seeded-drift control: same defect shape as the sweep/guideline
        # layers' mis-tuned collective (4x latency term, 3x fixed overhead)
        per_op_kw = {args.mistune: dict(alpha=12e-6, gamma=6e-6)}
    backend = SimBackend(p=8, seed0=args.seed, per_op_kw=per_op_kw,
                         sync_kw=dict(n_fitpts=60, n_exchanges=20))
    cases = [TestCase(op, m) for op in audit_ops for m in (512, 4096)]
    design = ExperimentDesign(n_launch_epochs=12, nrep=40, seed=args.seed)
    archive = RunArchive(args.archive)

    store = ResultStore(archive.new_store_path())
    res = Campaign(CampaignSpec(cases, design, name="repro-audit"),
                   backend, store).run()
    # a seeded-drift run is a *control*: archived for the record, but never
    # eligible as a default baseline (a deliberately-bad run must not
    # become the yardstick a later bad run "passes" against)
    tag = args.tag or (CONTROL_TAG if args.mistune else None)
    entry = archive.register(store.path, tag=tag)
    print(f"# registered {store.path.name} as run {entry.run_id}"
          + (f" [{entry.tag}]" if entry.tag else ""), file=sys.stderr)

    try:
        report = audit_runs(archive, entry, baseline_tag=args.baseline)
    except (LookupError, KeyError) as e:
        if args.baseline:
            ap.error(f"--baseline: {e}")
        print(f"# first run in {args.archive}: registered as the initial "
              "reference, nothing to audit against yet", file=sys.stderr)
        return
    print(format_audit_report(
        report, title=f"reproducibility audit [sim seed={args.seed}]"))
    print(f"# archive: {args.archive} ({report.n_computed} cells computed, "
          f"{report.n_resumed} resumed; campaign measured "
          f"{res.n_measured} cells)", file=sys.stderr)
    if not report.ok:
        print(format_drift(report), file=sys.stderr)
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="MPI-benchmarking-revisited reproduction suite")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--list", action="store_true",
                    help="list available benchmarks and exit")
    ap.add_argument("--seed", type=int, default=0,
                    help="offset added to every simulator seed (>= 0)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool size for campaign launch epochs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-bench wall-clock + rows as JSON")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="persist campaign results to a JSONL ResultStore")
    ap.add_argument("--compare", nargs=2, default=None,
                    metavar=("STOREA", "STOREB"),
                    help="print the Wilcoxon comparison of two stores and exit")
    ap.add_argument("--guidelines", action="store_true",
                    help="verify performance guidelines (PGMPI) and exit; "
                         "--only picks the backend (sim|kernel)")
    ap.add_argument("--sweep", action="store_true",
                    help="run a factor sweep (sim backend) and print the "
                         "factor-impact report; --axes/--store/--workers "
                         "apply")
    ap.add_argument("--axes", default=None, metavar="NAMES",
                    help="comma-separated factor axes for --sweep")
    ap.add_argument("--fleet", type=int, default=None, metavar="N",
                    help="run --sweep fault-tolerantly on N lease-queue "
                         "workers (requires --store; quarantined cells are "
                         "reported, exit 1 only if nothing completes)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="inject seeded faults into a --fleet sweep, e.g. "
                         "crash=0.4,straggle=0.2,seed=7 (kinds: crash, "
                         "straggle, raise, torn)")
    ap.add_argument("--archive", default=None, metavar="DIR",
                    help="run-archive directory for --audit")
    ap.add_argument("--audit", action="store_true",
                    help="run the sim audit campaign, archive it, and issue "
                         "TOST equivalence verdicts vs the baseline; exit 1 "
                         "on DRIFTED")
    ap.add_argument("--baseline", default=None, metavar="TAG",
                    help="audit against the archived run tagged TAG")
    ap.add_argument("--tag", default=None, metavar="TAG",
                    help="register this audit run under TAG")
    ap.add_argument("--mistune", default=None, metavar="OP",
                    help="seed a drifted collective into the audit run "
                         "(positive control)")
    args = ap.parse_args()
    if args.seed < 0:
        ap.error("--seed must be >= 0 (it offsets non-negative RNG seeds)")
    if args.axes and not args.sweep:
        ap.error("--axes only makes sense with --sweep")
    if args.fleet is not None and not args.sweep:
        ap.error("--fleet only makes sense with --sweep")
    if args.faults and args.fleet is None:
        ap.error("--faults only makes sense with --fleet")
    if args.audit and not args.archive:
        ap.error("--audit needs --archive DIR (where runs are registered)")
    for flag, val in (("--baseline", args.baseline), ("--tag", args.tag),
                      ("--mistune", args.mistune)):
        if val and not args.audit:
            ap.error(f"{flag} only makes sense with --audit")

    if args.compare:
        _compare_stores(ap, *args.compare)
        return

    if args.audit:
        _run_audit(ap, args)
        return

    if args.guidelines:
        _run_guidelines(ap, args)
        return

    if args.sweep:
        _run_sweep(ap, args)
        return

    from benchmarks import suite
    from benchmarks.suite import ALL_BENCHES

    if args.list:
        for bench in ALL_BENCHES:
            doc = (bench.__doc__ or "").strip().splitlines()[0]
            print(f"{bench.__name__}: {doc}")
        return

    if args.json:
        try:  # fail fast, not after minutes of benchmarking; append mode
            with open(args.json, "a"):  # so an existing file is untouched
                pass
        except OSError as e:
            ap.error(f"--json path not writable: {e}")

    suite.SEED_OFFSET = args.seed
    if args.workers is not None:
        suite.N_WORKERS = max(1, args.workers)
    suite.STORE_PATH = args.store

    report = {"seed_offset": args.seed, "workers": suite.N_WORKERS,
              "benches": []}
    print("name,us_per_call,derived")
    failures = 0
    t_suite = time.time()
    for bench in ALL_BENCHES:
        if args.only and args.only not in bench.__name__:
            continue
        t0 = time.time()
        try:
            rows = bench()
        except Exception as e:  # keep the suite running; report at the end
            print(f"{bench.__name__},NaN,ERROR:{e!r}", flush=True)
            report["benches"].append(
                dict(name=bench.__name__, seconds=time.time() - t0,
                     error=repr(e), rows=[]))
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}", flush=True)
        dt = time.time() - t0
        print(f"# {bench.__name__} took {dt:.1f}s", file=sys.stderr, flush=True)
        report["benches"].append(
            dict(name=bench.__name__, seconds=round(dt, 3),
                 rows=[dict(name=n, us_per_call=u, derived=d)
                       for n, u, d in rows]))
    report["total_seconds"] = round(time.time() - t_suite, 3)
    report["failures"] = failures
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
