"""Benchmark harness entry point: ``python -m benchmarks.run <command>``.

Subcommands (``--help`` on each for its full flag set):

  run         run the benchmark suite (default when no command is given).
              One function per paper table/figure (``benchmarks.suite``);
              prints ``name,us_per_call,derived`` CSV, per-bench
              wall-clock *and total nrep spent* go to stderr / ``--json``.
  sweep       run a factor sweep on the sim backend and print the
              factor-impact report. ``--axes`` picks the swept axes,
              ``--store`` makes it resumable, ``--workers`` shards cells
              over a pool, ``--fleet N`` runs it on a lease-queue worker
              fleet (``--faults`` injects chaos), and ``--policy``
              switches to *budgeted* allocation: ``racing`` /
              ``successive_halving`` spend nrep only on axes whose
              MATTERS-or-null verdict is still undecided (``--budget``
              caps total nrep; ``--verdicts PATH`` writes the final
              per-axis verdicts as JSON for gating).
  guidelines  verify the PGMPI-style performance-guideline family
              (``--backend sim|kernel``); exit 1 on violation.
  audit       run the fixed sim audit campaign, register it into
              ``--archive``, and issue TOST equivalence verdicts against
              the baseline; exit 1 on DRIFTED.
  compare     Wilcoxon comparison of two stores' campaigns (Fig. 28).
  calibrate   fit SimNet's noise model to a measured target backend
              (``--target sim|jax``), certify the fit EQUIVALENT on
              held-out launch epochs via the TOST audit engine, and
              register the run in ``--archive`` under the
              ``calibrated`` tag; exit 1 on DRIFTED. Resumable: pass
              the same ``--store`` to replay persisted ``calib-round``
              search state and resume measurements mid-campaign.

The pre-subcommand flag spelling (``--sweep``, ``--guidelines``,
``--audit``, ``--compare``, or bare suite flags) still works through a
shim that rewrites the argv and emits a :class:`DeprecationWarning` —
update invocations to the subcommand form.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import warnings

SUBCOMMANDS = ("run", "sweep", "guidelines", "audit", "compare", "calibrate")


def _legacy_argv(argv: list[str]) -> list[str]:
    """Map a legacy flag-style invocation onto the subcommand CLI.

    The returned argv is what the subcommand parser consumes; any
    rewriting (other than defaulting a bare no-argument call to ``run``)
    warns with the canonical spelling, so CI logs show exactly what to
    migrate to.
    """
    if not argv:
        return ["run"]            # documented no-args behavior, not legacy
    if argv[0] in SUBCOMMANDS or argv[0] in ("-h", "--help"):
        return list(argv)
    args = list(argv)
    if "--compare" in args:
        i = args.index("--compare")
        new = ["compare", *args[i + 1:i + 3]]
    elif "--audit" in args:
        args.remove("--audit")
        new = ["audit", *args]
    elif "--guidelines" in args:
        args.remove("--guidelines")
        if "--only" in args:       # --only picked the backend here
            args[args.index("--only")] = "--backend"
        new = ["guidelines", *args]
    elif "--sweep" in args:
        args.remove("--sweep")
        new = ["sweep", *args]
    else:
        new = ["run", *args]
    # stacklevel audited: warn(1) = this line, (2) = main's _legacy_argv
    # call, (3) = main's caller — the external invocation site. Pinned by
    # test_cli.test_legacy_warning_points_at_caller.
    warnings.warn(
        "flag-style invocation of benchmarks.run is deprecated; use the "
        f"subcommand form: python -m benchmarks.run {' '.join(new)}",
        DeprecationWarning, stacklevel=3)
    return new


def _compare_stores(ap, path_a: str, path_b: str) -> None:
    """Per-case Wilcoxon comparison (Fig. 28 style) of two stores' last
    campaigns; warns when the campaigns' factor fingerprints differ in more
    than the store identity (§5.9's comparability rule)."""
    import os

    from repro.campaign import ResultStore
    from repro.core import compare_tables, format_comparison

    for p in (path_a, path_b):
        if not os.path.exists(p):
            ap.error(f"compare: store not found: {p}")
    store_a, store_b = ResultStore(path_a), ResultStore(path_b)
    fps_a, fps_b = store_a.fingerprints(), store_b.fingerprints()
    if not fps_a or not fps_b:
        ap.error("compare: a store holds no campaigns")
    for path, fps in ((path_a, fps_a), (path_b, fps_b)):
        if len(fps) > 1:
            print(f"# note: {path} holds {len(fps)} campaigns; comparing "
                  f"the last one ({fps[-1]})", file=sys.stderr)
    fa, fb = store_a.factors(), store_b.factors()
    diffs = sorted(k for k in fa if k != "host" and fa.get(k) != fb.get(k))
    if diffs:
        print(f"# note: factor sets differ in {diffs} — treat these as the "
              "factors under test", file=sys.stderr)
    try:
        rows = compare_tables(store_a, store_b)
    except ValueError as e:   # no common (op, msize) cells
        ap.error(f"compare: {e}")
    print(format_comparison(rows, name_a=os.path.basename(path_a),
                            name_b=os.path.basename(path_b)))


def _run_guidelines(ap, args) -> None:
    """Guideline-verification mode: the repo auditing an implementation
    (here: the simulated MPI library, or the Pallas kernels vs. their jnp
    oracles) instead of benchmarking itself."""
    from repro.campaign import KernelBackend, ResultStore, SimBackend
    from repro.core import ExperimentDesign
    from repro.guidelines import (default_guidelines, format_report,
                                  format_violations, verify_guidelines)

    backend_name = args.backend
    if backend_name == "sim":
        backend = SimBackend(p=8, seed0=args.seed)
        design = ExperimentDesign(n_launch_epochs=10, nrep_min=20,
                                  nrep_max=150, rel_ci_target=0.05,
                                  seed=args.seed)
    else:
        # interpret mode off-TPU: the "pallas <= ref" guideline is expected
        # to fail there — the verdict names the emulation factor, which is
        # the point of carrying factors on every result. Lighter design:
        # a kernel launch epoch pays a real re-jit, unlike a simulated one.
        backend = KernelBackend(seed0=args.seed)
        design = ExperimentDesign(n_launch_epochs=6, nrep_min=10,
                                  nrep_max=40, rel_ci_target=0.10,
                                  seed=args.seed)
    guidelines = default_guidelines(backend_name)
    store = ResultStore(args.store) if args.store else None
    report = verify_guidelines(guidelines, backend, design=design,
                               store=store)
    print(format_report(report,
                        title=f"performance guidelines [{backend_name}]"))
    if store is not None:
        print(f"# store: {args.store} (resumable; "
              f"{report.n_resumed} cells loaded, "
              f"{report.n_measured} measured this run)", file=sys.stderr)
    if not report.ok:
        print(format_violations(report), file=sys.stderr)
        raise SystemExit(1)


def _run_sweep(ap, args) -> None:
    """Factor-sweep mode: enumerate a factor grid, run every cell as its
    own campaign (resumable through the store), and print the paper-style
    "which factors matter" table. With ``--policy``, allocation is
    budgeted: rounds of measurement with per-look axis verdicts."""
    from repro.campaign import ResultStore, SweepScheduler
    from repro.sweeps import (cells_from_result, default_sim_sweep,
                              format_factor_report, interaction_screen,
                              main_effects)

    axes = None
    if args.axes:
        axes = [a.strip() for a in args.axes.split(",") if a.strip()]
    try:
        spec, backend = default_sim_sweep(seed=args.seed, axes=axes)
    except ValueError as e:
        ap.error(f"--axes: {e}")
    store = ResultStore(args.store) if args.store else None
    policy = None
    if args.policy:
        if store is None:
            ap.error("--policy needs --store PATH: allocation rounds "
                     "persist their decisions as sweep-alloc lines")
        from repro.sweeps import make_policy
        policy = make_policy(args.policy, nrep_budget=args.budget)
    elif args.budget is not None:
        ap.error("--budget only makes sense with --policy")
    if args.fleet is not None:
        res = _run_fleet_sweep(ap, args, spec, backend, store, policy)
    else:
        res = SweepScheduler(spec, backend, store,
                             n_workers=args.workers or 1,
                             policy=policy).run()
    cells = cells_from_result(res)
    axis_names = ", ".join(ax.name for ax in spec.grid.axes)
    effects = None
    try:
        effects = main_effects(cells)
    except ValueError as e:
        # a quarantine-degraded fleet run can lose every cell of an axis
        # level; partial-but-honest results still exit 0, just without
        # the factor table the missing cells would have fed
        if not (args.fleet is not None and getattr(res, "degraded",
                                                   lambda: False)()):
            raise
        print(f"# factor analysis skipped on the degraded grid: {e}",
              file=sys.stderr)
    else:
        print(format_factor_report(effects, interaction_screen(cells),
                                   title=f"factor impact [{axis_names}]"))
    alloc = res.meta.get("alloc")
    if alloc:
        sv = (f"{alloc['savings']:.2f}x" if alloc.get("savings")
              else "n/a")
        print(f"# alloc: policy={alloc['policy']} "
              f"rounds={alloc['n_rounds']} "
              f"spent_nrep={alloc['spent_nrep']} "
              f"uniform_nrep={alloc['uniform_nrep']} savings={sv}",
              file=sys.stderr)
        print(f"# alloc decisions: {alloc['decisions']}"
              + (f" undecided: {alloc['undecided']}"
                 if alloc.get("undecided") else ""), file=sys.stderr)
    if args.verdicts:
        verdicts = {}
        if effects is not None:
            verdicts = {e.axis: ("MATTERS" if e.significant else "null")
                        for e in effects}
        if alloc:
            # the sequential verdicts are authoritative for the axes they
            # resolved; the one-shot report only fills in the leftovers
            verdicts.update(alloc["decisions"])
        with open(args.verdicts, "w") as f:
            json.dump(dict(axes=verdicts, alloc=alloc), f, indent=2,
                      sort_keys=True)
        print(f"# wrote {args.verdicts}", file=sys.stderr)
    if store is not None:
        print(f"# store: {args.store} (resumable; "
              f"{res.n_cells_resumed} cells resumed, "
              f"{res.n_cells_measured} cells measured this run)",
              file=sys.stderr)


def _run_fleet_sweep(ap, args, spec, backend, store, policy=None):
    """Fault-tolerant sweep execution (``--fleet N``): lease-queue
    scheduling over N worker processes, optionally under an injected
    :class:`~repro.fleet.FaultPlan` (``--faults``). Degradation semantics:
    quarantined cells are reported and the run still exits 0 — partial-
    but-honest results beat a wedged campaign — but a fleet that completes
    *nothing* exits 1."""
    from repro.fleet import FaultPlan, FleetConfig, FleetScheduler

    if store is None:
        ap.error("--fleet needs --store PATH: lease recovery and shard "
                 "federation are meaningless without durable results")
    plan = None
    if args.faults:
        try:
            plan = FaultPlan.parse(args.faults)
        except ValueError as e:
            ap.error(f"--faults: {e}")
    cfg = FleetConfig(n_workers=max(1, args.fleet), faults=plan)
    res = FleetScheduler(spec, backend, store, cfg, policy=policy).run()
    fl = res.fleet
    print(f"# fleet: {fl.get('n_workers')} workers, "
          f"{fl.get('n_done', 0)}/{fl.get('n_cells', 0)} cells done, "
          f"{fl.get('n_failed_attempts', 0)} failed attempts recovered, "
          f"{fl.get('n_quarantined', 0)} quarantined"
          + (f", faults: {args.faults}" if args.faults else ""),
          file=sys.stderr)
    for index, info in sorted(res.quarantined.items()):
        print(f"# QUARANTINED cell {index} "
              f"(fingerprint {info['fingerprint'][:12]}) after "
              f"{info['attempts']} attempts: {info['error']}",
              file=sys.stderr)
    if not res.cells:
        print("# fleet completed no cells: every cell exhausted its retry "
              "budget", file=sys.stderr)
        raise SystemExit(1)
    return res


def _run_audit(ap, args) -> None:
    """Reproducibility-audit mode: measure the fixed audit campaign,
    archive it, and certify it EQUIVALENT to (or DRIFTED from) the
    archived baseline — the paper's "reproducible" claim made executable."""
    from repro.campaign import Campaign, CampaignSpec, ResultStore, SimBackend
    from repro.core import ExperimentDesign, TestCase
    from repro.history import (CONTROL_TAG, RunArchive, audit_runs,
                               format_audit_report, format_drift)

    audit_ops = ("allreduce", "bcast", "alltoall")
    per_op_kw = {}
    if args.mistune:
        if args.mistune not in audit_ops:
            # per_op_kw overrides are looked up by op name, so a typo (or
            # an op the audit campaign never measures) would inject nothing
            # and the "positive control" would silently pass
            ap.error(f"--mistune: {args.mistune!r} is not an audited op "
                     f"(one of {', '.join(audit_ops)})")
        if args.tag:
            ap.error("--tag cannot be combined with --mistune: seeded-drift "
                     "runs are always tagged 'control' so they can never "
                     "become a pinned baseline")
        # the seeded-drift control: same defect shape as the sweep/guideline
        # layers' mis-tuned collective (4x latency term, 3x fixed overhead)
        per_op_kw = {args.mistune: dict(alpha=12e-6, gamma=6e-6)}
    backend = SimBackend(p=8, seed0=args.seed, per_op_kw=per_op_kw,
                         sync_kw=dict(n_fitpts=60, n_exchanges=20))
    cases = [TestCase(op, m) for op in audit_ops for m in (512, 4096)]
    design = ExperimentDesign(n_launch_epochs=12, nrep=40, seed=args.seed)
    archive = RunArchive(args.archive)

    store = ResultStore(archive.new_store_path())
    res = Campaign(CampaignSpec(cases, design, name="repro-audit"),
                   backend, store).run()
    # a seeded-drift run is a *control*: archived for the record, but never
    # eligible as a default baseline (a deliberately-bad run must not
    # become the yardstick a later bad run "passes" against)
    tag = args.tag or (CONTROL_TAG if args.mistune else None)
    entry = archive.register(store.path, tag=tag)
    print(f"# registered {store.path.name} as run {entry.run_id}"
          + (f" [{entry.tag}]" if entry.tag else ""), file=sys.stderr)

    try:
        report = audit_runs(archive, entry, baseline_tag=args.baseline)
    except (LookupError, KeyError) as e:
        if args.baseline:
            ap.error(f"--baseline: {e}")
        print(f"# first run in {args.archive}: registered as the initial "
              "reference, nothing to audit against yet", file=sys.stderr)
        return
    print(format_audit_report(
        report, title=f"reproducibility audit [sim seed={args.seed}]"))
    print(f"# archive: {args.archive} ({report.n_computed} cells computed, "
          f"{report.n_resumed} resumed; campaign measured "
          f"{res.n_measured} cells)", file=sys.stderr)
    if not report.ok:
        print(format_drift(report), file=sys.stderr)
        raise SystemExit(1)


def _run_calibrate(ap, args) -> None:
    """Sim-to-real calibration mode: fit SimNet's noise model to a
    measured target backend, certify the fit with the TOST audit engine
    on held-out launch epochs, and archive the run as ``calibrated``.
    Exit 1 only on DRIFTED (positive drift evidence on a held-out cell);
    INCONCLUSIVE cells report visibly but pass."""
    from repro.calibrate import calibrate, default_space
    from repro.campaign import ResultStore, SimBackend
    from repro.core import ExperimentDesign, TestCase
    from repro.history import RunArchive, format_audit_report, format_drift

    param_names = [s.strip() for s in args.params.split(",") if s.strip()]
    archive = RunArchive(args.archive)
    base = SimBackend(p=args.p, seed0=args.seed,
                      sync_kw=dict(n_fitpts=60, n_exchanges=20))
    # a real runtime's per-call dispatch cost (JaxBackend pmap on CPU:
    # hundreds of µs) dwarfs simulator-scale latencies; widen the
    # alpha/gamma bounds so the fit can reach it instead of railing
    latency_scale = 100.0 if args.target == "jax" else 1.0
    try:
        space = default_space(base=base, names=param_names or None,
                              latency_scale=latency_scale)
    except ValueError as e:
        ap.error(f"--params: {e}")

    if args.target == "sim":
        # sim-as-target smoke: a "truth" simulator with shifted noise
        # knobs and an offset seed0 (same seed would fit one noise
        # realization, which calibrate() rejects). What the fit should
        # recover is known, so CI can gate on the verdict.
        target = SimBackend(
            p=args.p, seed0=args.seed + 7919,
            op_kw=dict(alpha=6e-6, noise_sigma=0.09, tail_prob=0.16),
            sync_kw=dict(n_fitpts=60, n_exchanges=20))
        ops = ("allreduce", "bcast")
    else:
        # jax op names are unknown to make_op's preset table, so the sim
        # candidates start from the base noise model — which is the point:
        # the fit, not a preset, reproduces the measured latencies
        from repro.campaign import JaxBackend
        target = JaxBackend()
        ops = ("psum", "all_gather")
    cases = [TestCase(op, m) for op in ops for m in (512, 4096)]
    design = ExperimentDesign(n_launch_epochs=args.epochs, nrep=args.nrep,
                              seed=args.seed)
    store = ResultStore(args.store if args.store
                        else archive.new_store_path(stem="calib"))

    result = calibrate(space, target, cases=cases, design=design,
                       store=store, archive=archive, seed=args.seed,
                       budget=args.budget, max_rounds=args.rounds)

    fitted = ", ".join(f"{k}={v:.4g}" for k, v in result.params.items())
    print(f"# fitted: {fitted}", file=sys.stderr)
    print(f"# objective: {result.objective:.6f} after "
          f"{len(result.rounds)} rounds ({result.n_rounds_resumed} "
          f"replayed from the store), {result.spent_nrep} nrep spent",
          file=sys.stderr)
    print(format_audit_report(
        result.report,
        title=f"calibration certification [{args.target} -> sim, "
              f"{result.n_heldout_epochs} held-out epochs]"))
    print(f"# registered {store.path.name} as run "
          f"{result.run_entry.run_id} [{result.run_entry.tag}]"
          if result.run_entry else "# no archive entry", file=sys.stderr)
    print(f"# store: {store.path} (resumable: calib-round lines replay "
          "the search, records resume the measurements)", file=sys.stderr)
    if not result.ok:
        print(format_drift(result.report), file=sys.stderr)
        raise SystemExit(1)


def _run_suite(ap, args) -> None:
    """The default mode: run the benchmark suite and print CSV rows."""
    from repro.core.design import NREP_SPENT
    from repro.simjax import engine_stats

    from benchmarks import suite
    from benchmarks.suite import ALL_BENCHES

    if args.list:
        for bench in ALL_BENCHES:
            doc = (bench.__doc__ or "").strip().splitlines()[0]
            print(f"{bench.__name__}: {doc}")
        return

    if args.json:
        try:  # fail fast, not after minutes of benchmarking; append mode
            with open(args.json, "a"):  # so an existing file is untouched
                pass
        except OSError as e:
            ap.error(f"--json path not writable: {e}")

    suite.SEED_OFFSET = args.seed
    if args.workers is not None:
        suite.N_WORKERS = max(1, args.workers)
    suite.STORE_PATH = args.store

    report = {"seed_offset": args.seed, "workers": suite.N_WORKERS,
              "benches": []}
    print("name,us_per_call,derived")
    failures = 0
    t_suite = time.time()
    nrep_suite = NREP_SPENT.read()
    for bench in ALL_BENCHES:
        if args.only and args.only not in bench.__name__:
            continue
        t0 = time.time()
        nrep0 = NREP_SPENT.read()
        jit0 = engine_stats()
        try:
            rows = bench()
        except Exception as e:  # keep the suite running; report at the end
            print(f"{bench.__name__},NaN,ERROR:{e!r}", flush=True)
            report["benches"].append(
                dict(name=bench.__name__, seconds=time.time() - t0,
                     nrep_total=NREP_SPENT.read() - nrep0,
                     error=repr(e), rows=[]))
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}", flush=True)
        dt = time.time() - t0
        nrep_total = NREP_SPENT.read() - nrep0
        # repetitions spent is the machine-independent cost: wall-clock
        # shows *when* a box is slow, nrep shows what the experiment *paid*
        print(f"# {bench.__name__} took {dt:.1f}s, spent {nrep_total} nrep",
              file=sys.stderr, flush=True)
        entry = dict(name=bench.__name__, seconds=round(dt, 3),
                     nrep_total=nrep_total,
                     rows=[dict(name=n, us_per_call=u, derived=d)
                           for n, u, d in rows])
        # jit telemetry delta: traces compiled / device dispatches this
        # bench issued through the simulation engine ("one trace per
        # campaign" as a measured quantity; absent for numpy-only benches)
        jit1 = engine_stats()
        nd = jit1["n_dispatches"] - jit0["n_dispatches"]
        if nd > 0:
            nt = jit1["n_traces"] - jit0["n_traces"]
            entry["jit"] = dict(n_traces=nt, n_dispatches=nd,
                                cache_hit_rate=round(1.0 - nt / nd, 4))
        report["benches"].append(entry)
    report["total_seconds"] = round(time.time() - t_suite, 3)
    report["total_nrep"] = NREP_SPENT.read() - nrep_suite
    report["failures"] = failures
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


def _add_seed(p) -> None:
    p.add_argument("--seed", type=int, default=0,
                   help="offset added to every simulator seed (>= 0)")


def _add_store(p, why: str) -> None:
    p.add_argument("--store", default=None, metavar="PATH", help=why)


def main(argv: list[str] | None = None) -> None:
    argv = _legacy_argv(sys.argv[1:] if argv is None else list(argv))

    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="MPI-benchmarking-revisited reproduction suite")
    sub = ap.add_subparsers(dest="cmd", required=True, metavar="COMMAND")

    p_run = sub.add_parser(
        "run", help="run the benchmark suite (the default command)")
    p_run.add_argument("--only", default=None,
                       help="substring filter on benchmark names")
    p_run.add_argument("--list", action="store_true",
                       help="list available benchmarks and exit")
    _add_seed(p_run)
    p_run.add_argument("--workers", type=int, default=None,
                       help="process-pool size for campaign launch epochs")
    p_run.add_argument("--json", default=None, metavar="PATH",
                       help="write per-bench wall-clock + nrep + rows as "
                            "JSON")
    _add_store(p_run, "persist campaign results to a JSONL ResultStore")

    p_sweep = sub.add_parser(
        "sweep", help="factor sweep + factor-impact report (sim backend)")
    p_sweep.add_argument("--axes", default=None, metavar="NAMES",
                         help="comma-separated subset of the stock factor "
                              "axes (default: tuning,sync_method,"
                              "window_us,dtype)")
    _add_seed(p_sweep)
    p_sweep.add_argument("--workers", type=int, default=None,
                         help="shard grid cells over a process pool")
    _add_store(p_sweep, "resumable sweep store (cell granularity; "
                        "required by --policy and --fleet)")
    p_sweep.add_argument("--policy", default=None,
                         choices=("uniform", "racing", "successive_halving"),
                         help="budgeted allocation policy: spend nrep in "
                              "rounds, only on axes whose verdict is still "
                              "undecided (requires --store)")
    p_sweep.add_argument("--budget", type=int, default=None, metavar="NREP",
                         help="total-nrep cap for --policy (a stop "
                              "criterion: raising it only extends the "
                              "allocation sequence)")
    p_sweep.add_argument("--verdicts", default=None, metavar="PATH",
                         help="write the final per-axis MATTERS/null "
                              "verdicts (+ allocation summary) as JSON")
    p_sweep.add_argument("--fleet", type=int, default=None, metavar="N",
                         help="run fault-tolerantly on N lease-queue "
                              "workers (requires --store; quarantined "
                              "cells are reported, exit 1 only if nothing "
                              "completes)")
    p_sweep.add_argument("--faults", default=None, metavar="SPEC",
                         help="inject seeded faults into a --fleet sweep, "
                              "e.g. crash=0.4,straggle=0.2,seed=7 (kinds: "
                              "crash, straggle, raise, torn)")

    p_guide = sub.add_parser(
        "guidelines", help="verify the performance-guideline family "
                           "(exit 1 on violation)")
    p_guide.add_argument("--backend", default="sim",
                         choices=("sim", "kernel"),
                         help="which implementation to audit")
    _add_seed(p_guide)
    _add_store(p_guide, "resumable verification store")

    p_audit = sub.add_parser(
        "audit", help="reproducibility audit vs the archived baseline "
                      "(exit 1 on DRIFTED)")
    p_audit.add_argument("--archive", required=True, metavar="DIR",
                         help="run-archive directory "
                              "(repro.history.RunArchive)")
    p_audit.add_argument("--baseline", default=None, metavar="TAG",
                         help="audit against the archived run tagged TAG")
    p_audit.add_argument("--tag", default=None, metavar="TAG",
                         help="register this audit run under TAG")
    p_audit.add_argument("--mistune", default=None, metavar="OP",
                         help="seed a drifted collective into the audit "
                              "run (positive control)")
    _add_seed(p_audit)

    p_cal = sub.add_parser(
        "calibrate", help="fit SimNet's noise model to a target backend, "
                          "certify on held-out epochs (exit 1 on DRIFTED)")
    p_cal.add_argument("--target", default="sim", choices=("sim", "jax"),
                       help="what to calibrate against: a shifted-truth "
                            "simulator (CI smoke) or the JAX backend's "
                            "measured collectives")
    p_cal.add_argument("--archive", required=True, metavar="DIR",
                       help="run-archive directory; the fitted run is "
                            "registered under the 'calibrated' tag and "
                            "the fit report logged to its manifest")
    _add_store(p_cal, "shared fit store (target + candidates + search "
                      "state; default: a fresh calib-NNN.jsonl in the "
                      "archive). Pass the same path to resume a killed "
                      "fit.")
    p_cal.add_argument("--budget", type=int, default=None, metavar="NREP",
                       help="total-repetition cap, checked at round "
                            "boundaries (a stop criterion)")
    p_cal.add_argument("--params", default="op.alpha,op.noise_sigma,"
                                           "op.tail_prob", metavar="NAMES",
                       help="comma-separated noise-model knobs to fit "
                            "(stock surface in repro.calibrate."
                            "default_space)")
    p_cal.add_argument("--rounds", type=int, default=8, metavar="N",
                       help="max coordinate-descent rounds")
    p_cal.add_argument("--epochs", type=int, default=12, metavar="N",
                       help="launch epochs per campaign (first two thirds "
                            "fit, the rest certify)")
    p_cal.add_argument("--nrep", type=int, default=30, metavar="N",
                       help="repetitions per (case, epoch)")
    p_cal.add_argument("--p", type=int, default=8, metavar="RANKS",
                       help="simulated cluster size")
    _add_seed(p_cal)

    p_cmp = sub.add_parser(
        "compare", help="Wilcoxon comparison of two stores' campaigns")
    p_cmp.add_argument("store_a", metavar="STOREA")
    p_cmp.add_argument("store_b", metavar="STOREB")

    args = ap.parse_args(argv)
    if getattr(args, "seed", 0) < 0:
        ap.error("--seed must be >= 0 (it offsets non-negative RNG seeds)")
    if args.cmd == "sweep" and args.faults and args.fleet is None:
        ap.error("--faults only makes sense with --fleet")

    if args.cmd == "compare":
        _compare_stores(ap, args.store_a, args.store_b)
    elif args.cmd == "audit":
        _run_audit(ap, args)
    elif args.cmd == "calibrate":
        _run_calibrate(ap, args)
    elif args.cmd == "guidelines":
        _run_guidelines(ap, args)
    elif args.cmd == "sweep":
        _run_sweep(ap, args)
    else:
        _run_suite(ap, args)


if __name__ == "__main__":
    main()
