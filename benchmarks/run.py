"""Benchmark harness entry point: ``python -m benchmarks.run [--only ...]``.

One function per paper table/figure (see ``benchmarks.suite``). Prints
``name,us_per_call,derived`` CSV. The full suite runs in a few minutes on a
single CPU core; ``--only fig9`` style substring filters select subsets.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args()

    from benchmarks.suite import ALL_BENCHES

    print("name,us_per_call,derived")
    failures = 0
    for bench in ALL_BENCHES:
        if args.only and args.only not in bench.__name__:
            continue
        t0 = time.time()
        try:
            rows = bench()
        except Exception as e:  # keep the suite running; report at the end
            print(f"{bench.__name__},NaN,ERROR:{e!r}", flush=True)
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}", flush=True)
        print(f"# {bench.__name__} took {time.time() - t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
