"""Benchmark harness entry point: ``python -m benchmarks.run [options]``.

One function per paper table/figure (see ``benchmarks.suite``). Prints
``name,us_per_call,derived`` CSV; per-bench wall-clock goes to stderr.

Options:
  --only SUBSTR   substring filter on benchmark function names
                  (e.g. ``--only fig`` for the simulation-backed figures,
                  ``--only micro`` for the engine microbenchmark)
  --list          print the available benchmark names and exit
  --seed N        offset every simulator seed by N (re-rolls the whole
                  suite under a different RNG universe; default 0)
  --workers N     processes for campaign launch epochs (default 1 =
                  serial; N > 1 gives bit-identical results and pays off
                  only when one epoch outweighs pool startup)
  --json PATH     also write machine-readable results: per-bench wall-clock
                  seconds + rows, for recording the perf trajectory in CI
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(
        description="MPI-benchmarking-revisited reproduction suite")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--list", action="store_true",
                    help="list available benchmarks and exit")
    ap.add_argument("--seed", type=int, default=0,
                    help="offset added to every simulator seed (>= 0)")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool size for campaign launch epochs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write per-bench wall-clock + rows as JSON")
    args = ap.parse_args()
    if args.seed < 0:
        ap.error("--seed must be >= 0 (it offsets non-negative RNG seeds)")

    from benchmarks import suite
    from benchmarks.suite import ALL_BENCHES

    if args.list:
        for bench in ALL_BENCHES:
            doc = (bench.__doc__ or "").strip().splitlines()[0]
            print(f"{bench.__name__}: {doc}")
        return

    if args.json:
        try:  # fail fast, not after minutes of benchmarking; append mode
            with open(args.json, "a"):  # so an existing file is untouched
                pass
        except OSError as e:
            ap.error(f"--json path not writable: {e}")

    suite.SEED_OFFSET = args.seed
    if args.workers is not None:
        suite.N_WORKERS = max(1, args.workers)

    report = {"seed_offset": args.seed, "workers": suite.N_WORKERS,
              "benches": []}
    print("name,us_per_call,derived")
    failures = 0
    t_suite = time.time()
    for bench in ALL_BENCHES:
        if args.only and args.only not in bench.__name__:
            continue
        t0 = time.time()
        try:
            rows = bench()
        except Exception as e:  # keep the suite running; report at the end
            print(f"{bench.__name__},NaN,ERROR:{e!r}", flush=True)
            report["benches"].append(
                dict(name=bench.__name__, seconds=time.time() - t0,
                     error=repr(e), rows=[]))
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}", flush=True)
        dt = time.time() - t0
        print(f"# {bench.__name__} took {dt:.1f}s", file=sys.stderr, flush=True)
        report["benches"].append(
            dict(name=bench.__name__, seconds=round(dt, 3),
                 rows=[dict(name=n, us_per_call=u, derived=d)
                       for n, u, d in rows]))
    report["total_seconds"] = round(time.time() - t_suite, 3)
    report["failures"] = failures
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
